"""Re-derive loop-aware flops/bytes + collective accounting for every
saved dry-run HLO (no recompilation) and update the JSON records in
place.  Used when the hlo_analysis cost model improves."""
from __future__ import annotations

import glob
import gzip
import json
import os
import sys

from repro.launch.hlo_analysis import (collective_bytes_from_hlo,
                                       flops_bytes_from_hlo)


def main(dryrun_dir: str) -> None:
    for gz in sorted(glob.glob(os.path.join(dryrun_dir, "*.hlo.gz"))):
        js = gz[: -len(".hlo.gz")] + ".json"
        if not os.path.exists(js):
            continue
        with gzip.open(gz, "rt") as f:
            txt = f.read()
        with open(js) as f:
            rec = json.load(f)
        rec["hlo_loop_aware"] = flops_bytes_from_hlo(txt)
        rec["collectives"] = collective_bytes_from_hlo(txt)
        with open(js, "w") as f:
            json.dump(rec, f, indent=1)
        print("updated", os.path.basename(js), flush=True)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else
         os.path.join(os.path.dirname(__file__), "..", "..", "..",
                      "experiments", "dryrun"))
