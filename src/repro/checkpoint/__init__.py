from repro.checkpoint.manager import CheckpointManager, restore_latest
from repro.checkpoint.packed import (CODR_FORMAT_VERSION,
                                     PackedCheckpointError, load_packed,
                                     save_packed)

__all__ = ["CheckpointManager", "restore_latest", "CODR_FORMAT_VERSION",
           "PackedCheckpointError", "load_packed", "save_packed"]
