"""CoDR-compressed linear layers for JAX models.

Three representations of the same weights, used at different levels:

1. **RLE streams** (`repro.core.rle`) — the paper's exact variable-width
   format.  Used for DRAM/storage accounting and the offline encoder; a
   variable-width bitstream is not expressible as a static-shape XLA
   buffer, so it does not appear in compiled graphs (documented in
   docs/DESIGN.md §2).
2. **Fixed-width unique-index pack** — the TPU-native adaptation: weights
   stored as ``b``-bit indices into a per-tensor sorted unique table,
   packed into uint32 words.  ``b = ceil(log2(U))`` is searched like the
   paper's encoding parameter, subject to TPU word alignment.  This is the
   format the Pallas kernel decodes in VMEM; HBM traffic = b/8 bytes per
   weight.
3. **Plain int8 + scale** — weight-only quantization fallback, XLA-visible
   in the dry-run serving graphs (1 byte/weight HBM traffic).

The unique-table format realises *weight repetition* and *sparsity*
(zero is just another table entry) in the kernel; *similarity* (Δ
encoding) lives in representation 1, where variable-width coding is
possible.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PackedWeight", "PackedLinear", "PackedEmbedding", "pack_unique",
           "pack_projection", "pack_embedding", "unpack_unique",
           "dense_weight", "codr_matmul_ref", "choose_bits"]


@dataclasses.dataclass
class PackedWeight:
    """Fixed-width unique-index packed weight for a (K, N) matrix.

    Registered as a JAX pytree so packed operands ride inside compiled
    graphs as ordinary leaves: ``packed``/``table``/``scale`` are the
    children (arrays), ``bits``/``shape`` the static aux data — so a
    ``jax.jit`` over a params pytree containing packed weights caches on
    the pack geometry and never retraces across decode steps.  The
    arrays may carry extra *leading* stack dimensions (scan-stacked
    transformer layers, expert stacks); ``shape`` is always the
    per-matrix ``(K, N_padded)`` geometry, so ``lax.scan`` slicing a
    stacked pack yields a valid per-matrix pack with unchanged aux.
    """

    packed: jax.Array      # (..., K, N * bits // 32) uint32
    table: jax.Array       # (..., 2**bits) unique values (zero-padded)
    scale: jax.Array       # per-tensor (leading-dims broadcast) scale
    bits: int
    shape: tuple[int, int]

    @property
    def hbm_bytes(self) -> int:
        return (self.packed.size * 4
                + self.table.size * self.table.dtype.itemsize
                + self.scale.size * 4)

    @property
    def dense_bf16_bytes(self) -> int:
        lead = int(np.prod(self.packed.shape[:-2], dtype=np.int64))
        return lead * int(np.prod(self.shape)) * 2

    @property
    def compression_vs_bf16(self) -> float:
        return self.dense_bf16_bytes / self.hbm_bytes


jax.tree_util.register_pytree_node(
    PackedWeight,
    lambda w: ((w.packed, w.table, w.scale), (w.bits, w.shape)),
    lambda aux, ch: PackedWeight(ch[0], ch[1], ch[2], aux[0], aux[1]))


def choose_bits(n_unique: int) -> int:
    """Smallest TPU-friendly index width covering ``n_unique`` values.
    Widths are restricted to divisors of 32 (clean word packing)."""
    for b in (1, 2, 4, 8, 16):
        if n_unique <= (1 << b):
            return b
    raise ValueError(f"too many unique values: {n_unique}")


def pack_unique(q: np.ndarray, scale: np.ndarray | float,
                dtype=jnp.bfloat16) -> PackedWeight:
    """Pack an int8 (K, N) weight matrix into the unique-index format."""
    q = np.asarray(q)
    assert q.ndim == 2, q.shape
    k, n = q.shape
    table = np.unique(q)                            # sorted ascending
    bits = choose_bits(len(table))
    per_word = 32 // bits
    if n % per_word:
        raise ValueError(f"N={n} not divisible by {per_word} ({bits}-bit pack)")
    idx = np.searchsorted(table, q).astype(np.uint32)   # (K, N)
    idx = idx.reshape(k, n // per_word, per_word)
    shifts = (np.arange(per_word, dtype=np.uint32) * bits)[None, None, :]
    packed = (idx << shifts).astype(np.uint32).sum(axis=-1, dtype=np.uint32)
    padded = np.zeros(1 << bits, dtype=np.float32)
    padded[: len(table)] = table
    return PackedWeight(
        packed=jnp.asarray(packed),
        table=jnp.asarray(padded, dtype=dtype),
        scale=jnp.asarray(scale, dtype=jnp.float32),
        bits=bits, shape=(k, n))


@partial(jax.jit, static_argnames=("bits", "n"))
def unpack_unique(packed: jax.Array, table: jax.Array, *, bits: int,
                  n: int) -> jax.Array:
    """Decode packed indices → dense weight matrix (table gather)."""
    per_word = 32 // bits
    shifts = jnp.arange(per_word, dtype=jnp.uint32) * bits
    mask = jnp.uint32((1 << bits) - 1)
    idx = (packed[:, :, None] >> shifts[None, None, :]) & mask
    idx = idx.reshape(packed.shape[0], n)
    return jnp.take(table, idx.astype(jnp.int32), axis=0)


def codr_matmul_ref(x: jax.Array, w: PackedWeight) -> jax.Array:
    """Reference decode-then-matmul (the Pallas kernel fuses these)."""
    dense = unpack_unique(w.packed, w.table, bits=w.bits, n=w.shape[1])
    y = jnp.dot(x.astype(jnp.float32), dense.astype(jnp.float32))
    return (y * w.scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# packed projection leaves — the transformer serving representation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PackedLinear:
    """A projection weight in packed bitstream form, as a params leaf.

    This is the pytree-leaf shape a ``repro.models`` params tree takes
    after ``repro.api.compile_params``: the :class:`PackedWeight`
    bitstream (possibly with leading stack dims — scanned layer stacks,
    expert stacks) plus the logical output-feature count (the pack pads
    the output dim to a whole uint32 word) and the name of the registered
    backend whose ``matmul`` executes it.  ``models.common.linear``
    intercepts these leaves and resolves the matmul through
    ``repro.core.backends`` instead of dense ``jnp.dot``
    (docs/DESIGN.md §2).

    Static aux data is ``(out_features, backend)`` — both hashable, so
    jitted ``prefill``/``decode_step`` graphs cache across calls and
    ``lax.scan`` can carry stacked packs in its xs.
    """

    weight: PackedWeight
    out_features: int
    backend: str = "codr_matmul"

    @property
    def in_features(self) -> int:
        return self.weight.shape[0]

    @property
    def hbm_bytes(self) -> int:
        return self.weight.hbm_bytes

    @property
    def n_weights(self) -> int:
        lead = int(np.prod(self.weight.packed.shape[:-2], dtype=np.int64))
        return lead * self.weight.shape[0] * self.out_features

    def dense(self) -> jax.Array:
        """Decode to the dequantized dense weight, float32.

        Bit-for-bit equal to ``ucr.dequantize_int8(restrict_unique(q, U),
        scale)`` on the original float leaf — the quantize-*applied*
        reference lane (``serving.codr_compress_params``) computes exactly
        that, which is what makes decode-fused vs quantize-applied logits
        comparable at the bit level.  Traceable: safe inside jit/scan
        (decode-on-dispatch).
        """
        pw = self.weight
        k, n_pad = pw.shape
        lead = pw.packed.shape[:-2]
        if lead:
            flat_p = pw.packed.reshape((-1,) + pw.packed.shape[-2:])
            flat_t = pw.table.reshape((-1,) + pw.table.shape[-1:])
            dec = jax.vmap(
                lambda p, t: unpack_unique(p, t, bits=pw.bits, n=n_pad)
            )(flat_p, flat_t)
            dec = dec.reshape(tuple(lead) + (k, n_pad))
            scale = pw.scale.reshape(tuple(lead) + (1, 1))
        else:
            dec = unpack_unique(pw.packed, pw.table, bits=pw.bits, n=n_pad)
            scale = pw.scale
        return dec[..., : self.out_features] * scale


jax.tree_util.register_pytree_node(
    PackedLinear,
    lambda w: ((w.weight,), (w.out_features, w.backend)),
    lambda aux, ch: PackedLinear(ch[0], aux[0], aux[1]))


def dense_weight(w, dtype=None):
    """Decode a :class:`PackedLinear` to its dense dequantized form;
    pass plain arrays through.  The escape hatch for weight uses no
    backend matmul covers — absorbed-MLA reshapes, ``ragged_dot`` expert
    stacks, recurrent einsums — keeping decode-on-dispatch semantics at
    those sites."""
    if isinstance(w, PackedLinear):
        w = w.dense()
    return w if dtype is None else w.astype(dtype)


def pack_projection(w: np.ndarray, *, n_unique: int = 16,
                    backend: str = "codr_matmul") -> PackedLinear:
    """Offline-encode one float projection leaf into bitstream form.

    ``w`` is ``(..., K, N)`` — any leading dims are treated as a stack of
    independent ``(K, N)`` matrices (scan-stacked transformer layers,
    expert stacks) sharing one quantization: like
    ``serving.codr_compress_params``, the leaf is quantized as a single
    tensor (``quantize_int8`` over ``w.reshape(-1, N)`` + the paper's U
    restriction), so decode-fused execution and the quantize-applied
    reference see bit-identical weights.  The shared unique table and
    scale are broadcast over the leading dims so ``lax.scan`` can slice
    the stack axis uniformly across all three arrays.
    """
    from repro.core import ucr

    w = np.asarray(w, dtype=np.float32)
    if w.ndim < 2:
        raise ValueError(f"pack_projection needs a (..., K, N) matrix, "
                         f"got shape {w.shape}")
    *lead, k, n = w.shape
    q, scale = ucr.quantize_int8(w.reshape(-1, n))
    q = ucr.restrict_unique(q, n_unique).reshape(w.shape)
    table = np.unique(q)
    bits = choose_bits(max(len(table), 2))
    per_word = 32 // bits
    idx = np.searchsorted(table, q).astype(np.uint32)
    pad = (-n) % per_word
    if pad:                       # pad output features to a whole word;
        idx = np.pad(idx, [(0, 0)] * (idx.ndim - 1) + [(0, pad)])
        # padded columns decode to table[0] and are cropped post-matmul
    idx = idx.reshape(*lead, k, (n + pad) // per_word, per_word)
    shifts = np.arange(per_word, dtype=np.uint32) * bits
    packed = (idx << shifts).astype(np.uint32).sum(axis=-1, dtype=np.uint32)
    padded_table = np.zeros(1 << bits, dtype=np.float32)
    padded_table[: len(table)] = table
    lead = tuple(lead)
    if lead:
        padded_table = np.broadcast_to(padded_table,
                                       lead + padded_table.shape).copy()
        scale_arr = np.full(lead, scale, dtype=np.float32)
    else:
        scale_arr = np.asarray(scale, dtype=np.float32)
    pw = PackedWeight(
        packed=jnp.asarray(packed),
        table=jnp.asarray(padded_table, dtype=jnp.float32),
        scale=jnp.asarray(scale_arr),
        bits=bits, shape=(k, n + pad))
    return PackedLinear(pw, out_features=n, backend=backend)


# ---------------------------------------------------------------------------
# packed embedding leaves — row-gatherable vocabulary tables
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PackedEmbedding:
    """A ``(V, d)`` embedding table in packed bitstream form.

    Same fixed-width unique-index pack as :class:`PackedLinear`, but
    the access pattern is a *row gather* by token id rather than a
    matmul: the pack keeps the vocab axis leading, so a lookup touches
    only ``d * bits / 8`` bytes per token instead of the dense row.
    ``models.common.embedding_lookup`` / ``unembed`` intercept these
    leaves and resolve through ``repro.core.backends`` (``gather`` /
    ``unembed``), mirroring how ``linear`` treats :class:`PackedLinear`
    (docs/DESIGN.md §2.2).
    """

    weight: PackedWeight
    d_model: int                 # logical row width (pack pads to a word)
    backend: str = "codr_matmul"

    @property
    def vocab_size(self) -> int:
        return self.weight.shape[0]

    @property
    def hbm_bytes(self) -> int:
        return self.weight.hbm_bytes

    @property
    def n_weights(self) -> int:
        return self.weight.shape[0] * self.d_model

    def lookup(self, tokens: jax.Array) -> jax.Array:
        """Gather + decode rows for ``tokens`` (any int shape), f32.

        Bit-for-bit equal to indexing the quantize-applied dense table:
        the gathered packed words are unpacked with the same shift/mask
        arithmetic as ``unpack_unique`` and dequantized through the same
        f32 ``table-value * scale`` product."""
        pw = self.weight
        rows = jnp.take(pw.packed, tokens, axis=0)       # (..., words)
        per_word = 32 // pw.bits
        shifts = jnp.arange(per_word, dtype=jnp.uint32) * pw.bits
        mask = jnp.uint32((1 << pw.bits) - 1)
        idx = (rows[..., None] >> shifts) & mask
        idx = idx.reshape(tuple(tokens.shape) + (pw.shape[1],))
        vals = jnp.take(pw.table, idx.astype(jnp.int32), axis=0)
        return vals[..., : self.d_model] * pw.scale

    def dense(self) -> jax.Array:
        """Decode the whole table to its dequantized ``(V, d)`` f32
        form (the unembed logit projection consumes this)."""
        pw = self.weight
        dec = unpack_unique(pw.packed, pw.table, bits=pw.bits,
                            n=pw.shape[1])
        return dec[:, : self.d_model] * pw.scale


jax.tree_util.register_pytree_node(
    PackedEmbedding,
    lambda w: ((w.weight,), (w.d_model, w.backend)),
    lambda aux, ch: PackedEmbedding(ch[0], aux[0], aux[1]))


def pack_embedding(w: np.ndarray, *, n_unique: int = 16,
                   backend: str = "codr_matmul") -> PackedEmbedding:
    """Offline-encode one ``(V, d)`` embedding leaf into row-gatherable
    packed form.  Quantization is identical to :func:`pack_projection`
    (single-tensor ``quantize_int8`` + U restriction), so packed-gather
    lookups match the quantize-applied dense table bit-for-bit."""
    w = np.asarray(w, dtype=np.float32)
    if w.ndim != 2:
        raise ValueError(f"pack_embedding needs a (V, d) table, "
                         f"got shape {w.shape}")
    pl = pack_projection(w, n_unique=n_unique, backend=backend)
    return PackedEmbedding(pl.weight, d_model=w.shape[1], backend=backend)
