"""Generic decoder-only language model with heterogeneous layers.

Layers are organized in *periods*: one period = ``cfg.block_pattern``
(e.g. Jamba's ``(m, m, m, attn, m, m, m, m)``), scanned ``n_periods``
times with stacked parameters — the HLO contains one period body
regardless of depth.  Optional non-scanned prologue layers cover
DeepSeek's leading dense layer.  The same forward serves train (causal,
no cache), prefill (returns the KV/state caches) and decode (single
token against preallocated caches).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.common import (DEFAULT_DTYPE, constrain_tokens, embed_init,
                                 embedding_lookup, norm_apply, norm_init,
                                 softmax_xent, unembed)

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_mixer(key, kind: str, cfg):
    if kind == "attn":
        return attn.mla_init(key, cfg) if cfg.use_mla else attn.gqa_init(key, cfg)
    if kind == "mamba":
        return ssm.mamba_init(key, cfg)
    if kind == "mlstm":
        return ssm.mlstm_init(key, cfg)
    if kind == "slstm":
        return ssm.slstm_init(key, cfg)
    raise ValueError(kind)


def _init_layer(key, spec, cfg) -> dict:
    kind, ffn = spec
    k1, k2 = jax.random.split(key)
    p = {"norm1": norm_init(cfg.d_model, cfg.norm_type),
         "mixer": _init_mixer(k1, kind, cfg)}
    if ffn == "dense":
        p["norm2"] = norm_init(cfg.d_model, cfg.norm_type)
        p["mlp"] = moe_mod.mlp_init(k2, cfg.d_model, cfg.d_ff)
    elif ffn == "moe":
        p["norm2"] = norm_init(cfg.d_model, cfg.norm_type)
        p["mlp"] = moe_mod.moe_init(k2, cfg)
    return p


def _init_period(key, cfg) -> dict:
    plan = cfg.layer_plan()
    keys = jax.random.split(key, len(plan))
    return {f"b{i}": _init_layer(keys[i], spec, cfg)
            for i, spec in enumerate(plan)}


def init_params(key, cfg) -> dict:
    ks = jax.random.split(key, 5)
    params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
        "final_norm": norm_init(cfg.d_model, cfg.norm_type),
        "stack": jax.vmap(lambda k: _init_period(k, cfg))(
            jax.random.split(ks[1], cfg.n_periods)),
    }
    if not cfg.tied_embeddings:
        params["out_embed"] = embed_init(ks[2], cfg.vocab_size, cfg.d_model)
    if cfg.n_dense_layers:
        pkeys = jax.random.split(ks[3], cfg.n_dense_layers)
        params["prologue"] = [
            _init_layer(pkeys[i], ("attn", "dense"), cfg)
            for i in range(cfg.n_dense_layers)]
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _mixer_cache(kind: str, cfg, batch: int, seq: int, dtype, paged=None):
    if kind == "attn":
        if paged is not None:
            if cfg.use_mla:
                return attn.mla_cache_init_paged(cfg, paged, dtype)
            return attn.gqa_cache_init_paged(cfg, paged, dtype)
        if cfg.use_mla:
            return attn.mla_cache_init(cfg, batch, seq, dtype)
        return attn.gqa_cache_init(cfg, batch, seq, dtype)
    if paged is not None:
        raise NotImplementedError(
            f"paged KV cache covers attention mixers only, got {kind!r} "
            f"({cfg.name}) — SSM states have no sequence axis to page")
    if kind == "mamba":
        return ssm.mamba_state_init(cfg, batch, dtype)
    if kind == "mlstm":
        return ssm.mlstm_state_init(cfg, batch)
    if kind == "slstm":
        return ssm.slstm_state_init(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg, batch: int, seq: int, dtype=DEFAULT_DTYPE,
               paged=None) -> dict:
    """``paged`` is an optional :class:`repro.models.cache.PagedSpec`;
    when given, attention KV leaves become :class:`PagedKV` pools
    (``batch`` must equal ``paged.n_slots``, ``seq`` its ``max_len``)."""
    if paged is not None and (batch != paged.n_slots
                              or seq != paged.max_len):
        raise ValueError(
            f"paged cache geometry mismatch: batch={batch}/seq={seq} vs "
            f"spec n_slots={paged.n_slots}/max_len={paged.max_len}")
    plan = cfg.layer_plan()
    period = {f"b{i}": _mixer_cache(spec[0], cfg, batch, seq, dtype, paged)
              for i, spec in enumerate(plan)}
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape),
        period)
    out = {"stack": stacked}
    if cfg.n_dense_layers:
        out["prologue"] = [
            _mixer_cache("attn", cfg, batch, seq, dtype, paged)
            for _ in range(cfg.n_dense_layers)]
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block_apply(lp, x, spec, cfg, mode, cache, pos, positions):
    kind, _ffn = spec
    h = norm_apply(x, lp["norm1"], cfg.norm_type, f32=cfg.norm_f32)
    if kind == "attn":
        mixer = lp["mixer"]
        if mode == "decode":
            fn = attn.mla_decode if cfg.use_mla else attn.gqa_decode
            out, new_cache = fn(mixer, h, cfg, cache, pos)
        else:
            fn = attn.mla_forward if cfg.use_mla else attn.gqa_forward
            out, new_cache = fn(mixer, h, cfg, positions)
    elif kind == "mamba":
        if mode == "decode":
            out, new_cache = ssm.mamba_decode(lp["mixer"], h, cfg, cache)
        else:
            out, new_cache = ssm.mamba_forward(lp["mixer"], h, cfg,
                                               chunk=cfg.mamba_chunk)
    elif kind == "mlstm":
        out, new_cache = ssm.mlstm_forward(
            lp["mixer"], h, cfg, state=cache if mode == "decode" else None)
    elif kind == "slstm":
        out, new_cache = ssm.slstm_forward(
            lp["mixer"], h, cfg, state=cache if mode == "decode" else None)
    else:
        raise ValueError(kind)
    x = x + out
    if "mlp" in lp:
        h = norm_apply(x, lp["norm2"], cfg.norm_type, f32=cfg.norm_f32)
        if "router" in lp["mlp"]:
            out = moe_mod.moe_forward(lp["mlp"], h, cfg, mode=mode)
        else:
            out = moe_mod.mlp_forward(lp["mlp"], h, cfg.act)
        x = x + out
    x = constrain_tokens(x)
    return x, new_cache


def forward(params, tokens, cfg, *, mode: str = "train", cache=None,
            pos=None, prefix=None):
    """tokens (B, S) int32 → (logits, new_cache).

    mode='train'  : causal forward, logits for every position, no cache.
    mode='prefill': causal forward, logits for the LAST position, cache out.
    mode='decode' : S == 1, attends into the preallocated cache at ``pos``.
    """
    plan = cfg.layer_plan()
    x = embedding_lookup(params["embed"], tokens, DEFAULT_DTYPE)
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    x = constrain_tokens(x)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    new_prologue = []
    for i, lp in enumerate(params.get("prologue", [])):
        c = cache["prologue"][i] if cache else None
        x, nc = _block_apply(lp, x, ("attn", "dense"), cfg, mode, c, pos,
                             positions)
        new_prologue.append(nc)

    if mode == "train":
        def body(xc, period_params):
            for i, spec in enumerate(plan):
                xc, _ = _block_apply(period_params[f"b{i}"], xc, spec, cfg,
                                     mode, None, pos, positions)
            return xc, None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["stack"])
        stack_cache = None
    elif mode == "prefill":
        def body(xc, period_params):
            caches = {}
            for i, spec in enumerate(plan):
                xc, nc = _block_apply(period_params[f"b{i}"], xc, spec, cfg,
                                      mode, None, pos, positions)
                caches[f"b{i}"] = nc
            return xc, caches
        x, stack_cache = jax.lax.scan(body, x, params["stack"])
    else:  # decode
        # the cache rides in the scan CARRY with per-period in-place index
        # updates (donation-friendly; scan-ys stacking round-trips the
        # whole cache through a staging buffer on some backends)
        def body(carry, xs):
            xc, cache_stack = carry
            period_params, idx = xs
            period_cache = jax.tree.map(
                lambda buf: jax.lax.dynamic_index_in_dim(
                    buf, idx, 0, keepdims=False), cache_stack)
            new_caches = {}
            for i, spec in enumerate(plan):
                xc, nc = _block_apply(period_params[f"b{i}"], xc, spec, cfg,
                                      mode, period_cache[f"b{i}"], pos,
                                      positions)
                new_caches[f"b{i}"] = nc
            cache_stack = jax.tree.map(
                lambda buf, nc: jax.lax.dynamic_update_index_in_dim(
                    buf, nc.astype(buf.dtype), idx, 0),
                cache_stack, new_caches)
            return (xc, cache_stack), None

        (x, stack_cache), _ = jax.lax.scan(
            body, (x, cache["stack"]),
            (params["stack"], jnp.arange(cfg.n_periods)))

    x = norm_apply(x, params["final_norm"], cfg.norm_type,
                   f32=cfg.norm_f32)
    if mode == "prefill":
        x = x[:, -1:]
    out_embed = params.get("out_embed", params["embed"])
    logits = unembed(x, out_embed)

    new_cache = None
    if mode != "train":
        new_cache = {"stack": stack_cache}
        if cfg.n_dense_layers:
            new_cache["prologue"] = new_prologue
    return logits, new_cache


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def train_loss(params, batch, cfg):
    logits, _ = forward(params, batch["tokens"], cfg, mode="train",
                        prefix=batch.get("prefix"))
    if cfg.frontend_seq and "prefix" in batch:
        logits = logits[:, cfg.frontend_seq:]
    mask = batch.get("mask")
    return softmax_xent(logits[:, :-1], batch["tokens"][:, 1:],
                        mask[:, 1:] if mask is not None else None)


def prefill(params, tokens, cfg, prefix=None):
    return forward(params, tokens, cfg, mode="prefill", prefix=prefix)


def decode_step(params, cache, token, pos, cfg):
    """token (B,) int32, pos scalar int32 → (logits (B, V), cache)."""
    logits, cache = forward(params, token[:, None], cfg, mode="decode",
                            cache=cache, pos=pos)
    return logits[:, 0], cache
