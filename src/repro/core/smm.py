"""Scalar–matrix multiplication dataflow (paper §III-A, Fig. 3b) with
differential computation (paper Eq. 1).

This is the *faithful execution model* of a CoDR processing unit, in
NumPy/JAX: each unique weight (reconstructed by the running Δ-sum — the
differential accumulator) multiplies the whole input-feature matrix once,
and every repetition index routes a window of that product to its output
accumulator (the MPE→crossbar→APE path).

It is the oracle the Pallas kernels and the cost model are validated
against, and is bit-exact in int32 accumulation.
"""
from __future__ import annotations

import numpy as np

from repro.core.ucr import LayerCode, UCRVector

__all__ = ["conv2d_smm", "conv2d_smm_batched", "linear_smm",
           "conv2d_dense_ref", "decode_index"]


def decode_index(flat_idx: int, kernel_shape: tuple[int, int]) -> tuple[int, int, int]:
    """A flat index in a UCR vector of length ``T_M*R_K*C_K`` encodes the
    (output-channel-within-tile, kernel-row, kernel-col) coordinate."""
    rk, ck = kernel_shape
    m = flat_idx // (rk * ck)
    rem = flat_idx % (rk * ck)
    return m, rem // ck, rem % ck


def conv2d_dense_ref(x: np.ndarray, w: np.ndarray, stride: int = 1) -> np.ndarray:
    """Dense int32 conv oracle. ``x``: (N, R_I, C_I) int, ``w``: (M, N, R_K, C_K)."""
    n, ri, ci = x.shape
    m, n2, rk, ck = w.shape
    assert n == n2
    ro, co = (ri - rk) // stride + 1, (ci - ck) // stride + 1
    out = np.zeros((m, ro, co), dtype=np.int64)
    for mm in range(m):
        for nn in range(n):
            for r in range(rk):
                for c in range(ck):
                    out[mm] += (w[mm, nn, r, c].astype(np.int64)
                                * x[nn, r : r + stride * ro : stride,
                                     c : c + stride * co : stride])
    return out


def conv2d_smm(x: np.ndarray, code: LayerCode, stride: int = 1) -> np.ndarray:
    """CoDR execution: differential scalar–matrix multiply + index routing.

    ``x``: (N, R_I, C_I) int8/int32 input features.
    Returns int64 accumulations (pre-activation), identical to the dense
    oracle — computation reuse changes *work*, not results.
    """
    return conv2d_smm_batched(x[None], code, stride)[0]


def conv2d_smm_batched(x: np.ndarray, code: LayerCode,
                       stride: int = 1) -> np.ndarray:
    """Batched CoDR execution: ``x`` (B, N, R_I, C_I) → (B, M, RO, CO).

    No per-sample Python loop — every scalar–matrix product and every
    routed window broadcasts over the batch axis, so the MPE/APE work per
    unique weight is shared by the whole batch (the software analogue of
    the accelerator streaming a feature batch through one weight decode).
    """
    x = np.asarray(x)
    m, n = code.shape[0], code.shape[1]
    rk, ck = (code.shape[2], code.shape[3]) if len(code.shape) == 4 else (1, 1)
    b, _, ri, ci = x.shape
    ro, co = (ri - rk) // stride + 1, (ci - ck) // stride + 1
    out = np.zeros((b, m, ro, co), dtype=np.int64)

    vec_iter = iter(zip(code.vectors, code.ucr))
    n_tiles_n = -(-n // code.t_n)
    for m0 in range(0, m, code.t_m):
        for n0idx in range(n_tiles_n):
            n0 = n0idx * code.t_n
            for nn in range(n0, min(n0 + code.t_n, n)):
                _, u = next(vec_iter)
                _smm_one_vector(out, x[:, nn], u, m0, (rk, ck), ro, co,
                                stride)
    return out


def _smm_one_vector(out, x_planes, u: UCRVector, m0, kshape, ro, co, stride):
    """One MPE pass: running Δ-sum over unique weights; scalar × matrix;
    per-repetition window routed to APE ``m0 + m_local``.  ``x_planes`` is
    the batched input plane (B, R_I, C_I); all products broadcast over B."""
    running = np.int64(0)
    cursor = 0
    x_planes = x_planes.astype(np.int64)
    prev_product = None
    for val, rep in zip(u.unique_vals, u.reps):
        delta = np.int64(val) - running
        running += delta
        # differential computation (Eq. 1): Δ × I + previous product.
        # bit-exact with running × I since int arithmetic is associative.
        if prev_product is None:
            product = running * x_planes
        else:
            product = delta * x_planes + prev_product
        prev_product = product
        for idx in u.indexes[cursor : cursor + int(rep)]:
            m_local, r, c = decode_index(int(idx), kshape)
            out[:, m0 + m_local] += product[:, r : r + stride * ro : stride,
                                            c : c + stride * co : stride]
        cursor += int(rep)


def linear_smm(x: np.ndarray, code: LayerCode) -> np.ndarray:
    """FC layer via SMM (paper Fig. 1 model): per input unit, the weight
    column's unique values each multiply the input scalar once; indexes
    route products to output accumulators."""
    m, n = code.shape[0], code.shape[1]
    out = np.zeros(m, dtype=np.int64)
    vec_iter = iter(zip(code.vectors, code.ucr))
    for m0 in range(0, m, code.t_m):
        for n0 in range(0, n, code.t_n):
            for nn in range(n0, min(n0 + code.t_n, n)):
                _, u = next(vec_iter)
                running = np.int64(0)
                cursor = 0
                xi = np.int64(x[nn])
                prev = None
                for val, rep in zip(u.unique_vals, u.reps):
                    delta = np.int64(val) - running
                    running += delta
                    prev = delta * xi + (prev if prev is not None else np.int64(0))
                    for idx in u.indexes[cursor : cursor + int(rep)]:
                        out[m0 + int(idx)] += prev
                    cursor += int(rep)
    return out


def smm_op_counts(code: LayerCode, feature_elems: int) -> dict:
    """Multiplication / accumulation counts under UCR — the paper's ALU
    story: multiplies ∝ unique weights (not total weights)."""
    n_unique = sum(len(u.unique_vals) for u in code.ucr)
    n_nonzero = sum(u.n_nonzero for u in code.ucr)
    return {
        "mults": n_unique * feature_elems,
        "accums": n_nonzero * feature_elems,
        "dense_mults": code.n_weights * feature_elems,
        "unique_ratio": n_unique / max(n_nonzero, 1),
        "density": n_nonzero / max(code.n_weights, 1),
    }
