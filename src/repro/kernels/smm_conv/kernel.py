"""Pallas kernel: CoDR scalar–matrix-multiplication convolution.

This is the *faithful-mechanism* kernel — the MPE/APE datapath of paper
Fig. 5c expressed as a TPU kernel (run in interpret mode on CPU; the MXU
kernel in :mod:`repro.kernels.codr_matmul` is the performance path, see
docs/DESIGN.md §2):

* **Phase A — MPE / differential MLP array**: a ``fori_loop`` over the
  unique weights performs ``P[u] = P[u-1] + Δ[u] * X`` — the Matrix-Matrix
  Accumulator adding the Δ-multiplication result to the prior product
  (paper Eq. 1).  One scalar×matrix multiply per *unique* weight: weight
  sparsity, repetition, and similarity are all exploited here.
* **Phase B — crossbar + APE**: a ``fori_loop`` over the repetition
  entries routes a ``(RO, CO)`` window of the selected product ``P[u]``
  into the output accumulator of its output channel (dynamic slice +
  dynamic store = the interconnection network).  A convolution stride
  becomes a *strided* window load (``pl.dslice(r, ro, stride)``) — the
  crossbar skips feature columns instead of the ALUs doing extra work.

Grid ``(B, m_tiles, N)``: the whole batch is dispatched by one kernel
call (batched SMM dispatch — no per-sample Python loop); per (batch,
tile) the output stays stationary in VMEM scratch across the
input-channel loop (output stationary) while the input plane block is the
Input-RF broadcast.

Operand layout (built offline by ``pack_smm_operands`` from the UCR/RLE
decode — static shapes, padded, packed once per layer):

* ``x``       (B, N, RI, CI)          input feature batch
* ``deltas``  (m_tiles, N, U+1)       unique-weight Δs (padded 0)
* ``entries`` (m_tiles, N, L, 4)      (u, m_local, r, c) per repetition;
                                      padding points at the zero product
                                      row ``u = U`` and m_local = 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _smm_conv_kernel(x_ref, deltas_ref, entries_ref, o_ref, acc_ref, p_ref,
                     *, n_in: int, u_max: int, l_max: int, ro: int, co: int,
                     stride: int):
    n_step = pl.program_id(2)

    @pl.when(n_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0, 0].astype(jnp.float32)                    # (RI, CI)

    # -- Phase A: differential scalar–matrix multiplies (MPE array) --------
    p_ref[u_max, :, :] = jnp.zeros_like(x)                 # zero product row

    def mpe(u, carry):
        prod = carry + deltas_ref[0, 0, u].astype(jnp.float32) * x
        p_ref[u, :, :] = prod
        return prod

    jax.lax.fori_loop(0, u_max, mpe, jnp.zeros_like(x))

    # -- Phase B: crossbar routing + APE accumulation ----------------------
    def ape(l, _):
        u = entries_ref[0, 0, l, 0]
        m_loc = entries_ref[0, 0, l, 1]
        r = entries_ref[0, 0, l, 2]
        c = entries_ref[0, 0, l, 3]
        window = pl.load(p_ref, (pl.dslice(u, 1), pl.dslice(r, ro, stride),
                                 pl.dslice(c, co, stride)))
        cur = pl.load(acc_ref, (pl.dslice(m_loc, 1), slice(None), slice(None)))
        pl.store(acc_ref, (pl.dslice(m_loc, 1), slice(None), slice(None)),
                 cur + window)
        return 0

    jax.lax.fori_loop(0, l_max, ape, 0)

    @pl.when(n_step == n_in - 1)
    def _done():
        o_ref[...] = acc_ref[...][None].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("t_m", "ro", "co", "stride", "interpret"))
def smm_conv_pallas(x: jax.Array, deltas: jax.Array, entries: jax.Array,
                    *, t_m: int, ro: int, co: int, stride: int = 1,
                    interpret: bool = True) -> jax.Array:
    """Batched SMM convolution: ``x`` (B, N, RI, CI) → (B, m_tiles·t_m,
    RO, CO).  One compiled kernel call covers the whole batch."""
    b, n_in, ri, ci = x.shape
    m_tiles, n2, u_plus = deltas.shape
    assert n2 == n_in
    l_max = entries.shape[2]
    u_max = u_plus - 1

    kernel = functools.partial(_smm_conv_kernel, n_in=n_in, u_max=u_max,
                               l_max=l_max, ro=ro, co=co, stride=stride)
    return pl.pallas_call(
        kernel,
        grid=(b, m_tiles, n_in),
        in_specs=[
            pl.BlockSpec((1, 1, ri, ci), lambda bb, i, n: (bb, n, 0, 0)),
            pl.BlockSpec((1, 1, u_plus), lambda bb, i, n: (i, n, 0)),
            pl.BlockSpec((1, 1, l_max, 4), lambda bb, i, n: (i, n, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t_m, ro, co),
                               lambda bb, i, n: (bb, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m_tiles * t_m, ro, co),
                                       jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((t_m, ro, co), jnp.float32),        # APE accumulators
            pltpu.VMEM((u_plus, ri, ci), jnp.float32),     # MPE product rows
        ],
        interpret=interpret,
    )(x, deltas, entries)
