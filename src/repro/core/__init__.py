"""CoDR core: Universal Computation Reuse, customized RLE, the
scalar-matrix-multiplication dataflow, access/energy cost models, the
SCNN/UCNN baselines the paper compares against, the pluggable execution
backends, and the spec → compile → serve API (``repro.api``)."""
from repro.core import rle, ucr, smm, dataflow, cost_model  # noqa: F401
from repro.core.codr_linear import (PackedWeight, pack_unique,  # noqa: F401
                                    unpack_unique, codr_matmul_ref)
from repro.core.ucr import (LayerCode, encode_conv_layer,  # noqa: F401
                            encode_linear_layer, quantize_int8, ucr_transform)
from repro.core import backends, api  # noqa: F401  (after the codec deps)
