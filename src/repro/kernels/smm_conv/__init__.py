from repro.kernels.smm_conv.ops import smm_conv, pack_smm_operands
from repro.kernels.smm_conv.ref import smm_conv_ref

__all__ = ["smm_conv", "pack_smm_operands", "smm_conv_ref"]
