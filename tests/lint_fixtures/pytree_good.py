"""codrlint fixture: registered leaves and exempt host containers."""
import dataclasses

import jax


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RegisteredLeaf:
    data: jax.Array

    def tree_flatten(self):
        return (self.data,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@dataclasses.dataclass
class CallRegisteredLeaf:
    data: jax.Array


jax.tree_util.register_pytree_node(
    CallRegisteredLeaf,
    lambda v: ((v.data,), None),
    lambda aux, ch: CallRegisteredLeaf(*ch))


@dataclasses.dataclass
class HostOnlyPool:
    free_pages: list                # no array fields — stays host-side
    page_size: int = 16
