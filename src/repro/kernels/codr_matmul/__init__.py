from repro.kernels.codr_matmul.ops import codr_matmul
from repro.kernels.codr_matmul.ref import codr_matmul_ref

__all__ = ["codr_matmul", "codr_matmul_ref"]
