#!/usr/bin/env python
"""Doc cross-reference link check.

Scans every tracked ``.py`` and ``.md`` file for references to markdown
documents — both markdown links ``[text](DESIGN.md)`` and inline mentions
like ``docs/DESIGN.md §2`` in docstrings/comments — and fails (exit 1)
listing every reference that does not resolve.  A reference resolves if
the target exists relative to the referencing file's directory, the repo
root, or ``docs/``.  Section references into ``docs/DESIGN.md``
(``DESIGN.md §N`` and subsection forms like ``§3.5``) are additionally
checked against the ``## §N`` / ``### §N.M`` headings that actually
exist.

This is the guard against the failure mode this repo actually had:
module docstrings citing a ``DESIGN.md §2`` that was never written.

  python tools/check_docs.py          # from the repo root
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ["src", "tests", "benchmarks", "examples", "tools", "docs"]
ROOT_DOCS = ["README.md", "ROADMAP.md", "PAPER.md", "PAPERS.md"]
# SNIPPETS.md quotes external repos verbatim, ISSUE.md is the transient
# PR brief, CHANGES.md is a changelog (entries describe files as they
# existed at that point in history, including ones since removed)
SKIP = {"SNIPPETS.md", "ISSUE.md", "CHANGES.md"}

MD_TOKEN = re.compile(r"[A-Za-z0-9_./-]*[A-Za-z0-9_]\.md\b")
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+\.md)(#[^)]*)?\)")
SECTION_REF = re.compile(r"DESIGN\.md[`]*\s*§(\d+(?:\.\d+)*)")


def files_to_scan():
    for d in SCAN_DIRS:
        base = ROOT / d
        if base.is_dir():
            for ext in ("*.py", "*.md"):
                yield from sorted(base.rglob(ext))
    for name in ROOT_DOCS:
        p = ROOT / name
        if p.exists():
            yield p


def resolves(ref: str, src: pathlib.Path) -> bool:
    ref = ref.split("#")[0]
    for base in (src.parent, ROOT, ROOT / "docs"):
        try:
            if (base / ref).exists():
                return True
        except OSError:                 # pragma: no cover — weird token
            pass
    return False


def design_sections() -> set[str]:
    design = ROOT / "docs" / "DESIGN.md"
    if not design.exists():
        return set()
    return set(re.findall(r"^##+\s*§(\d+(?:\.\d+)*)", design.read_text(),
                          flags=re.M))


def main() -> int:
    errors = []
    sections = design_sections()
    for path in files_to_scan():
        if path.name in SKIP:
            continue
        text = path.read_text(errors="replace")
        rel = path.relative_to(ROOT)
        refs = set(MD_TOKEN.findall(text)) | \
            {m.group(1) for m in MD_LINK.finditer(text)}
        for ref in sorted(refs):
            if not resolves(ref, path):
                errors.append(f"{rel}: dangling doc reference {ref!r}")
        for m in SECTION_REF.finditer(text):
            if m.group(1) not in sections:
                errors.append(f"{rel}: DESIGN.md §{m.group(1)} — no such "
                              f"section (have: §{', §'.join(sorted(sections))})")
    if errors:
        print(f"{len(errors)} dangling doc reference(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print("doc cross-references OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
