"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e
top-2 on every other layer. [arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    n_experts=16, moe_top_k=2, moe_d_ff=14336, moe_every=2, moe_offset=1,
    block_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    ssm_d_state=16, ssm_d_conv=4, ssm_expand=2,
    sub_quadratic=True,
)
