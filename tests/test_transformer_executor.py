"""Transformer compressed-weight executor: ``repro.api.compile_params``
serving parity, jit/no-retrace behavior, and capability errors.

The contract under test (docs/DESIGN.md §2): a params pytree whose
projection leaves were packed into bitstream form must serve logits
**bit-for-bit equal** to the quantize-*applied* reference lane
(``serving.codr_compress_params``) when executed through the
decode-then-matmul backend (``tiled``), and near-exactly through the
fused ``codr_matmul`` Pallas kernel (f32 accumulation vs the reference's
bf16 dot).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as codr
from repro.configs import get_config, smoke_variant
from repro.core.serving import codr_compress_params
from repro.models import get_model

B, S = 2, 8
N_UNIQUE = 16

# GQA+MLP / MLA+MoE+prologue / mLSTM+sLSTM (recurrent-einsum r_proj)
PARITY_ARCHS = ["qwen2.5-3b", "deepseek-v2-236b", "xlstm-350m"]


def _setup(arch, key, backend):
    cfg = smoke_variant(get_config(arch))
    api = get_model(cfg)
    params = api.init_params(key, cfg)
    ref_params, _ = codr_compress_params(params, n_unique=N_UNIQUE)
    cp = codr.compile_params(params, codr.EncodeConfig(n_unique=N_UNIQUE),
                             backend=backend, accounting=False)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return cfg, api, ref_params, cp, tokens


# ---------------------------------------------------------------------------
# bit-for-bit: packed decode-then-matmul lane vs quantize-applied params
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_packed_prefill_decode_bitwise_vs_quantize_applied(arch, key):
    cfg, api, ref_params, cp, tokens = _setup(arch, key, "tiled")
    assert cp.packed_paths, arch
    lr, _ = api.prefill(ref_params, {"tokens": tokens}, cfg)
    lp, _ = api.prefill(cp.params, {"tokens": tokens}, cfg)
    np.testing.assert_array_equal(np.asarray(lr, np.float32),
                                  np.asarray(lp, np.float32))

    cache_r = api.init_cache(cfg, B, S)
    cache_p = api.init_cache(cfg, B, S)
    step = jax.jit(lambda p, c, t, i: api.decode_step(p, c, t, i, cfg))
    tok = tokens[:, 0]
    for i in range(4):
        l_r, cache_r = step(ref_params, cache_r, tok, jnp.int32(i))
        l_p, cache_p = step(cp.params, cache_p, tok, jnp.int32(i))
        np.testing.assert_array_equal(np.asarray(l_r, np.float32),
                                      np.asarray(l_p, np.float32))
        tok = jnp.argmax(l_r, -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# fused kernel lane: near-exact, same argmax tokens
# ---------------------------------------------------------------------------

def test_fused_codr_matmul_lane_matches_reference(key):
    cfg, api, ref_params, cp, tokens = _setup("qwen2.5-3b", key,
                                              "codr_matmul")
    lr, _ = api.prefill(ref_params, {"tokens": tokens}, cfg)
    lp, _ = api.prefill(cp.params, {"tokens": tokens}, cfg)
    a = np.asarray(lr, np.float32)
    b = np.asarray(lp, np.float32)
    # the fused kernel accumulates in f32 where the reference dot runs
    # bf16 — differences are bounded by bf16 rounding of the same sums
    assert np.abs(a - b).max() <= 0.02 * max(np.abs(a).max(), 1.0)
    np.testing.assert_array_equal(a.argmax(-1), b.argmax(-1))

    cache = api.init_cache(cfg, B, S)
    cache_r = api.init_cache(cfg, B, S)
    step = jax.jit(lambda p, c, t, i: api.decode_step(p, c, t, i, cfg))
    tok = tokens[:, 0]
    for i in range(2):
        l_r, cache_r = step(ref_params, cache_r, tok, jnp.int32(i))
        l_p, cache = step(cp.params, cache, tok, jnp.int32(i))
        a = np.asarray(l_r, np.float32)
        b = np.asarray(l_p, np.float32)
        assert np.abs(a - b).max() <= 0.02 * max(np.abs(a).max(), 1.0)
        tok = jnp.argmax(l_r, -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# jit compatibility: packed leaves never retrace across decode steps
# ---------------------------------------------------------------------------

def test_no_retrace_across_decode_steps(key):
    cfg, api, _, cp, tokens = _setup("qwen2.5-3b", key, "codr_matmul")
    traces = [0]

    def f(p, c, t, i):
        traces[0] += 1
        return api.decode_step(p, c, t, i, cfg)

    step = jax.jit(f)
    cache = api.init_cache(cfg, B, S)
    tok = tokens[:, 0]
    for i in range(5):
        logits, cache = step(cp.params, cache, tok, jnp.int32(i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert traces[0] == 1, f"decode_step retraced: {traces[0]} traces"


# ---------------------------------------------------------------------------
# capability errors
# ---------------------------------------------------------------------------

def test_conv_leaf_capability_error(rng):
    # a ViT-style patch-projection conv leaf (OIHW, spatial trailing
    # dims): the linear-only codr_matmul backend must reject it at
    # compile time with its capability reason
    params = {"patch_proj": rng.normal(size=(64, 8, 3, 3)
                                       ).astype(np.float32)}
    with pytest.raises(ValueError, match="no 'conv' path"):
        codr.compile_params(params, backend="codr_matmul",
                            accounting=False)


def test_non_packed_backend_rejected(rng):
    params = {"q_proj": rng.normal(size=(64, 64)).astype(np.float32)}
    with pytest.raises(ValueError, match="packed-projection matmul"):
        codr.compile_params(params, backend="smm", accounting=False)


def test_no_packable_leaves_rejected(rng):
    params = {"embed": rng.normal(size=(128, 64)).astype(np.float32)}
    with pytest.raises(ValueError, match="no packable projection"):
        codr.compile_params(params, accounting=False)


# ---------------------------------------------------------------------------
# packed leaf mechanics
# ---------------------------------------------------------------------------

def test_pack_projection_roundtrip_bitwise(rng):
    from repro.core import ucr
    w = (rng.normal(size=(3, 48, 40)) * 0.1).astype(np.float32)
    pl = codr.pack_projection(w, n_unique=N_UNIQUE)
    q, scale = ucr.quantize_int8(w.reshape(-1, 40))
    ref = ucr.dequantize_int8(ucr.restrict_unique(q, N_UNIQUE),
                              scale).reshape(w.shape)
    np.testing.assert_array_equal(np.asarray(pl.dense()), ref)
    # N=40 pads to the next whole uint32 word and crops back
    assert pl.out_features == 40
    assert pl.weight.shape[1] % (32 // pl.weight.bits) == 0
    # lax.scan-style leading-axis slicing yields a valid per-matrix pack
    sliced = jax.tree_util.tree_map(lambda a: a[1], pl)
    assert isinstance(sliced, codr.PackedLinear)
    np.testing.assert_array_equal(np.asarray(sliced.dense()), ref[1])


def test_dense_weight_passthrough(rng):
    w = rng.normal(size=(8, 8)).astype(np.float32)
    assert codr.dense_weight(w) is w
    assert codr.dense_weight(w, jnp.bfloat16).dtype == jnp.bfloat16


def test_compiled_params_accounting(key):
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    api = get_model(cfg)
    params = api.init_params(key, cfg)
    cp = codr.compile_params(params, codr.EncodeConfig(n_unique=N_UNIQUE))
    # measured bytes: packed indices beat bf16, report carries pack_bits
    assert 0 < cp.hbm_bytes() < cp.dense_bf16_bytes()
    assert cp.bits_per_weight() < 16
    assert cp.reports and all(r.pack_bits > 0 for r in cp.reports)
    assert "measured" in cp.summary()
    # embeddings ride their own packed-gather lane — not in packed_paths
    # (those are projections), and no longer served dense
    assert all("embed" not in p for p in cp.packed_paths)
    assert cp.embed_paths == ["embed"]
    assert all("embed" not in p for p in cp.quantized_paths)
    # the escape hatch keeps the old dense-quantized route
    cp_dense = codr.compile_params(params,
                                   codr.EncodeConfig(n_unique=N_UNIQUE),
                                   pack_embeddings=False)
    assert cp_dense.embed_paths == []
    assert any("embed" in p for p in cp_dense.quantized_paths)
