"""export-surface: ``__all__`` matches and re-exports resolve.

The package facades (``repro.api``, the subpackage ``__init__.py``
files) promise a surface; nothing verified it.  Two rules:

* every name in a module's ``__all__`` must be bound in that module
  (defined, assigned, or imported) — a stale ``__all__`` entry breaks
  ``from repro.x import *`` and lies to readers;
* every ``from repro.x import y`` (absolute, first-party) must name a
  ``y`` actually bound at the top level of ``repro/x`` — resolved
  against the linted source tree, so a renamed symbol fails the lint
  before it fails at import time in some lazy path.

Third-party and relative imports are skipped (no source to resolve
against); ``import repro.x`` module imports are checked only for the
module file existing.
"""
from __future__ import annotations

import ast
import pathlib

from tools.codrlint.core import (Checker, Finding, ModuleInfo, Project,
                                 literal_or_none, register_checker,
                                 top_level_bindings)

FIRST_PARTY_ROOTS = ("repro", "tools")


def _module_file(dotted: str, search_roots) -> pathlib.Path | None:
    """Resolve a dotted module to its file, or to the package directory
    itself for namespace packages (``src/repro`` has no ``__init__.py``)."""
    rel = dotted.replace(".", "/")
    for root in search_roots:
        for cand in (root / f"{rel}.py", root / rel / "__init__.py"):
            if cand.exists():
                return cand
        if (root / rel).is_dir():
            return root / rel                  # namespace package
    return None


class ExportSurfaceChecker(Checker):
    name = "export-surface"
    description = ("__all__ names are bound; 'from repro.x import y' "
                   "re-exports resolve against the source tree")

    def check_module(self, mod: ModuleInfo, project: Project):
        findings: list[Finding] = []
        bound = top_level_bindings(mod.tree)
        # rule 1: __all__ entries all bound
        for node in mod.tree.body:
            if (isinstance(node, ast.Assign)
                    and any(isinstance(t, ast.Name) and t.id == "__all__"
                            for t in node.targets)):
                names = literal_or_none(node.value)
                if not isinstance(names, (list, tuple)):
                    findings.append(Finding(
                        "export-surface", mod.rel, node.lineno,
                        "__all__:literal",
                        "__all__ must be a literal list/tuple of names"))
                    continue
                for n in names:
                    if n not in bound:
                        findings.append(Finding(
                            "export-surface", mod.rel, node.lineno,
                            f"__all__:{n}",
                            f"__all__ lists {n!r} but the module never "
                            f"binds it — stale export"))
        # rule 2: first-party from-imports resolve
        root = mod.path
        for _ in mod.rel.split("/"):
            root = root.parent                   # repo root
        search_roots = (root / "src", root)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ImportFrom) or node.level:
                continue
            if not node.module:
                continue
            top = node.module.split(".")[0]
            if top not in FIRST_PARTY_ROOTS:
                continue
            target = _module_file(node.module, search_roots)
            if target is None:
                findings.append(Finding(
                    "export-surface", mod.rel, node.lineno,
                    f"import:{node.module}",
                    f"first-party module {node.module!r} not found in "
                    f"the source tree"))
                continue
            if target.is_dir():
                # namespace package: only submodules are importable from it
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    if not _module_file(f"{node.module}.{alias.name}",
                                        search_roots):
                        findings.append(Finding(
                            "export-surface", mod.rel, node.lineno,
                            f"import:{node.module}.{alias.name}",
                            f"'from {node.module} import {alias.name}' — "
                            f"no such submodule under the namespace "
                            f"package {node.module!r}"))
                continue
            try:
                t_bound = top_level_bindings(
                    ast.parse(target.read_text(encoding="utf-8",
                                               errors="replace")))
            except SyntaxError:
                continue                   # its own parse finding covers it
            is_pkg_init = target.name == "__init__.py"
            for alias in node.names:
                if alias.name == "*":
                    continue
                if alias.name in t_bound:
                    continue
                if is_pkg_init and _module_file(
                        f"{node.module}.{alias.name}", search_roots):
                    continue               # importing a submodule
                findings.append(Finding(
                    "export-surface", mod.rel, node.lineno,
                    f"import:{node.module}.{alias.name}",
                    f"'from {node.module} import {alias.name}' — "
                    f"{alias.name!r} is not bound at the top level of "
                    f"{node.module} (renamed or removed?)"))
        return findings


register_checker(ExportSurfaceChecker())
