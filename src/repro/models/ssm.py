"""State-space / recurrent mixers: Mamba (Jamba's SSM layers) and the
xLSTM sLSTM/mLSTM blocks.

Mamba's selective scan is implemented chunkwise: an outer ``lax.scan``
over sequence chunks carries the (B, d_inner, N) state; within a chunk a
``lax.associative_scan`` gives log-depth parallelism without ever
materializing the full (B, S, d_inner, N) decay tensor (only one chunk is
live).  sLSTM/mLSTM use stabilized exponential gating per the xLSTM paper
and scan sequentially (their recurrent matrix / matrix memory is the
non-parallelizable part; chunkwise-parallel mLSTM is a §Perf candidate).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, dense_weight, linear

# ---------------------------------------------------------------------------
# Mamba (S6) block
# ---------------------------------------------------------------------------

def mamba_init(key, cfg) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_d_state
    dt_rank = max(1, math.ceil(d / 16))
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_d_conv, d_in),
                                    jnp.float32) * 0.1,
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "x_proj": dense_init(ks[2], d_in, dt_rank + 2 * n),
        "dt_proj": dense_init(ks[3], dt_rank, d_in),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (d_in, n))),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], d_in, d),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq: x (B,S,C), w (K,C)."""
    k = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _ssm_scan_chunked(a: jax.Array, b: jax.Array, h0: jax.Array,
                      chunk: int) -> jax.Array:
    """h_t = a_t ⊙ h_{t-1} + b_t over axis 1; a/b (B,S,d,N), h0 (B,d,N).
    Returns all h_t (B,S,d,N)."""
    bsz, s, d, n = a.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    ac = a.reshape(bsz, s // chunk, chunk, d, n).transpose(1, 0, 2, 3, 4)
    bc = b.reshape(bsz, s // chunk, chunk, d, n).transpose(1, 0, 2, 3, 4)

    def combine(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    def step(h, ab):
        aa, bb = ab                                    # (B, chunk, d, N)
        pa, pb = jax.lax.associative_scan(combine, (aa, bb), axis=1)
        hs = pa * h[:, None] + pb
        return hs[:, -1], hs

    _, hs = jax.lax.scan(step, h0, (ac, bc))
    return hs.transpose(1, 0, 2, 3, 4).reshape(bsz, s, d, n)


def mamba_forward(p, x, cfg, *, chunk: int = 256):
    """x (B,S,d) → (y (B,S,d), state (conv_tail, h_last))."""
    bsz, s, d = x.shape
    n = cfg.ssm_d_state
    d_in = cfg.ssm_expand * d
    dt_rank = max(1, math.ceil(d / 16))
    xz = linear(x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi_conv = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
    xdb = linear(xi_conv, p["x_proj"])
    dt = jax.nn.softplus(
        linear(xdb[..., :dt_rank], p["dt_proj"]) + p["dt_bias"].astype(x.dtype))
    bmat = xdb[..., dt_rank : dt_rank + n].astype(jnp.float32)
    cmat = xdb[..., dt_rank + n :].astype(jnp.float32)
    a_cont = -jnp.exp(p["A_log"])                          # (d_in, N)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf[..., None] * a_cont[None, None])   # (B,S,d_in,N)
    drive = (dtf * xi_conv.astype(jnp.float32))[..., None] * bmat[:, :, None, :]
    h0 = jnp.zeros((bsz, d_in, n), jnp.float32)
    hs = _ssm_scan_chunked(decay, drive, h0, chunk)
    y = jnp.einsum("bsdn,bsn->bsd", hs, cmat)
    y = (y + p["D"].astype(jnp.float32) * xi_conv.astype(jnp.float32))
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = linear(y, p["out_proj"])
    conv_tail = xi[:, -(cfg.ssm_d_conv - 1):]              # raw pre-conv tail
    return out, (conv_tail, hs[:, -1])


def mamba_decode(p, x, cfg, state):
    """Single-token step. state = (conv_tail (B,K-1,d_in), h (B,d_in,N))."""
    conv_tail, h = state
    bsz, _, d = x.shape
    n = cfg.ssm_d_state
    dt_rank = max(1, math.ceil(d / 16))
    xz = linear(x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)                      # (B,1,d_in)
    window = jnp.concatenate([conv_tail.astype(xi.dtype), xi], axis=1)
    conv = (window * p["conv_w"].astype(xi.dtype)).sum(axis=1, keepdims=True) \
        + p["conv_b"].astype(xi.dtype)
    xi_conv = jax.nn.silu(conv)
    xdb = linear(xi_conv, p["x_proj"])
    dt = jax.nn.softplus(
        linear(xdb[..., :dt_rank], p["dt_proj"]) + p["dt_bias"].astype(x.dtype))
    bmat = xdb[..., dt_rank : dt_rank + n].astype(jnp.float32)
    cmat = xdb[..., dt_rank + n :].astype(jnp.float32)
    a_cont = -jnp.exp(p["A_log"])
    dtf = dt[:, 0].astype(jnp.float32)                     # (B,d_in)
    decay = jnp.exp(dtf[..., None] * a_cont[None])
    drive = (dtf * xi_conv[:, 0].astype(jnp.float32))[..., None] \
        * bmat[:, 0, None, :]
    h = decay * h + drive
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])[:, None]
    y = y + p["D"].astype(jnp.float32) * xi_conv.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = linear(y, p["out_proj"])
    return out, (window[:, 1:], h)


def mamba_state_init(cfg, batch: int, dtype=jnp.bfloat16):
    d_in = cfg.ssm_expand * cfg.d_model
    return (jnp.zeros((batch, cfg.ssm_d_conv - 1, d_in), dtype),
            jnp.zeros((batch, d_in, cfg.ssm_d_state), jnp.float32))


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory, recurrent mix)
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    d_up = 2 * d
    dk = d_up // h
    ks = jax.random.split(key, 7)
    return {
        "up_proj": dense_init(ks[0], d, 2 * d_up),
        "q_proj": dense_init(ks[1], d_up, d_up),
        "k_proj": dense_init(ks[2], d_up, d_up),
        "v_proj": dense_init(ks[3], d_up, d_up),
        "if_proj": dense_init(ks[4], d_up, 2 * h, scale=0.02),
        "if_bias": jnp.concatenate([jnp.zeros((h,)), jnp.ones((h,)) * 3.0]
                                   ).astype(jnp.float32),
        "out_proj": dense_init(ks[5], d_up, d),
    }


def _mlstm_step(carry, qkvif):
    c, n, m = carry                        # C (B,H,dk,dv), n (B,H,dk), m (B,H)
    q, k, v, ig, fg = qkvif                # q/k (B,H,dk), v (B,H,dv)
    m_new = jnp.maximum(fg + m, ig)
    i_p = jnp.exp(ig - m_new)
    f_p = jnp.exp(fg + m - m_new)
    c = f_p[..., None, None] * c + i_p[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = f_p[..., None] * n + i_p[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    h_out = num / den[..., None]
    return (c, n, m_new), h_out


def mlstm_forward(p, x, cfg, state=None):
    """x (B,S,d) → (out, state). Sequential scan over S."""
    bsz, s, d = x.shape
    h = cfg.n_heads
    d_up = 2 * d
    dk = d_up // h
    up = linear(x, p["up_proj"])
    xin, z = jnp.split(up, 2, axis=-1)                    # (B,S,d_up)
    q = linear(xin, p["q_proj"]).reshape(bsz, s, h, dk) / math.sqrt(dk)
    k = linear(xin, p["k_proj"]).reshape(bsz, s, h, dk)
    v = linear(xin, p["v_proj"]).reshape(bsz, s, h, dk)
    ifg = linear(xin, p["if_proj"]).astype(jnp.float32) \
        + p["if_bias"].astype(jnp.float32)
    ig, fg = ifg[..., :h], jax.nn.log_sigmoid(ifg[..., h:])
    if state is None:
        state = mlstm_state_init(cfg, bsz)
    qs = q.transpose(1, 0, 2, 3).astype(jnp.float32)
    ks_ = k.transpose(1, 0, 2, 3).astype(jnp.float32)
    vs = v.transpose(1, 0, 2, 3).astype(jnp.float32)
    igs, fgs = ig.transpose(1, 0, 2), fg.transpose(1, 0, 2)
    state, hs = jax.lax.scan(_mlstm_step, state, (qs, ks_, vs, igs, fgs))
    hs = hs.transpose(1, 0, 2, 3).reshape(bsz, s, d_up).astype(x.dtype)
    hs = hs * jax.nn.silu(z)
    return linear(hs, p["out_proj"]), state


def mlstm_state_init(cfg, batch: int):
    h = cfg.n_heads
    dk = 2 * cfg.d_model // h
    return (jnp.zeros((batch, h, dk, dk), jnp.float32),
            jnp.zeros((batch, h, dk), jnp.float32),
            jnp.full((batch, h), -1e30, jnp.float32))


def slstm_init(key, cfg) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    return {
        "w_proj": dense_init(ks[0], d, 4 * d),
        "r_proj": jax.random.normal(ks[1], (h, dh, 4 * dh), jnp.float32)
        / math.sqrt(dh),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "out_proj": dense_init(ks[2], d, d),
    }


def _slstm_step(p, cfg, carry, wx_t):
    c, n, hprev, m = carry                   # each (B, d) / m (B, H)
    bsz, d = c.shape
    h = cfg.n_heads
    dh = d // h
    hh = hprev.reshape(bsz, h, dh)
    rec = jnp.einsum("bhd,hde->bhe", hh, p["r_proj"]).reshape(bsz, 4 * d)
    raw = (wx_t + rec).astype(jnp.float32)
    zt, it, ft, ot = jnp.split(raw, 4, axis=-1)
    ith = it.reshape(bsz, h, dh)
    fth = jax.nn.log_sigmoid(ft).reshape(bsz, h, dh)
    m_new = jnp.maximum(fth.mean(-1) + m, ith.mean(-1))      # per-head stabilizer
    i_p = jnp.exp(ith - m_new[..., None]).reshape(bsz, d)
    f_p = jnp.exp(fth + (m - m_new)[..., None]).reshape(bsz, d)
    c_new = f_p * c + i_p * jnp.tanh(zt)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(p, x, cfg, state=None):
    bsz, s, d = x.shape
    # the recurrent mix consumes r_proj via einsum inside the scan step —
    # decode a packed leaf once per forward, not once per timestep
    p = {**p, "r_proj": dense_weight(p["r_proj"])}
    wx = linear(x, p["w_proj"]) + p["bias"].astype(x.dtype)
    if state is None:
        state = slstm_state_init(cfg, bsz)

    def step(carry, wx_t):
        return _slstm_step(p, cfg, carry, wx_t)

    state, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2).astype(x.dtype)
    return linear(hs, p["out_proj"]), state


def slstm_state_init(cfg, batch: int):
    d = cfg.d_model
    return (jnp.zeros((batch, d), jnp.float32),
            jnp.zeros((batch, d), jnp.float32),
            jnp.zeros((batch, d), jnp.float32),
            jnp.full((batch, cfg.n_heads), -1e30, jnp.float32))
