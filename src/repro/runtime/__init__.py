from repro.runtime.loop import TrainLoop, TrainLoopConfig
from repro.runtime.straggler import StragglerConfig, StragglerMonitor
from repro.runtime.elastic import ElasticMeshManager, HostSet
from repro.runtime.resilience import (
    DeadlineExceeded, Fault, FaultInjector, FaultPlan, QuarantinedError,
    RejectedError, RestartPolicy, RetryPolicy, ServingSupervisor,
    WorkerCrashed, retry_call,
)

__all__ = ["TrainLoop", "TrainLoopConfig", "StragglerConfig",
           "StragglerMonitor", "ElasticMeshManager", "HostSet",
           "Fault", "FaultPlan", "FaultInjector", "RetryPolicy",
           "RestartPolicy", "ServingSupervisor", "retry_call",
           "DeadlineExceeded", "RejectedError", "QuarantinedError",
           "WorkerCrashed"]
