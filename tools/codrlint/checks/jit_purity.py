"""jit-purity: no host synchronization inside traced function bodies.

A function is *traced* when it is passed to ``jax.jit`` / ``shard_map``
/ ``jax.lax.scan`` / ``pl.pallas_call`` (directly, as a lambda, or as a
local ``def`` resolved by name within the file) or decorated with
``@jax.jit`` / ``@partial(jax.jit, ...)``.  Inside such a body the
checker flags operations that force a host sync or leak tracers
(the latent bug class PR 3 fixed in ``engine._build_forward``):

* ``np.*`` / ``numpy.*`` calls — host NumPy materializes the tracer;
* ``.item()`` calls and ``float()`` / ``int()`` / ``bool()`` coercions;
* ``print(...)`` — a host sync per trace (use ``jax.debug.print``);
* ``time.*()`` calls — wall-clock reads burn into the trace;
* attribute mutation (``obj.attr = ...``) — a side effect the trace
  replays never, once, or per-retrace, all of them wrong.

Statements under ``with jax.ensure_compile_time_eval():`` are exempt —
that context is exactly the sanctioned host-compute escape hatch (the
PR 3 fix uses it).  The analysis is one level deep by design: only the
direct body of the traced function (including nested defs, which trace
when called) is checked, not the transitive call graph — a documented
soundness/noise trade-off (docs/DESIGN.md §7).
"""
from __future__ import annotations

import ast

from tools.codrlint.core import (Checker, Finding, ModuleInfo, Project,
                                 dotted_name, register_checker)

JIT_WRAPPERS = {"jax.jit", "jit", "pjit", "jax.pjit"}
SCAN_WRAPPERS = {"jax.lax.scan", "lax.scan"}
SHARD_WRAPPERS = {"shard_map", "_shard_map", "jax.shard_map",
                  "jax.experimental.shard_map.shard_map"}
PALLAS_WRAPPERS = {"pl.pallas_call", "pallas_call",
                   "jax.experimental.pallas.pallas_call"}
HOST_MODULES = {"np", "numpy"}
TIME_MODULES = {"time"}
COERCIONS = {"float", "int", "bool"}
ESCAPE_CTX = "ensure_compile_time_eval"


def _is_jit_callable(node: ast.AST) -> str | None:
    """Is ``node`` (the func of a Call) a tracing wrapper?  Returns the
    wrapper family name or None."""
    name = dotted_name(node)
    if name in JIT_WRAPPERS:
        return "jax.jit"
    if name in SCAN_WRAPPERS:
        return "lax.scan"
    if name in SHARD_WRAPPERS:
        return "shard_map"
    if name in PALLAS_WRAPPERS:
        return "pallas_call"
    return None


def _jit_decorator(dec: ast.AST) -> bool:
    if dotted_name(dec) in JIT_WRAPPERS:
        return True
    if isinstance(dec, ast.Call):
        fname = dotted_name(dec.func)
        if fname in JIT_WRAPPERS:                      # @jax.jit(static...)
            return True
        if fname in {"partial", "functools.partial"} and dec.args:
            return dotted_name(dec.args[0]) in JIT_WRAPPERS
    return False


class _BodyScanner(ast.NodeVisitor):
    """Walk a traced body; collect impurity findings.  Skips subtrees
    under ``with ...ensure_compile_time_eval():``."""

    def __init__(self, mod: ModuleInfo, owner: str):
        self.mod = mod
        self.owner = owner
        self.findings: list[Finding] = []

    def _flag(self, node: ast.AST, what: str, detail: str) -> None:
        self.findings.append(Finding(
            "jit-purity", self.mod.rel, node.lineno,
            f"{self.owner}:{what}",
            f"{detail} inside traced function {self.owner!r} — host "
            f"sync / trace side effect (wrap in "
            f"jax.ensure_compile_time_eval() if this is deliberate "
            f"trace-time compute)"))

    def visit_With(self, node: ast.With) -> None:
        for item in node.items:
            expr = item.context_expr
            callee = expr.func if isinstance(expr, ast.Call) else expr
            if dotted_name(callee).split(".")[-1] == ESCAPE_CTX:
                return                       # exempt whole block
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        name = dotted_name(func)
        root = name.split(".")[0] if name else ""
        if root in HOST_MODULES:
            self._flag(node, name, f"host NumPy call {name}()")
        elif root in TIME_MODULES:
            self._flag(node, name, f"wall-clock call {name}()")
        elif isinstance(func, ast.Attribute) and func.attr == "item":
            self._flag(node, "item", "device-sync .item() call")
        elif isinstance(func, ast.Name) and func.id in COERCIONS:
            self._flag(node, func.id,
                       f"host coercion {func.id}() on a traced value")
        elif isinstance(func, ast.Name) and func.id == "print":
            self._flag(node, "print",
                       "print() traces as a host sync (jax.debug.print)")
        self.generic_visit(node)

    def _check_mutation(self, targets, node) -> None:
        for t in targets:
            if isinstance(t, ast.Attribute):
                self._flag(node, f"set:{t.attr}",
                           f"attribute mutation .{t.attr} = ...")

    def visit_Assign(self, node: ast.Assign) -> None:
        self._check_mutation(node.targets, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_mutation([node.target], node)
        self.generic_visit(node)


def _scan_body(mod: ModuleInfo, fn: ast.AST, owner: str) -> list[Finding]:
    sc = _BodyScanner(mod, owner)
    if isinstance(fn, ast.Lambda):
        sc.visit(fn.body)
    else:
        for stmt in fn.body:
            sc.visit(stmt)
    return sc.findings


class JitPurityChecker(Checker):
    name = "jit-purity"
    description = ("no host sync (np.*, .item(), float()/int(), print, "
                   "attribute mutation) inside jit/scan/shard_map/pallas "
                   "bodies")

    def check_module(self, mod: ModuleInfo, project: Project):
        findings: list[Finding] = []
        # index every def in the file by name for by-name resolution
        defs: dict[str, list[ast.FunctionDef]] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(node)

        seen: set[int] = set()          # id(fn-node) → scan once

        def scan(fn: ast.AST, owner: str) -> None:
            if id(fn) in seen:
                return
            seen.add(id(fn))
            findings.extend(_scan_body(mod, fn, owner))

        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(_jit_decorator(d) for d in node.decorator_list):
                    scan(node, node.name)
            elif isinstance(node, ast.Call):
                family = _is_jit_callable(node.func)
                if family is None or not node.args:
                    continue
                target = node.args[0]
                if isinstance(target, ast.Lambda):
                    scan(target, f"<lambda@{family}:{target.lineno}>")
                elif isinstance(target, ast.Name):
                    for fn in defs.get(target.id, ()):
                        scan(fn, fn.name)
        return findings


register_checker(JitPurityChecker())
