"""Public CoDR engine API — spec → compile → serve.

    import repro.api as codr

    spec = codr.ModelSpec.from_params(params)      # any conv/dense pytree
    compiled = codr.compile(spec, codr.EncodeConfig(n_unique=16))
    y = compiled.run(x)                            # from the RLE bitstreams
    server = compiled.serve(max_batch=8)

Transformer params pytrees (``repro.models``) compile *in place*: every
projection leaf becomes a packed bitstream the model executes through
the backend registry (``launch/serve.py --codr`` rides this)::

    cp = codr.compile_params(params, codr.EncodeConfig(n_unique=16),
                             backend="codr_matmul")
    logits, cache = api.prefill(cp.params, batch, cfg)   # decode-fused

Compile once, then persist the packed artifact and boot servers from
it without re-encoding (``launch/serve.py --packed-ckpt``)::

    codr.save_packed(cp, "ckpt/qwen.codr")        # bitstreams + manifest
    cp = codr.load_packed("ckpt/qwen.codr")       # mmap'd, bit-identical

Everything here re-exports from :mod:`repro.core.api` (the pipeline),
:mod:`repro.core.backends` (the pluggable execution backends), and
:mod:`repro.checkpoint.packed` (the packed artifact).
"""
from repro.checkpoint.packed import (CODR_FORMAT_VERSION,  # noqa: F401
                                     PackedCheckpointError, load_packed,
                                     save_packed)
from repro.core.api import (CompiledModel, CompiledParams,  # noqa: F401
                            EncodeConfig, LayerSpec, ModelSpec, compile,
                            compile_params)
from repro.core.backends import (Backend, BackendCaps,  # noqa: F401
                                 available_backends, get_backend, register)
from repro.core.codr_linear import (PackedEmbedding,  # noqa: F401
                                    PackedLinear, PackedWeight, dense_weight,
                                    pack_embedding, pack_projection)

__all__ = [
    "LayerSpec", "ModelSpec", "EncodeConfig", "CompiledModel", "compile",
    "CompiledParams", "compile_params", "PackedLinear", "PackedWeight",
    "PackedEmbedding", "dense_weight", "pack_projection", "pack_embedding",
    "Backend", "BackendCaps", "available_backends", "get_backend",
    "register",
    "CODR_FORMAT_VERSION", "PackedCheckpointError", "save_packed",
    "load_packed",
]
