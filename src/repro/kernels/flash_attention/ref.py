"""Pure-jnp oracle: naive softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    b, s, hq, d = q.shape
    _, sk, hkv, dv = v.shape
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((s, sk), bool))
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, hq, dv).astype(q.dtype)
