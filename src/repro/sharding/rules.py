"""Sharding rules: one place that knows the mesh axes.

Axes (transformer serving stack, ``docs/DESIGN.md`` §5):
  * ``pod``   — outer pure-DP axis (multi-pod); gradients cross DCI once.
  * ``data``  — FSDP axis: batch + parameter/optimizer-state sharding.
  * ``model`` — TP axis: attention heads / FFN hidden / MoE experts / vocab.

Models are mesh-agnostic: layers call :func:`maybe_constrain` with logical
specs; outside a mesh context it is the identity, so the same code runs in
single-device smoke tests and under the 512-chip production mesh.

The CoDR engine adds one more axis: ``tile`` — the output-tile axis the
``sharded`` backend (:mod:`repro.core.backends`) partitions each layer's
decoded tile stack over.  :func:`tile_mesh` builds the 1-D mesh and
:func:`shard_leading` pads + ``device_put``\\ s a host array across it;
both degrade gracefully to a single device, so the same backend code
runs in 1-device CI and on a forced-multi-device host platform
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``) alike.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

# the CoDR engine's output-tile model-parallel axis (sharded backend)
ENGINE_TILE_AXIS = "tile"


def tile_mesh(devices=None, *, axis: str = ENGINE_TILE_AXIS) -> Mesh:
    """1-D mesh over ``devices`` (default: all local devices) named with
    the engine's output-tile axis.  With one device this is a valid
    1-element mesh — ``shard_map`` over it is the single-device fallback,
    no special-casing in the caller."""
    devs = jax.devices() if devices is None else list(devices)
    return Mesh(np.asarray(devs), (axis,))


def pad_to_multiple(n: int, k: int) -> int:
    """Smallest multiple of ``k`` that is >= ``n`` (>= k for n == 0)."""
    return max(-(-n // k), 1) * k


def shard_leading(x, mesh: Mesh, *, axis: str = ENGINE_TILE_AXIS):
    """``device_put`` a host array sharded over its leading dimension.

    The leading dim is zero-padded up to a multiple of the mesh axis size
    first (a ragged tile stack still shards; the pad rows compute zeros
    the caller crops away), so any ``n >= 1`` works on any device count.
    Returns the committed, sharded ``jax.Array``.
    """
    x = np.asarray(x)
    d = mesh.shape[axis]
    pad = pad_to_multiple(x.shape[0], d) - x.shape[0]
    if pad:
        x = np.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    spec = P(axis, *([None] * (x.ndim - 1)))
    return jax.device_put(x, NamedSharding(mesh, spec))


@dataclasses.dataclass
class ShardCtx:
    mesh: Mesh
    batch_axes: tuple[str, ...] = ("pod", "data")   # axes present → used
    fsdp_axis: str = "data"
    model_axis: str = "model"

    @property
    def batch_spec(self):
        axes = tuple(a for a in self.batch_axes if a in self.mesh.axis_names)
        return axes if len(axes) > 1 else (axes[0] if axes else None)

    def axis_size(self, name: str) -> int:
        if name in self.mesh.axis_names:
            return self.mesh.shape[name]
        return 1


def set_ctx(ctx: ShardCtx | None) -> None:
    _STATE.ctx = ctx


def current_ctx() -> ShardCtx | None:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use_ctx(ctx: ShardCtx | None):
    prev = current_ctx()
    set_ctx(ctx)
    try:
        yield ctx
    finally:
        set_ctx(prev)


def maybe_constrain(x: jax.Array, *spec) -> jax.Array:
    """``with_sharding_constraint`` if a mesh context is active, else id.

    ``spec`` uses logical names: 'batch' → the batch axes, 'model'/'data'
    → those mesh axes, None → replicated.
    """
    ctx = current_ctx()
    if ctx is None:
        return x
    resolved = []
    for s in spec:
        if s == "batch":
            resolved.append(ctx.batch_spec)
        elif s in (None,):
            resolved.append(None)
        elif isinstance(s, str) and s in ctx.mesh.axis_names:
            resolved.append(s)
        else:
            resolved.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*resolved)))


# ---------------------------------------------------------------------------
# parameter sharding rules (path-pattern → PartitionSpec)
# ---------------------------------------------------------------------------

def param_spec(path: str, shape: tuple[int, ...], mesh: Mesh,
               *, fsdp: bool = True, moe2d: bool = False) -> P:
    """PartitionSpec for a parameter by its pytree path.

    Conventions (docs/DESIGN.md §5): 2-D weights ``(d_in, d_out)`` are
    column-parallel (out over ``model``) when they *enter* a parallel
    region (qkv/up/gate), row-parallel (in over ``model``) when they
    *leave* one (o_proj/down).  FSDP shards the complementary dimension
    over ``data``.  Stacked-layer leading axes (scan) are never sharded.
    MoE expert stacks shard experts over ``model``.  Embeddings shard
    vocab over ``model``.  Any dim not divisible by its axis is left
    unsharded (GSPMD padding is wasteful at these sizes — be explicit).
    """
    dsize = mesh.shape.get("data", 1)
    msize = mesh.shape.get("model", 1)
    name = path.split("/")[-1]
    stacked = "stack" in path          # leading (n_periods, ...) axis

    def fits(dim: int, size: int) -> bool:
        return size > 1 and dim % size == 0

    ndim = len(shape)
    spec: list = [None] * ndim
    base = 1 if stacked else 0         # skip the scan axis

    def setax(i: int, axis: str, size: int):
        if 0 <= i < ndim and spec[i] is None and fits(shape[i], size):
            spec[i] = axis

    if name in ("embed", "out_embed", "lm_head"):
        # (V, D): vocab over model, D over data (FSDP)
        setax(base, "model", msize)
        if fsdp:
            setax(base + 1, "data", dsize)
    elif name in ("w_experts_in", "w_experts_gate", "w_experts_out"):
        # (E, d_in, d_out): experts over model; FSDP over data on d_in.
        # moe2d (decode serving): shard the expert-FFN hidden dim over
        # data instead, matching the _moe_2d shard_map in_specs so the
        # weights enter with zero resharding collectives.
        setax(base, "model", msize)
        if moe2d and name in ("w_experts_in", "w_experts_gate"):
            setax(base + 2, "data", dsize)
        elif fsdp or moe2d:
            setax(base + 1, "data", dsize)
    elif name.endswith(("q_proj", "k_proj", "v_proj", "up_proj", "gate_proj",
                        "in_proj", "qkv_proj", "kv_a_proj", "q_a_proj",
                        "q_b_proj", "kv_b_proj")):
        # column parallel (d_in, d_out): out over model
        setax(base + 1, "model", msize)
        if fsdp:
            setax(base, "data", dsize)
    elif name.endswith(("o_proj", "down_proj", "out_proj")):
        # row parallel: in over model
        setax(base, "model", msize)
        if fsdp:
            setax(base + 1, "data", dsize)
    elif ndim - base >= 2:
        # generic 2-D: FSDP over data on d_in
        if fsdp:
            setax(base, "data", dsize)
    else:
        # 1-D (norms, biases): replicate
        pass
    return P(*spec)


def named_sharding_tree(params, mesh: Mesh, paths_and_shapes=None,
                        *, fsdp: bool = True, moe2d: bool = False):
    """Map a params pytree (or eval_shape result) to NamedShardings."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append(NamedSharding(mesh, param_spec(pstr, leaf.shape, mesh,
                                                  fsdp=fsdp, moe2d=moe2d)))
    return jax.tree_util.tree_unflatten(treedef, out)
