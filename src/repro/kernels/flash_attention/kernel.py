"""Pallas TPU kernel: fused flash attention.

This is the production fix for the dominant memory term the roofline
report surfaces (``benchmarks/roofline.py``; methodology in
``docs/DESIGN.md`` §6): the XLA-compiled attention materializes every
(q_block × kv_block) score tile in HBM (B·H·S² traffic); the fused
kernel keeps score tiles, the online-softmax stats, and the output
accumulator **in VMEM** — HBM traffic collapses to q/k/v reads + o
writes (the theoretical floor).

Grid ``(B·H, n_q, n_k)`` with the kv loop innermost: the (bq, D)
accumulator and (bq,) running max/denominator live in VMEM scratch
across the kv sweep (output-stationary, same loop discipline as the
CoDR matmul kernel).  Causal masking by absolute block positions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, bq: int, bk: int, n_k: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # (bq, D)
    k = k_ref[0].astype(jnp.float32)              # (bk, D)
    v = v_ref[0].astype(jnp.float32)              # (bk, Dv)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[0, ...] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)[:, None]
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "scale",
                                    "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 512,
                           bk: int = 512, scale: float | None = None,
                           interpret: bool = False) -> jax.Array:
    """q/k/v: (BH, S, D) — batch·heads flattened (GQA grouping done by
    the ops wrapper)."""
    bh, sq, d = q.shape
    _, sk, dv = v.shape
    # snap block sizes to divisors of S (padding blocks would otherwise
    # inject garbage keys into the softmax)
    bq = min(bq, sq)
    while sq % bq:
        bq -= 1
    bk = min(bk, sk)
    while sk % bk:
        bk -= 1
    scale = scale if scale is not None else d ** -0.5
    grid = (bh, pl.cdiv(sq, bq), pl.cdiv(sk, bk))
    kernel = functools.partial(_flash_kernel, causal=causal, bq=bq, bk=bk,
                               n_k=grid[2], scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # denominator
            pltpu.VMEM((bq, dv), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
