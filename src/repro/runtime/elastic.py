"""Elastic mesh management: rebuild the device mesh after host loss (or
growth) and re-shard state from the latest checkpoint.

Policy: the mesh data axis must divide the global batch; on host loss we
pick the largest feasible (data, model) grid from the surviving chip
count, preferring to shrink ``data`` (keeps TP intact — model-axis
collectives are latency-critical) and re-spliting the per-host batch.
State flows through :class:`repro.checkpoint.CheckpointManager`:
host-side numpy leaves are re-placed against the *new* mesh's
NamedShardings (no resharding collectives needed — the filesystem is the
exchange medium, which is also the fault-tolerance path).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np


@dataclasses.dataclass
class HostSet:
    """Logical fleet state (control-plane view)."""
    n_hosts: int
    chips_per_host: int
    healthy: np.ndarray          # bool mask

    @property
    def healthy_chips(self) -> int:
        return int(self.healthy.sum()) * self.chips_per_host


def feasible_grid(chips: int, *, model_parallel: int,
                  global_batch: int) -> tuple[int, int]:
    """Largest (data, model) grid with data·model ≤ chips, model fixed,
    data dividing global_batch."""
    if model_parallel < 1:
        raise ValueError(f"model_parallel must be >= 1, got "
                         f"{model_parallel}")
    if chips < model_parallel:
        raise ValueError(
            f"no feasible grid: {chips} surviving chip(s) cannot host "
            f"even one model-parallel group of {model_parallel} (the "
            f"model axis is fixed; recover hosts or lower "
            f"model_parallel)")
    data = chips // model_parallel
    while data > 0 and global_batch % data:
        data -= 1
    if data == 0:
        raise ValueError(
            f"no feasible grid: chips={chips} model={model_parallel} "
            f"batch={global_batch} — no data-axis size ≤ "
            f"{chips // model_parallel} divides the global batch")
    return data, model_parallel


class ElasticMeshManager:
    def __init__(self, hosts: HostSet, *, model_parallel: int,
                 global_batch: int):
        self.hosts = hosts
        self.model_parallel = model_parallel
        self.global_batch = global_batch

    def mark_failed(self, host_id: int) -> None:
        self.hosts.healthy[host_id] = False

    def mark_recovered(self, host_id: int) -> None:
        self.hosts.healthy[host_id] = True

    def current_grid(self) -> tuple[int, int]:
        return feasible_grid(self.hosts.healthy_chips,
                             model_parallel=self.model_parallel,
                             global_batch=self.global_batch)

    def make_mesh(self, devices=None):
        data, model = self.current_grid()
        devices = devices if devices is not None else jax.devices()
        need = data * model
        if len(devices) < need:
            raise ValueError(f"need {need} devices, have {len(devices)}")
        arr = np.asarray(devices[:need]).reshape(data, model)
        return jax.sharding.Mesh(arr, ("data", "model"))

    def resume_plan(self, step: int) -> dict:
        """What the control plane executes after a failure."""
        data, model = self.current_grid()
        return {
            "restore_step": step,
            "mesh": (data, model),
            "per_host_batch": self.global_batch // max(data, 1),
            "actions": ["drain-collectives", "rebuild-mesh",
                        "restore-checkpoint", "resume"],
        }
