"""End-to-end training driver.

Two modes:
  * ``--smoke``  — reduced config of the chosen arch on the host devices
    (the quickstart path; runs real optimization steps on CPU).
  * cluster mode — production mesh + FSDP/TP shardings; on this CPU
    container use ``--dryrun`` to stop after lower+compile (the dry-run
    proper lives in ``repro.launch.dryrun``).

Fault tolerance: the loop checkpoints every N steps (atomic, async) and
``--resume`` restores the latest checkpoint including the data cursor, so
a killed run continues bit-exactly.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.data import DataConfig, host_batch_iterator
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.runtime import TrainLoop, TrainLoopConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a failure at this step (FT demo)")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch,
                      frontend=cfg.frontend
                      or ("audio" if cfg.family == "encdec" else None),
                      frontend_seq=cfg.frontend_seq or args.seq,
                      d_model=cfg.d_model)
    loop = TrainLoop(
        train_loss_fn=lambda p, b: api.train_loss(p, b, cfg),
        params=params,
        batch_iter=host_batch_iterator(dcfg),
        opt_cfg=AdamWConfig(lr=args.lr, use_master=False),
        loop_cfg=TrainLoopConfig(total_steps=args.steps,
                                 checkpoint_every=max(args.steps // 4, 1),
                                 ckpt_dir=args.ckpt_dir,
                                 peak_lr=args.lr,
                                 fail_at_step=args.fail_at))
    if args.resume:
        start = loop.try_restore()
        print(f"resumed from step {start}")
    hist = loop.run()
    first = np.mean([h["loss"] for h in hist[:10]])
    last = np.mean([h["loss"] for h in hist[-10:]])
    print(f"steps={len(hist)} loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
