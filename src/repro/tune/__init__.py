"""``repro.tune`` — per-layer encoding autotuner + quality eval harness.

The paper's §III-C search as a first-class offline subsystem: score
every (U budget × tile geometry × RLE params) candidate per layer with
the cost model, select under a budget, emit a serializable
:class:`TunePlan`, and compile with it::

    from repro import tune
    import repro.api as codr

    plan = tune.tune_spec(spec, input_hw=(20, 20),
                          budget=tune.TuneBudget(max_rel_err=0.04))
    compiled = codr.compile(spec, plan=plan)
    print(compiled.layer_table((20, 20)))     # predicted vs measured

Quality numbers come from :mod:`repro.tune.eval`; the CLI entry point is
``python -m repro.launch.tune`` (``--small --check`` in CI asserts the
tuned plan beats the best global config).  Design notes:
docs/DESIGN.md §2.1.
"""
from repro.tune.autotune import (Candidate, TuneGrid,  # noqa: F401
                                 best_global_config, cache_stats,
                                 clear_cache, layer_candidate_table,
                                 select_plan, tune_params, tune_spec)
from repro.tune.eval import (cnn_quality, eval_batch,  # noqa: F401
                             pareto_curve, transformer_quality)
from repro.tune.plan import (LayerPlan, TuneBudget,  # noqa: F401
                             TunePlan, layer_fingerprint)

__all__ = [
    "TuneBudget", "TuneGrid", "TunePlan", "LayerPlan", "Candidate",
    "tune_spec", "tune_params", "select_plan", "best_global_config",
    "layer_candidate_table", "layer_fingerprint",
    "cache_stats", "clear_cache",
    "cnn_quality", "eval_batch", "pareto_curve", "transformer_quality",
]
