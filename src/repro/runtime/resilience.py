"""Serving resilience: deterministic fault injection, request-level
robustness policies, and supervised graceful degradation.

The serving plane (``docs/DESIGN.md`` §3) assumed a healthy world: a
fixed device mesh, a worker loop that never dies, requests that always
finish.  This module is the layer that removes those assumptions
(``docs/DESIGN.md`` §3.5):

* **Fault injection** — :class:`FaultPlan` / :class:`FaultInjector`: a
  seeded, fully deterministic schedule of faults fired at named *sites*
  inside the serving stack (dispatch exceptions, artificial latency,
  simulated device loss, worker-thread crashes).  Every hooked object
  holds ``self._injector = None`` by default and guards the site with a
  single ``is None`` check, so the disabled path adds one attribute
  load per dispatch — a run without an injector is byte-identical to a
  build without this module.
* **Request robustness** — :class:`RetryPolicy` (bounded exponential
  backoff + deterministic jitter for *transient* dispatch failures,
  :func:`retry_call`), poison quarantine after the retry budget
  (:class:`QuarantinedError` — the request is consumed and recorded,
  never requeued), per-request deadlines (:class:`DeadlineExceeded`),
  and bounded admission with explicit load shedding
  (:class:`RejectedError`, carrying a ``retry_after_s`` hint).
* **Supervision** — :class:`RestartPolicy` (worker crash → backoff →
  restart with pending work preserved, executed by
  ``serving.AsyncWorkerLoop``) and :class:`ServingSupervisor`, which
  feeds serving latencies into the :class:`~repro.runtime.straggler
  .StragglerMonitor` and, on sustained degradation or device loss,
  walks the :class:`~repro.runtime.elastic.ElasticMeshManager` ladder:
  shrink the ``sharded`` backend's tile mesh to the surviving feasible
  grid (re-registered, re-jitted on next dispatch), and finally fall
  back to the single-device ``tiled`` lane — whose outputs are
  bit-for-bit identical (DESIGN §3.3), so degradation is invisible in
  the results.

Injection sites (string constants below; ``FaultPlan.seeded`` restricts
kinds per site so a plan is always executable):

=======================  ====================================================
site                     where it fires
=======================  ====================================================
``server.worker``        top of each ``CodrBatchServer`` flush-loop iteration
``server.dispatch``      before each batch dispatch (sync flush AND async)
``batcher.worker``       top of each ``ContinuousBatcher`` loop iteration
``batcher.prefill``      before each admission prefill
``batcher.decode``       before each pooled decode step
``sharded.dispatch``     inside ``ShardedBackend.run_model``
=======================  ====================================================

Crash faults (:class:`InjectedCrash`) derive from ``BaseException`` so
they sail through the per-batch ``except Exception`` isolation handlers
and kill the worker thread wherever they fire — exactly what a real
thread death does.  Everything else derives from ``Exception`` and is
subject to the normal isolation/retry machinery.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro.runtime.elastic import ElasticMeshManager, HostSet
from repro.runtime.straggler import StragglerConfig, StragglerMonitor

__all__ = [
    "TransientDispatchError", "InjectedFault", "InjectedCrash",
    "DeviceLost", "WorkerCrashed", "DeadlineExceeded", "RejectedError",
    "QuarantinedError", "Fault", "FaultPlan", "FaultInjector",
    "RetryPolicy", "RestartPolicy", "retry_call", "ServingSupervisor",
    "SITE_SERVER_WORKER", "SITE_SERVER_DISPATCH", "SITE_BATCHER_WORKER",
    "SITE_BATCHER_PREFILL", "SITE_BATCHER_DECODE", "SITE_SHARDED_DISPATCH",
]

SITE_SERVER_WORKER = "server.worker"
SITE_SERVER_DISPATCH = "server.dispatch"
SITE_BATCHER_WORKER = "batcher.worker"
SITE_BATCHER_PREFILL = "batcher.prefill"
SITE_BATCHER_DECODE = "batcher.decode"
SITE_SHARDED_DISPATCH = "sharded.dispatch"

ALL_SITES = (SITE_SERVER_WORKER, SITE_SERVER_DISPATCH, SITE_BATCHER_WORKER,
             SITE_BATCHER_PREFILL, SITE_BATCHER_DECODE,
             SITE_SHARDED_DISPATCH)


# ---------------------------------------------------------------------------
# fault taxonomy
# ---------------------------------------------------------------------------

class TransientDispatchError(RuntimeError):
    """A dispatch failure that is safe to retry: the work unit was not
    consumed and re-running it is side-effect free.  Real integrations
    raise (or subclass) this for e.g. a dropped RPC; :class:`RetryPolicy`
    treats it as retryable by default."""


class InjectedFault(TransientDispatchError):
    """A scheduled transient dispatch failure from a :class:`FaultPlan`."""


class InjectedCrash(BaseException):
    """A scheduled worker-thread crash.  Derives from ``BaseException``
    so the per-batch ``except Exception`` isolation does NOT contain it:
    it escapes the worker loop like a genuine thread death and lands in
    the ``AsyncWorkerLoop`` supervision path (restart or fail-live)."""


class DeviceLost(RuntimeError):
    """A device dropped out of the mesh (simulated by fault injection;
    a real deployment maps its runtime's device-failure error here).
    Not retryable in place — the :class:`ServingSupervisor` must first
    degrade to a mesh that excludes the lost device."""


class WorkerCrashed(RuntimeError):
    """Handed to every live future/handle when a serving worker thread
    died and the restart budget (if any) is exhausted — the guarantee
    that ``result()`` never hangs on a dead loop."""


class DeadlineExceeded(TimeoutError):
    """A request's deadline passed before it was dispatched (or, for a
    streaming generation, before it finished)."""


class RejectedError(RuntimeError):
    """Admission rejected: the bounded queue is full.  ``retry_after_s``
    is the server's hint for when capacity is likely to free up."""

    def __init__(self, msg: str, *, retry_after_s: float = 0.0):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


class QuarantinedError(RuntimeError):
    """A work unit failed transiently more times than the retry budget
    allows and is quarantined: consumed, recorded, never requeued (a
    poison request must not kill every subsequent batch).  ``attempts``
    counts executions including the first; the last failure is chained
    as ``__cause__``."""

    def __init__(self, msg: str, *, attempts: int):
        super().__init__(msg)
        self.attempts = attempts


# ---------------------------------------------------------------------------
# fault plans + injector
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault: at the ``at_call``-th firing (0-based) of
    ``site``, do ``kind`` — ``"error"`` (raise :class:`InjectedFault`),
    ``"latency"`` (sleep ``latency_s``), ``"device_loss"`` (raise
    :class:`DeviceLost`) or ``"crash"`` (raise :class:`InjectedCrash`).
    """

    site: str
    at_call: int
    kind: str = "error"
    latency_s: float = 0.0

    KINDS = ("error", "latency", "device_loss", "crash")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {self.KINDS}")
        if self.at_call < 0:
            raise ValueError("at_call must be >= 0")


def _site_kinds(site: str, kinds) -> tuple[str, ...]:
    """Kinds executable at a site.  Worker-loop sites take latency or
    crash (an "error" at a loop top has no per-request owner — it IS a
    crash, so only crash is scheduled there); dispatch sites take
    error/latency (retryable per work unit), plus device loss at the
    sharded dispatch (the only site with a mesh to lose)."""
    if site.endswith(".worker"):
        allowed = {"latency", "crash"}
    else:
        allowed = {"error", "latency"}
        if site == SITE_SHARDED_DISPATCH:
            allowed.add("device_loss")
    out = tuple(k for k in kinds if k in allowed)
    return out or ("latency",)


class FaultPlan:
    """An immutable schedule of :class:`Fault`\\ s.  Build one explicitly
    or derive it deterministically from a seed (:meth:`seeded` — the
    ``--chaos SEED`` surface): the same seed always yields the same
    plan, so a chaos failure reproduces exactly."""

    def __init__(self, faults=()):
        self.faults = tuple(faults)
        seen = set()
        for f in self.faults:
            key = (f.site, f.at_call)
            if key in seen:
                raise ValueError(f"duplicate fault at {key}")
            seen.add(key)

    def __len__(self) -> int:
        return len(self.faults)

    def __iter__(self):
        return iter(self.faults)

    def by_site(self) -> dict[str, dict[int, Fault]]:
        out: dict[str, dict[int, Fault]] = {}
        for f in self.faults:
            out.setdefault(f.site, {})[f.at_call] = f
        return out

    def describe(self) -> str:
        if not self.faults:
            return "FaultPlan(empty)"
        rows = [f"  {f.site}#{f.at_call}: {f.kind}"
                + (f"({f.latency_s * 1e3:.0f}ms)" if f.kind == "latency"
                   else "")
                for f in sorted(self.faults,
                                key=lambda f: (f.site, f.at_call))]
        return "FaultPlan:\n" + "\n".join(rows)

    @classmethod
    def seeded(cls, seed: int, sites, *, n_faults: int = 4,
               kinds=("error", "latency", "crash"), max_call: int = 10,
               latency_s: float = 0.01) -> "FaultPlan":
        """Deterministic plan: ``n_faults`` faults spread over ``sites``
        at call indexes in ``[0, max_call)``, kinds drawn from ``kinds``
        but restricted per site to what is executable there (crashes at
        worker sites, device loss at the sharded dispatch).  Same seed →
        same plan, byte for byte."""
        sites = tuple(sites)
        if not sites:
            raise ValueError("need at least one site")
        rng = np.random.default_rng(seed)
        faults, used = [], set()
        for _ in range(n_faults):
            for _attempt in range(64):
                site = sites[int(rng.integers(len(sites)))]
                at = int(rng.integers(max_call))
                if (site, at) not in used:
                    break
            else:                                # plan saturated
                break
            used.add((site, at))
            pool = _site_kinds(site, kinds)
            kind = pool[int(rng.integers(len(pool)))]
            faults.append(Fault(site, at, kind, latency_s=latency_s))
        return cls(faults)


class FaultInjector:
    """Executes a :class:`FaultPlan`.  Thread-safe: every hooked site
    calls :meth:`fire` with its name; the injector counts calls per site
    and fires the scheduled fault at its exact index.  ``fired`` is the
    execution log (what a chaos run reports)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._by_site = plan.by_site()
        self._counts: dict[str, int] = {}   # guarded-by: _lock
        self._lock = threading.Lock()
        self.fired: list[Fault] = []        # guarded-by: _lock

    def calls(self, site: str) -> int:
        with self._lock:
            return self._counts.get(site, 0)

    def remaining(self) -> int:
        with self._lock:
            return len(self.plan) - len(self.fired)

    def fire(self, site: str) -> None:
        with self._lock:
            idx = self._counts.get(site, 0)
            self._counts[site] = idx + 1
            fault = self._by_site.get(site, {}).get(idx)
            if fault is not None:
                self.fired.append(fault)
        if fault is None:
            return
        if fault.kind == "latency":
            time.sleep(fault.latency_s)
        elif fault.kind == "error":
            raise InjectedFault(f"injected dispatch failure at "
                                f"{site}#{idx}")
        elif fault.kind == "device_loss":
            raise DeviceLost(f"injected device loss at {site}#{idx}")
        else:                                    # crash
            raise InjectedCrash(f"injected worker crash at {site}#{idx}")


# ---------------------------------------------------------------------------
# retry / restart policies
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff + deterministic jitter for
    *transient* dispatch failures.  ``transient`` is the exception
    allowlist — anything else re-raises immediately (a shape error will
    never succeed on retry; burning the budget on it only adds latency).
    After ``max_retries`` re-executions the work unit is quarantined
    (:class:`QuarantinedError`)."""

    max_retries: int = 3
    backoff_s: float = 0.005
    backoff_mult: float = 2.0
    jitter: float = 0.25               # ± fraction of the nominal delay
    seed: int = 0
    transient: tuple = (TransientDispatchError,)

    def __post_init__(self):
        if self.max_retries < 1:
            raise ValueError("max_retries must be >= 1")

    def is_transient(self, exc: BaseException) -> bool:
        return isinstance(exc, self.transient)

    def delay(self, attempt: int, rng=None) -> float:
        base = self.backoff_s * self.backoff_mult ** attempt
        if not self.jitter:
            return base
        r = (rng or np.random.default_rng(self.seed + attempt)).random()
        return base * (1.0 + self.jitter * (2.0 * r - 1.0))


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """Supervised worker restart: a crashed worker thread backs off and
    re-enters its loop with all pending work preserved, up to
    ``max_restarts`` times over the loop's lifetime; past the budget the
    crash fails every live future/handle (:class:`WorkerCrashed`)."""

    max_restarts: int = 2
    backoff_s: float = 0.005
    backoff_mult: float = 2.0

    def __post_init__(self):
        if self.max_restarts < 1:
            raise ValueError("max_restarts must be >= 1")

    def delay(self, n_restarts: int) -> float:
        return self.backoff_s * self.backoff_mult ** n_restarts


def retry_call(fn, *, policy: RetryPolicy | None = None,
               supervisor: "ServingSupervisor | None" = None, rng=None):
    """Run ``fn()`` under the request-robustness ladder.

    * Transient failures (``policy.is_transient``) retry with backoff +
      jitter, at most ``policy.max_retries`` times; exhaustion raises
      :class:`QuarantinedError` chaining the last failure.
    * :class:`DeviceLost` asks the supervisor to degrade the lane and
      retries on the new one (bounded by the ladder depth — at the
      bottom the loss re-raises).
    * Everything else re-raises immediately.

    With ``policy`` and ``supervisor`` both ``None`` this is exactly
    ``fn()`` — the disabled path stays byte-identical.  ``fn`` must be
    side-effect free on failure (the dispatch functions are: jitted
    calls either return or leave state untouched).
    """
    if policy is None and supervisor is None:
        return fn()
    attempt = 0
    while True:
        try:
            return fn()
        except DeviceLost:
            if supervisor is None or supervisor.notify_device_loss() is None:
                raise
        except Exception as e:          # noqa: BLE001 — classified below
            if policy is None or not policy.is_transient(e):
                raise
            if attempt >= policy.max_retries:
                raise QuarantinedError(
                    f"quarantined after {attempt + 1} attempts: {e}",
                    attempts=attempt + 1) from e
            time.sleep(policy.delay(attempt, rng))
            attempt += 1


# ---------------------------------------------------------------------------
# the serving supervisor: latency watch + degradation ladder
# ---------------------------------------------------------------------------

class ServingSupervisor:
    """Watches serving health and executes graceful degradation.

    **Latency watch.**  :meth:`record_latency` feeds each dispatch /
    decode-step wall time into a :class:`StragglerMonitor` as host 0 of
    a synthetic 4-host fleet whose other hosts report the warmed-up
    baseline (median of the first ``warmup`` samples) — so the monitor's
    fleet-median machinery (EWMA, threshold × median, patience) applies
    unchanged to a single serving lane.  A sustained flag degrades one
    rung.

    **Degradation ladder.**  The lane starts as a ``sharded`` backend
    over N devices.  Each degradation marks one device failed in an
    :class:`ElasticMeshManager` (devices are modeled as 1-chip hosts)
    and rebuilds the tile mesh over the largest surviving feasible grid;
    when no grid is feasible the lane falls back to ``fallback``
    (default ``tiled``, the single-device lane).  Each sharded rung is a
    fresh :class:`~repro.core.backends.ShardedBackend` registered as
    ``<name>@<n>`` — its per-layer shard state and whole-chain jit are
    keyed on the mesh, so the first dispatch after a shrink re-shards
    and re-jits automatically.  Outputs are bit-for-bit identical across
    every rung (DESIGN §3.3), so a degradation changes latency, never
    results.

    :meth:`notify_device_loss` degrades immediately (the dispatch that
    observed the loss retries on the new lane via :func:`retry_call`).
    ``history`` records every transition for the control plane.
    """

    def __init__(self, *, backend="sharded", fallback: str = "tiled",
                 monitor_cfg: StragglerConfig | None = None,
                 warmup: int = 8):
        from repro.core import backends as _backends
        self._lock = threading.Lock()
        self._base = _backends.resolve(backend)
        self._backend = self._base          # guarded-by: _lock
        self.fallback = fallback
        self.warmup = max(1, warmup)
        self.monitor = StragglerMonitor(
            4, monitor_cfg or StragglerConfig(patience=4))
        self._warm: list[float] = []        # guarded-by: _lock
        self._baseline: float | None = None  # guarded-by: _lock
        self.history: list[dict] = []       # guarded-by: _lock
        self.degradations = 0               # guarded-by: _lock
        self._exhausted = False             # guarded-by: _lock
        devices = self._lane_devices()
        hosts = HostSet(n_hosts=len(devices), chips_per_host=1,
                        healthy=np.ones(len(devices), dtype=bool))
        self.mesh_manager = ElasticMeshManager(
            hosts, model_parallel=1, global_batch=len(devices))
        self._devices = devices

    def _lane_devices(self) -> list:
        mesh = getattr(self._base, "_mesh", None)
        if mesh is not None:
            return list(np.asarray(mesh.devices).ravel())
        import jax
        return list(jax.devices())

    # -- state --------------------------------------------------------------
    @property
    def backend(self):
        """The current lane (a Backend instance) — what dispatches
        should execute on right now."""
        with self._lock:
            return self._backend

    @property
    def backend_name(self) -> str:
        return self.backend.name

    @property
    def baseline_s(self) -> float | None:
        with self._lock:
            return self._baseline

    # -- events -------------------------------------------------------------
    def record_latency(self, dt_s: float) -> str | None:
        """Feed one dispatch/step wall time.  Returns the new lane name
        when this observation tipped a sustained-degradation rung, else
        ``None``."""
        with self._lock:
            if self._baseline is None:
                self._warm.append(float(dt_s))
                if len(self._warm) >= self.warmup:
                    self._baseline = float(np.median(self._warm))
                return None
            fleet = np.array([dt_s] + [self._baseline] * 3)
            res = self.monitor.observe(fleet)
            if res["actions"].get(0) is None:
                return None
            name = self._degrade_locked(
                f"latency sustained {res['ratio'][0]:.2f}x baseline "
                f"({res['actions'][0]})")
            # the flag condition was measured against the OLD lane;
            # restart the evidence window for the new one
            self.monitor.flag_streak[:] = 0
            self.monitor.initialized = False
            return name

    def notify_device_loss(self, exc: BaseException | None = None
                           ) -> str | None:
        """A dispatch observed a lost device: degrade NOW.  Returns the
        new lane name, or ``None`` when the ladder is exhausted (the
        caller should let the loss propagate)."""
        with self._lock:
            return self._degrade_locked(
                f"device loss{f': {exc}' if exc else ''}")

    def degrade(self, reason: str = "manual") -> str | None:
        """Force one rung down the ladder (control-plane surface)."""
        with self._lock:
            return self._degrade_locked(reason)

    # -- internals ----------------------------------------------------------
    def _degrade_locked(self, reason: str) -> str | None:
        from repro.core import backends as _backends
        if self._exhausted:
            return None
        prev = self._backend.name
        healthy = np.nonzero(self.mesh_manager.hosts.healthy)[0]
        if healthy.size:
            self.mesh_manager.mark_failed(int(healthy[-1]))
        try:
            n_dev, _ = self.mesh_manager.current_grid()
        except ValueError:
            # no feasible grid survives — final rung: single-device lane
            new = _backends.get_backend(self.fallback)
            self._exhausted = True
        else:
            from repro.sharding import rules
            mesh = rules.tile_mesh(self._devices[:n_dev])
            new = _backends.ShardedBackend(
                mesh, name=f"{self._base.name}@{n_dev}")
            # carry the fault injector down the ladder so a chaos plan
            # can lose a second device from the already-shrunken lane
            new._injector = getattr(self._backend, "_injector", None)
            # re-register so the rung is selectable by name everywhere a
            # backend name is accepted; first dispatch re-shards + re-jits
            _backends.register(new, overwrite=True)
        self._backend = new
        self.degradations += 1
        self.history.append({
            "event": "degrade", "reason": reason, "from": prev,
            "to": new.name, "t": time.monotonic(),
            "surviving_devices": int(
                self.mesh_manager.hosts.healthy_chips),
        })
        return new.name
