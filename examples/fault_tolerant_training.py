"""Fault-tolerance demo: train → simulated host failure → elastic
re-mesh plan → restore from the atomic checkpoint → resume bit-exactly.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import tempfile

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.data import DataConfig, host_batch_iterator
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.runtime import (ElasticMeshManager, HostSet, TrainLoop,
                           TrainLoopConfig)


def make_loop(cfg, api, params, ckpt_dir, fail_at=None):
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4,
                      motif_prob=0.8)
    return TrainLoop(
        train_loss_fn=lambda p, b: api.train_loss(p, b, cfg),
        params=params,
        batch_iter=host_batch_iterator(dcfg),
        opt_cfg=AdamWConfig(lr=3e-3, use_master=False),
        loop_cfg=TrainLoopConfig(total_steps=40, checkpoint_every=10,
                                 ckpt_dir=ckpt_dir, peak_lr=3e-3,
                                 warmup_steps=5, fail_at_step=fail_at))


def main() -> None:
    cfg = smoke_variant(get_config("granite-moe-1b-a400m"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)

    with tempfile.TemporaryDirectory() as ckpt:
        print("=== phase 1: train until the simulated failure at step 25 ===")
        loop = make_loop(cfg, api, params, ckpt, fail_at=25)
        try:
            loop.run()
        except RuntimeError as e:
            print(f"  !! {e}")

        print("=== phase 2: control plane picks a degraded mesh ===")
        hosts = HostSet(n_hosts=8, chips_per_host=4,
                        healthy=np.ones(8, dtype=bool))
        mgr = ElasticMeshManager(hosts, model_parallel=2, global_batch=16)
        print(f"  healthy grid: {mgr.current_grid()}")
        mgr.mark_failed(3)
        plan = mgr.resume_plan(step=20)
        print(f"  after host-3 failure: grid={plan['mesh']}, "
              f"plan={plan['actions']}")

        print("=== phase 3: fresh process restores and finishes ===")
        params2 = api.init_params(jax.random.PRNGKey(0), cfg)
        loop2 = make_loop(cfg, api, params2, ckpt)
        start = loop2.try_restore()
        print(f"  restored from checkpoint, resuming at step {start}")
        hist = loop2.run()
        print(f"  finished at step {hist[-1]['step']}, "
              f"final loss {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
