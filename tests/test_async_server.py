"""The async ``CodrBatchServer`` path: futures parity with the sync
bucketed dispatch, deadline/max-batch flush triggers, out-of-order
completion across shape buckets, exception propagation into exactly the
failed batch's futures, and stop/drain/restart semantics.

Timing-sensitive assertions are one-sided (an event happens within a
generous timeout) so the file stays deterministic on loaded CI boxes.
"""
import threading

import numpy as np
import pytest

import repro.api as codr


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _sparse(rng, shape, density=0.5, scale=0.5):
    w = rng.normal(size=shape).astype(np.float32) * scale
    w[rng.random(shape) > density] = 0
    return w


@pytest.fixture(scope="module")
def compiled():
    """Tiny conv-only model (conv-only → any input spatial size works,
    which the mixed-shape tests need)."""
    rng = np.random.default_rng(7)
    w = rng.normal(size=(6, 3, 3, 3)).astype(np.float32) * 0.5
    w[rng.random(w.shape) > 0.5] = 0
    spec = codr.ModelSpec([codr.LayerSpec.conv(
        w, rng.normal(size=6).astype(np.float32), activation="relu",
        name="c0")])
    return codr.compile(spec, codr.EncodeConfig(n_unique=16))


def test_async_matches_sync_bit_for_bit(compiled, rng):
    """submit_async resolves to exactly what the sync path produces for
    the same request stream (same bucketing → same batch shapes →
    identical float bits)."""
    xs = [rng.normal(size=(9, 9, 3)).astype(np.float32) for _ in range(11)]
    refs = compiled.serve(max_batch=4).serve(xs)
    server = compiled.serve(max_batch=4, flush_deadline_s=0.05)
    with server:
        futs = [server.submit_async(x) for x in xs]
        outs = [f.result(timeout=120) for f in futs]
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)
    assert server.requests_served == len(xs)
    assert server.async_pending == 0


def test_deadline_triggers_partial_flush(compiled, rng):
    """A single request far below max_batch must still be served — the
    latency trigger flushes a partial batch after flush_deadline_s."""
    server = compiled.serve(max_batch=64, flush_deadline_s=0.05)
    fut = server.submit_async(rng.normal(size=(9, 9, 3)).astype(np.float32))
    out = fut.result(timeout=120)               # resolves ⇒ deadline fired
    assert out.shape == (7, 7, 6)
    assert server.batches_run == 1
    assert server.bucket_counts == {1: 1}       # partial: bucket of 1
    server.stop_async()


def test_max_batch_triggers_before_deadline(compiled, rng):
    """With an hour-long deadline, a full batch must dispatch on the
    load trigger — futures resolving at all proves it wasn't the
    deadline."""
    server = compiled.serve(max_batch=4, flush_deadline_s=3600.0)
    xs = [rng.normal(size=(9, 9, 3)).astype(np.float32) for _ in range(4)]
    futs = [server.submit_async(x) for x in xs]
    outs = [f.result(timeout=120) for f in futs]
    assert all(o.shape == (7, 7, 6) for o in outs)
    assert server.bucket_counts.get(4) == 1
    server.stop_async(drain=False)


def test_out_of_order_completion_across_shape_buckets(compiled, rng):
    """Mixed-shape streams complete per shape bucket, not in submission
    order; every future still gets its own sample's output."""
    a = [rng.normal(size=(9, 9, 3)).astype(np.float32) for _ in range(3)]
    b = [rng.normal(size=(11, 11, 3)).astype(np.float32) for _ in range(2)]
    order = []                                  # completion order, by tag
    done = threading.Event()

    def track(tag):
        def cb(fut):
            order.append(tag)
            if len(order) == 5:
                done.set()
        return cb

    # max_batch far above the submission count: neither trigger can fire
    # mid-submission, so the whole queue dispatches as one drained flush
    server = compiled.serve(max_batch=64, flush_deadline_s=3600.0)
    server.start_async()
    # interleave: a0 b0 a1 b1 a2 — then drain via stop
    futs, tags = [], []
    for i, (x, tag) in enumerate(zip(
            [a[0], b[0], a[1], b[1], a[2]],
            ["a0", "b0", "a1", "b1", "a2"])):
        f = server.submit_async(x)
        f.add_done_callback(track(tag))
        futs.append(f)
        tags.append(tag)
    server.stop_async(drain=True)
    assert done.wait(timeout=120)
    # chunks dispatch grouped by shape: [a0,a1,a2] then [b0,b1] — so a2
    # (submitted last) completes before b0 (submitted second)
    assert order.index("a2") < order.index("b0")
    # ...and every future carries its own sample's result (sync refs use
    # the same max_batch so the batch shapes — hence float bits — match)
    refs_a = compiled.serve(max_batch=64).serve(a)
    refs_b = compiled.serve(max_batch=64).serve(b)
    refs = {"a0": refs_a[0], "a1": refs_a[1], "a2": refs_a[2],
            "b0": refs_b[0], "b1": refs_b[1]}
    for f, tag in zip(futs, tags):
        np.testing.assert_array_equal(f.result(timeout=1), refs[tag])


def test_exception_propagates_to_failed_batch_only(compiled, rng):
    """A malformed sample poisons exactly its own batch's futures; other
    batches and the flush loop survive."""
    server = compiled.serve(max_batch=2, flush_deadline_s=0.02)
    bad = rng.normal(size=(9, 9, 4)).astype(np.float32)  # 4 chans, model
    fut_bad = server.submit_async(bad)                   # expects 3 → dies
    with pytest.raises(Exception):
        fut_bad.result(timeout=120)
    # the loop is still alive and serving
    good = rng.normal(size=(9, 9, 3)).astype(np.float32)
    fut_good = server.submit_async(good)
    ref = np.asarray(compiled.run(good[None]))[0]
    np.testing.assert_array_equal(fut_good.result(timeout=120), ref)
    server.stop_async()


def test_stop_drain_false_cancels_and_restart_works(compiled, rng):
    server = compiled.serve(max_batch=64, flush_deadline_s=3600.0)
    x = rng.normal(size=(9, 9, 3)).astype(np.float32)
    fut = server.submit_async(x)
    server.stop_async(drain=False)
    assert fut.cancelled()
    # restart: the next submit lazily brings the loop back up
    fut2 = server.submit_async(x)
    server.stop_async(drain=True)
    np.testing.assert_array_equal(fut2.result(timeout=1),
                                  np.asarray(compiled.run(x[None]))[0])


def test_individually_cancelled_future_skips_compute(compiled, rng):
    """A future cancelled while queued is dropped before batching: it
    stays cancelled, burns no compute, and never counts as served."""
    server = compiled.serve(max_batch=64, flush_deadline_s=3600.0)
    xs = [rng.normal(size=(9, 9, 3)).astype(np.float32) for _ in range(2)]
    f_cancel = server.submit_async(xs[0])
    f_keep = server.submit_async(xs[1])
    assert f_cancel.cancel()
    server.stop_async(drain=True)
    assert f_cancel.cancelled()
    np.testing.assert_array_equal(
        f_keep.result(timeout=1),
        compiled.serve(max_batch=64).serve([xs[1]])[0])
    assert server.requests_served == 1
    assert server.bucket_counts == {1: 1}


def test_context_manager_drains_on_exit(compiled, rng):
    xs = [rng.normal(size=(9, 9, 3)).astype(np.float32) for _ in range(3)]
    server = compiled.serve(max_batch=64, flush_deadline_s=3600.0)
    with server:
        futs = [server.submit_async(x) for x in xs]
    # __exit__ = stop_async(drain=True): everything resolved, no waiting
    refs = compiled.serve(max_batch=64).serve(xs)
    for f, r in zip(futs, refs):
        np.testing.assert_array_equal(f.result(timeout=1), r)


def test_sync_flush_unaffected_by_async_state(compiled, rng):
    """The sync and async queues are independent: a running flush loop
    never steals synchronously submitted requests."""
    server = compiled.serve(max_batch=4, flush_deadline_s=0.01)
    server.start_async()
    x = rng.normal(size=(9, 9, 3)).astype(np.float32)
    rid = server.submit(x)
    assert rid == 0
    import time
    time.sleep(0.05)                    # give the loop a chance to misbehave
    outs = server.flush()
    assert len(outs) == 1 and outs[0].shape == (7, 7, 6)
    server.stop_async()
