"""Attention: GQA (bias / qk-norm options), chunked flash-style softmax
attention for long sequences, KV-cache decode, and DeepSeek-V2 MLA with
the absorbed decode form."""
from __future__ import annotations

import math
import os
from functools import partial

import jax
import jax.numpy as jnp
try:                                   # jax >= 0.6 exports it at top level
    from jax import shard_map
except ImportError:                    # jax 0.4.x
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models import cache as cache_lib
from repro.models.common import (apply_rope, dense_init, dense_weight,
                                 linear, norm_apply, norm_init, rms_norm)
from repro.sharding import current_ctx, maybe_constrain


def _einsum_f32(eq: str, a: jax.Array, b: jax.Array) -> jax.Array:
    """einsum with f32 accumulation.  On TPU (and in the dry-run, which
    targets TPU semantics) keep operands in their storage dtype and set
    preferred_element_type — no upcast copies of the big operand.  The
    CPU *runtime* cannot execute mixed bf16→f32 dots (DotThunk), so the
    executing path upcasts."""
    if jax.default_backend() == "tpu" or os.environ.get("REPRO_DRYRUN"):
        return jnp.einsum(eq, a, b, preferred_element_type=jnp.float32)
    return jnp.einsum(eq, a.astype(jnp.float32), b.astype(jnp.float32))

# ---------------------------------------------------------------------------
# chunked (flash-style) attention — pure JAX online softmax
# ---------------------------------------------------------------------------

def _pick_chunk(s: int, preferred: int) -> int:
    """Largest divisor of ``s`` that is ≤ preferred."""
    c = min(preferred, s)
    while s % c:
        c -= 1
    return c


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, q_chunk: int = 512, kv_chunk: int = 1024,
                    scale: float | None = None,
                    acc_dtype=jnp.float32) -> jax.Array:
    """q (B,Sq,Hq,Dk), k (B,Skv,Hkv,Dk), v (B,Skv,Hkv,Dv) → (B,Sq,Hq,Dv).

    Online-softmax over kv chunks inside a scan over q chunks: peak live
    score buffer is (B,Hkv,G,qc,kc) instead of (B,H,S,S).  GQA via head
    grouping (no kv repeat materialization).  ``acc_dtype`` is the dtype
    of the materialized score/accumulator buffers — the §Perf lever
    ``attn_f32=False`` uses bf16 (the max-subtracted exponentials keep
    values in [0,1] where bf16 is safe; MXU accumulation stays f32 on
    hardware via preferred_element_type).
    """
    b, sq, hq, dk = q.shape
    _, skv, hkv, dv = v.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    qc = _pick_chunk(sq, q_chunk)
    kc = _pick_chunk(skv, kv_chunk)
    n_q, n_k = sq // qc, skv // kc
    neg = jnp.asarray(-1e30, acc_dtype)   # bf16 exponent range covers this

    qr = q.reshape(b, n_q, qc, hkv, g, dk).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(b, n_k, kc, hkv, dk).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(b, n_k, kc, hkv, dv).transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_blk):
        qi, q_blk = qi_blk                       # q_blk (B,Hkv,G,qc,Dk)

        def kv_step(carry, ki_blk):
            m, l, acc = carry
            ki, k_blk, v_blk = ki_blk
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk, k_blk,
                           preferred_element_type=acc_dtype) * scale
            if causal:
                qpos = qi * qc + jnp.arange(qc)
                kpos = ki * kc + jnp.arange(kc)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, neg)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, v_blk.astype(acc_dtype),
                preferred_element_type=acc_dtype)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, hkv, g, qc), neg, acc_dtype),
                jnp.zeros((b, hkv, g, qc), acc_dtype),
                jnp.zeros((b, hkv, g, qc, dv), acc_dtype))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(n_k), kr, vr))
        out = acc / jnp.maximum(l, 1e-8).astype(acc_dtype)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(n_q), qr))
    # outs (n_q, B, Hkv, G, qc, Dv) → (B, Sq, Hq, Dv)
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, hq, dv)


def decode_positions(pos, b: int) -> jax.Array:
    """Decode-step position operand → the ``(B, 1)`` int32 matrix RoPE
    consumes.  ``pos`` is either a scalar (every row writes the same
    position — the classic single-request batch) or per-row ``(B,)``
    (a continuous-batching slot pool where each row sits at its own
    sequence position, docs/DESIGN.md §3.4)."""
    p = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(p[:, None] if p.ndim else p, (b, 1))


def cache_update(cache: jax.Array, new: jax.Array, pos) -> jax.Array:
    """Write the single-token block ``new`` (B, 1, ...) into the
    (B, S, ...) ``cache`` at ``pos`` (scalar or per-row ``(B,)``).  The
    scalar form keeps the contiguous ``dynamic_update_slice``; the
    per-row form lowers to a batched one-row scatter — the slot-pool
    cache-slicing primitive."""
    new = new.astype(cache.dtype)
    p = jnp.asarray(pos)
    if p.ndim == 0:
        start = (0, p) + (0,) * (cache.ndim - 2)
        return jax.lax.dynamic_update_slice(cache, new, start)
    return cache.at[jnp.arange(cache.shape[0]), p].set(new[:, 0])


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, scale: float | None = None
                     ) -> jax.Array:
    """Single-token attention against a (B,S,Hkv,D) cache, masked to
    positions ≤ pos (pos may be per-batch (B,) or scalar)."""
    b, sq, hq, dk = q.shape
    _, s, hkv, dv = v_cache.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    qg = q.reshape(b, sq, hkv, g, dk)
    scores = _einsum_f32("bqhgd,bshd->bhgqs", qg,
                         k_cache.astype(qg.dtype)) * scale
    idx = jnp.arange(s)
    posb = jnp.broadcast_to(jnp.asarray(pos), (b,))
    mask = idx[None, :] <= posb[:, None]                        # (B, S)
    scores = jnp.where(mask[:, None, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = _einsum_f32("bhgqs,bshd->bqhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, sq, hq, dv).astype(q.dtype)


def decode_attention_dist(q: jax.Array, k_cache: jax.Array,
                          v_cache: jax.Array, k_new: jax.Array,
                          v_new: jax.Array, pos: jax.Array, *,
                          scale: float | None = None):
    """Sequence-parallel decode attention with in-shard cache update
    (§Perf optimization).

    The cache stays sharded over ``model`` on its sequence axis — both
    the position-``pos`` update (only the owning shard writes; a plain
    XLA dynamic-update-slice on a sequence-sharded cache triggers
    GSPMD's involuntary full rematerialization, i.e. a cache gather)
    and the attention (each shard computes a local flash-style partial
    softmax; three tiny psums combine max / denominator / accumulator).
    The cache is NEVER gathered.  Returns (out, k_cache, v_cache).
    Falls back to the naive path without a mesh or when S doesn't
    divide."""
    ctx = current_ctx()
    b, sq, hq, dk = q.shape
    _, s, hkv, dv = v_cache.shape
    if (ctx is None or ctx.axis_size("model") <= 1
            or s % ctx.axis_size("model")):
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (0, pos, 0, 0))
        return (decode_attention(q, k_cache, v_cache, pos, scale=scale),
                k_cache, v_cache)
    mesh = ctx.mesh
    msize = ctx.axis_size("model")
    s_loc = s // msize
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    g = hq // hkv
    bspec = ctx.batch_spec
    if bspec is not None:
        baxes = bspec if isinstance(bspec, tuple) else (bspec,)
        btotal = 1
        for a in baxes:
            btotal *= ctx.axis_size(a)
        if b % btotal:
            bspec = None

    def body(q_l, k_l, v_l, kn, vn, pos_l):
        shard = jax.lax.axis_index("model")
        # -- in-shard cache update: write-or-keep at the clamped slot ----
        local = pos_l - shard * s_loc
        in_range = (local >= 0) & (local < s_loc)
        slot = jnp.clip(local, 0, s_loc - 1)
        old_k = jax.lax.dynamic_slice(
            k_l, (0, slot, 0, 0), (k_l.shape[0], 1, hkv, dk))
        old_v = jax.lax.dynamic_slice(
            v_l, (0, slot, 0, 0), (v_l.shape[0], 1, hkv, dv))
        k_l = jax.lax.dynamic_update_slice(
            k_l, jnp.where(in_range, kn.astype(k_l.dtype), old_k),
            (0, slot, 0, 0))
        v_l = jax.lax.dynamic_update_slice(
            v_l, jnp.where(in_range, vn.astype(v_l.dtype), old_v),
            (0, slot, 0, 0))
        # -- local partial softmax + global combine ----------------------
        qg = q_l.reshape(q_l.shape[0], sq, hkv, g, dk)
        sc = _einsum_f32("bqhgd,bshd->bhgqs", qg, k_l) * scale
        idx = shard * s_loc + jnp.arange(s_loc)
        posb = jnp.broadcast_to(jnp.asarray(pos_l), (q_l.shape[0],))
        mask = idx[None, :] <= posb[:, None]
        sc = jnp.where(mask[:, None, None, None, :], sc, -1e30)
        m_l = sc.max(axis=-1)
        p = jnp.exp(sc - m_l[..., None])
        l_l = p.sum(axis=-1)
        acc_l = _einsum_f32("bhgqs,bshd->bhgqd", p.astype(v_l.dtype), v_l)
        m_g = jax.lax.pmax(m_l, "model")
        corr = jnp.exp(m_l - m_g)
        l_g = jax.lax.psum(l_l * corr, "model")
        acc_g = jax.lax.psum(acc_l * corr[..., None], "model")
        out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
        # (B,Hkv,G,q,Dv) → (B,q,Hq,Dv)
        out = out.transpose(0, 3, 1, 2, 4).reshape(
            q_l.shape[0], sq, hq, dv).astype(q_l.dtype)
        return out, k_l, v_l

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None, None),
                  P(bspec, "model", None, None),
                  P(bspec, "model", None, None),
                  P(bspec, None, None, None),
                  P(bspec, None, None, None), P()),
        out_specs=(P(bspec, None, None, None),
                   P(bspec, "model", None, None),
                   P(bspec, "model", None, None)),
        check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new, jnp.asarray(pos, jnp.int32))


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def gqa_init(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "q_proj": dense_init(ks[0], d, hq * hd),
        "k_proj": dense_init(ks[1], d, hkv * hd),
        "v_proj": dense_init(ks[2], d, hkv * hd),
        "o_proj": dense_init(ks[3], hq * hd, d),
    }
    if cfg.qkv_bias:
        p["q_bias"] = jnp.zeros((hq * hd,), jnp.float32)
        p["k_bias"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["v_bias"] = jnp.zeros((hkv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd, "rmsnorm")
        p["k_norm"] = norm_init(hd, "rmsnorm")
    return p


def _qkv(p, x, cfg, positions):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(x, p["q_proj"], p.get("q_bias")).reshape(b, s, hq, hd)
    k = linear(x, p["k_proj"], p.get("k_bias")).reshape(b, s, hkv, hd)
    v = linear(x, p["v_proj"], p.get("v_bias")).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"]["w"])
        k = rms_norm(k, p["k_norm"]["w"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if hq % 16 == 0:  # hint only when cleanly divisible by any model axis
        q = maybe_constrain(q, "batch", None, "model", None)
    return q, k, v


def gqa_forward(p, x, cfg, positions, *, causal=True):
    """Full-sequence GQA (train / prefill). Returns (out, (k, v))."""
    q, k, v = _qkv(p, x, cfg, positions)
    out = flash_attention(q, k, v, causal=causal,
                          q_chunk=cfg.attn_q_chunk,
                          kv_chunk=cfg.attn_kv_chunk,
                          acc_dtype=jnp.float32 if cfg.attn_f32
                          else jnp.bfloat16)
    b, s = x.shape[:2]
    out = linear(out.reshape(b, s, -1), p["o_proj"])
    return out, (k, v)


def gqa_decode(p, x, cfg, cache, pos):
    """Single-token decode. cache = (k, v) each (B, S, Hkv, hd);
    pos is the position being written — scalar int32, or per-row (B,)
    int32 when the batch is a continuous-batching slot pool whose rows
    sit at different sequence positions (docs/DESIGN.md §3.4).  The
    sequence-parallel ``dist`` lane needs a uniform write position, so
    per-row pos always takes the standard lane."""
    k_cache, v_cache = cache
    positions = decode_positions(pos, x.shape[0])
    q, k_new, v_new = _qkv(p, x, cfg, positions)
    if isinstance(k_cache, cache_lib.PagedKV):
        # paged lane: write the new row into the slot's page, then run
        # the standard masked attention over the gathered dense view —
        # bf16 pages reproduce the contiguous cache byte-for-byte
        k_cache = k_cache.update(k_new, pos)
        v_cache = v_cache.update(v_new, pos)
        out = decode_attention(q, k_cache.gather(), v_cache.gather(), pos)
    elif cfg.decode_attn == "dist" and jnp.ndim(pos) == 0:
        out, k_cache, v_cache = decode_attention_dist(
            q, k_cache, v_cache, k_new, v_new, pos)
    else:
        k_cache = cache_update(k_cache, k_new, pos)
        v_cache = cache_update(v_cache, v_new, pos)
        out = decode_attention(q, k_cache, v_cache, pos)
    b = x.shape[0]
    out = linear(out.reshape(b, 1, -1), p["o_proj"])
    return out, (k_cache, v_cache)


def gqa_cache_init(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    shape = (batch, seq, cfg.n_kv_heads, cfg.head_dim)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def gqa_cache_init_paged(cfg, spec, dtype=jnp.bfloat16):
    feat = (cfg.n_kv_heads, cfg.head_dim)
    return (cache_lib.paged_kv_init(spec, feat, dtype),
            cache_lib.paged_kv_init(spec, feat, dtype))


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2) — compressed KV cache
# ---------------------------------------------------------------------------

def mla_init(key, cfg) -> dict:
    ks = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    return {
        "q_a_proj": dense_init(ks[0], d, qr),
        "q_a_norm": norm_init(qr, "rmsnorm"),
        "q_b_proj": dense_init(ks[1], qr, h * (dn + dr)),
        "kv_a_proj": dense_init(ks[2], d, kr + dr),
        "kv_a_norm": norm_init(kr, "rmsnorm"),
        "kv_b_proj": dense_init(ks[3], kr, h * (dn + dv)),
        "o_proj": dense_init(ks[4], h * dv, d),
    }


def _mla_q(p, x, cfg, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr = cfg.nope_head_dim, cfg.rope_head_dim
    qa = norm_apply(linear(x, p["q_a_proj"]), p["q_a_norm"], "rmsnorm")
    q = linear(qa, p["q_b_proj"]).reshape(b, s, h, dn + dr)
    qn, qrot = q[..., :dn], q[..., dn:]
    qrot = apply_rope(qrot, positions, cfg.rope_theta)
    return qn, qrot


def _mla_ckv(p, x, cfg, positions):
    kr, dr = cfg.kv_lora_rank, cfg.rope_head_dim
    kv_a = linear(x, p["kv_a_proj"])
    ckv = norm_apply(kv_a[..., :kr], p["kv_a_norm"], "rmsnorm")
    krot = kv_a[..., kr:][:, :, None, :]                 # (B,S,1,dr)
    krot = apply_rope(krot, positions, cfg.rope_theta)[:, :, 0]
    return ckv, krot


def mla_forward(p, x, cfg, positions, *, causal=True):
    """Materialized form (train / prefill). Returns (out, (ckv, krot))."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    qn, qrot = _mla_q(p, x, cfg, positions)
    ckv, krot = _mla_ckv(p, x, cfg, positions)
    kv = linear(ckv, p["kv_b_proj"]).reshape(b, s, h, dn + dv)
    kn, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate([kn, jnp.broadcast_to(krot[:, :, None, :],
                                              (b, s, h, dr)).astype(kn.dtype)],
                        axis=-1)
    q = jnp.concatenate([qn, qrot], axis=-1)
    out = flash_attention(q, k, v, causal=causal,
                          q_chunk=cfg.attn_q_chunk,
                          kv_chunk=cfg.attn_kv_chunk,
                          scale=1.0 / math.sqrt(dn + dr),
                          acc_dtype=jnp.float32 if cfg.attn_f32
                          else jnp.bfloat16)
    out = linear(out.reshape(b, s, -1), p["o_proj"])
    return out, (ckv, krot)


def mla_decode(p, x, cfg, cache, pos):
    """Absorbed decode: attention runs in the kv_lora latent space —
    cache is (ckv (B,S,c), krot (B,S,dr)); per-token HBM traffic is
    c + dr per position instead of H*(dn+dv)."""
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    c = cfg.kv_lora_rank
    ckv_cache, krot_cache = cache
    positions = decode_positions(pos, b)
    qn, qrot = _mla_q(p, x, cfg, positions)              # (B,1,H,dn/dr)
    ckv_new, krot_new = _mla_ckv(p, x, cfg, positions)
    if isinstance(ckv_cache, cache_lib.PagedKV):
        ckv_cache = ckv_cache.update(ckv_new, pos)
        krot_cache = krot_cache.update(krot_new, pos)
        ckv_dense, krot_dense = ckv_cache.gather(), krot_cache.gather()
    else:
        ckv_cache = cache_update(ckv_cache, ckv_new, pos)
        krot_cache = cache_update(krot_cache, krot_new, pos)
        ckv_dense, krot_dense = ckv_cache, krot_cache

    # absorbed form consumes the raw weight, not a matmul — decode a
    # packed leaf on dispatch (identity for dense params)
    w_kv_b = dense_weight(p["kv_b_proj"]).reshape(c, h, dn + dv)
    w_uk, w_uv = w_kv_b[..., :dn], w_kv_b[..., dn:]
    q_lat = _einsum_f32("bqhd,chd->bqhc", qn, w_uk.astype(qn.dtype))
    scores = (_einsum_f32("bqhc,bsc->bhqs", q_lat.astype(ckv_dense.dtype),
                          ckv_dense)
              + _einsum_f32("bqhd,bsd->bhqs", qrot.astype(krot_dense.dtype),
                            krot_dense))
    scores = scores / math.sqrt(dn + dr)
    posb = jnp.broadcast_to(jnp.asarray(pos), (b,))
    mask = jnp.arange(ckv_dense.shape[1])[None, :] <= posb[:, None]
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    out_lat = _einsum_f32("bhqs,bsc->bqhc", attn.astype(ckv_dense.dtype),
                          ckv_dense)
    out = jnp.einsum("bqhc,chd->bqhd", out_lat, w_uv.astype(jnp.float32))
    out = linear(out.reshape(b, 1, h * dv).astype(x.dtype), p["o_proj"])
    return out, (ckv_cache, krot_cache)


def mla_cache_init(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    return (jnp.zeros((batch, seq, cfg.kv_lora_rank), dtype),
            jnp.zeros((batch, seq, cfg.rope_head_dim), dtype))


def mla_cache_init_paged(cfg, spec, dtype=jnp.bfloat16):
    return (cache_lib.paged_kv_init(spec, (cfg.kv_lora_rank,), dtype),
            cache_lib.paged_kv_init(spec, (cfg.rope_head_dim,), dtype))
