import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_DRYRUN"] = "1"   # TPU-semantics lowering (no CPU upcasts)
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the
device count at first init).  512 host devices back both the 16×16
single-pod mesh and the 2×16×16 multi-pod mesh.

Per cell we record:
  * ``compiled.memory_analysis()``  — bytes/device (proves it fits HBM)
  * ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline
  * collective bytes parsed from the optimized HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute)
outputs land in ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
"""
import argparse      # noqa: E402
import gzip          # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

import dataclasses   # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, applicable_shapes, get_config  # noqa: E402
from repro.launch.hlo_analysis import (collective_bytes_from_hlo,  # noqa: E402
                                       flops_bytes_from_hlo)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import CellOptions, build_cell  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: str = OUT_DIR, *, options: CellOptions | None = None,
             cfg_overrides: dict | None = None, tag: str = "") -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    skip_reason = applicable_shapes(cfg)[shape_name]
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "options": dataclasses.asdict(options) if options else None,
              "cfg_overrides": cfg_overrides or None, "tag": tag}
    if skip_reason != "run":
        record["status"] = "SKIP"
        record["reason"] = skip_reason
        return record

    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    t0 = time.monotonic()
    fn, arg_shapes, in_sh, _ = build_cell(cfg, shape, mesh, options=options)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh)
        lowered = jitted.lower(*arg_shapes)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo_text)
    fb = flops_bytes_from_hlo(hlo_text)
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    with gzip.open(os.path.join(
            out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.hlo.gz"),
            "wt") as f:
        f.write(hlo_text)
    record.update({
        "status": "OK",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "n_devices": mesh.size,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "transcendentals": cost.get("transcendentals"),
        },
        # loop-aware per-device FLOPs/bytes (while bodies × trip count —
        # xla's cost_analysis counts loop bodies once; see hlo_analysis)
        "hlo_loop_aware": fb,
        "collectives": coll,
    })
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--opt", action="append", default=[],
                    help="CellOptions k=v (serve_weight_dtype, cache_dtype)")
    ap.add_argument("--cfg-opt", action="append", default=[],
                    help="ModelConfig override k=v (decode_attn=dist, "
                         "moe_decode_2d=true, block_causal=true, ...)")
    ap.add_argument("--tag", default="",
                    help="suffix for output files (perf iteration name)")
    args = ap.parse_args()

    opt_kv = dict(kv.split("=", 1) for kv in args.opt)
    options = CellOptions(**opt_kv) if opt_kv else None

    def conv(v: str):
        if v.lower() in ("true", "false"):
            return v.lower() == "true"
        for t in (int, float):
            try:
                return t(v)
            except ValueError:
                pass
        return v

    cfg_overrides = {k: conv(v) for k, v in
                     (kv.split("=", 1) for kv in args.cfg_opt)} or None

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_name in meshes:
                tag = f"{arch}__{shape_name}__{mesh_name}" \
                    + (f"__{args.tag}" if args.tag else "")
                path = os.path.join(args.out, tag + ".json")
                try:
                    rec = run_cell(arch, shape_name, mesh_name, args.out,
                                   options=options,
                                   cfg_overrides=cfg_overrides,
                                   tag=args.tag)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "FAIL",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "OK":
                    gb = (rec["memory"]["peak_bytes"] or 0) / 1e9
                    extra = (f" flops={rec['cost']['flops']:.3e}"
                             f" peak={gb:.2f}GB"
                             f" coll={rec['collectives']['total_bytes']:.3e}B"
                             f" compile={rec['compile_s']}s")
                elif status == "FAIL":
                    extra = " " + rec["error"][:200]
                print(f"[{status}] {tag}{extra}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
