from repro.sharding.rules import (ENGINE_TILE_AXIS, ShardCtx, current_ctx,
                                  maybe_constrain, pad_to_multiple,
                                  param_spec, set_ctx, shard_leading,
                                  tile_mesh, use_ctx)

__all__ = ["ENGINE_TILE_AXIS", "ShardCtx", "current_ctx", "maybe_constrain",
           "pad_to_multiple", "param_spec", "set_ctx", "shard_leading",
           "tile_mesh", "use_ctx"]
