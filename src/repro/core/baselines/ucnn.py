"""UCNN weight compression (paper §V-B).

"UCNN employs RLE to compress the weights and indexes, yet, it uses
bit-length of 5 for all layers. UCNN additionally appends 1 bit to each
index to indicate the transition to a new unique weight."

So: the same escape-coded Δ streams as CoDR but with the encoding
parameter *fixed at 5* (no per-layer search), no repetition-count stream
(group boundaries are marked by the per-index transition bit instead),
applied to the same UCR factorization (UCNN exploits repetition and
sparsity but not similarity — Δs are an encoding detail for it, not a
compute saving)."""
from __future__ import annotations

import math

import numpy as np

from repro.core import rle
from repro.core.ucr import UCRVector

FIXED_BITS = 5


def ucnn_vector_bits(u: UCRVector) -> int:
    index_bits = max(1, math.ceil(math.log2(max(u.vector_len, 2))))
    deltas = rle.delta_transform(u.unique_vals)
    weight_bits = rle.escape_stream_bits(deltas, FIXED_BITS, rle.FULL_BITS)
    idx_deltas, _ = rle.index_delta_fields(u.indexes)
    idx_bits = rle.escape_stream_bits(
        idx_deltas, min(FIXED_BITS, index_bits), index_bits)
    transition_bits = len(u.indexes)             # 1 bit per index
    return weight_bits + idx_bits + transition_bits


def ucnn_compress_bits(vectors: list[UCRVector]) -> int:
    # no per-layer parameter header — UCNN's bit-length is globally fixed
    return sum(ucnn_vector_bits(u) for u in vectors)
