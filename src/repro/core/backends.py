"""Pluggable execution backends for the CoDR engine.

The paper's accelerator is one fixed datapath; a software reproduction
grows several — the fused XLA tile dispatch, the faithful NumPy MPE/APE
execution model, the Pallas SMM kernel, the fused-decode matmul kernel.
Previously each was reachable through a different stringly-typed knob
(``CodrModel.run(backend=...)`` if/else chains, ``smm_forward(kernel=...)``).
This module makes backends first class:

* :class:`BackendCaps` — declarative capability flags (stride support,
  integer-activation requirement, which layer kinds execute natively).
  Kernel-adjacent facts live next to the kernels themselves
  (``repro.kernels.*.ops.KERNEL_CAPS``) and are consumed here.
* :class:`Backend` — the protocol: ``conv(layer, x)`` / ``linear(layer,
  x)`` steps plus ``run_model(model, x)`` chaining, with ``supports``
  answering *can this backend execute that layer, and if not, why not*.
* a **registry** — :func:`register` / :func:`get_backend` /
  :func:`available_backends` / :func:`resolve`.  ``repro.core.engine``
  and ``repro.core.api`` dispatch exclusively through it; the ROADMAP's
  multi-device sharding and async-serving work plug in here as new
  registered backends.

Built-ins registered at import:

``tiled``        fused ``lax.conv`` tile dispatch (any stride, float path)
``smm``          NumPy faithful MPE/APE execution (integer activations)
``smm_kernel``   Pallas MPE/APE kernel, batch in the grid (integer acts)
``codr_matmul``  Pallas fused decode+matmul (linear-only models)
``sharded``      shard_map tile-parallel executor over all local devices

Registering your own backend (worked example)::

    import jax, repro.api as codr

    class DenseDemoBackend(codr.Backend):
        '''Executes the decoded tile stack as one dense conv per layer
        — the minimal real backend.  The layer surface it relies on
        (``code`` / ``kind`` / ``stride`` / ``tiles_device`` plus the
        shared :meth:`Backend.finish` epilogue) is all any backend
        needs.'''

        name = "dense_demo"
        caps = codr.BackendCaps(max_stride=1,
                                description="toy dense executor")

        def conv(self, layer, x):
            t = layer.tiles_device                   # (T, t_m, N, RK, CK)
            w = t.reshape(-1, *t.shape[2:])[: layer.code.shape[0]]
            y = jax.lax.conv_general_dilated(
                x, w, window_strides=(1, 1), padding="VALID",
                dimension_numbers=("NHWC", "OIHW", "NHWC"))
            return self.finish(layer, y * layer.code.scale)

    codr.register(DenseDemoBackend())
    compiled = codr.compile(spec, cfg, backend="dense_demo")  # just works

``compile`` now capability-checks specs against it (stride 2 convs are
rejected at compile time with the reason, because of ``max_stride=1``),
and every surface accepting a backend name — ``CompiledModel.run``,
``CodrModel.run``, benchmarks — can select it.
"""
from __future__ import annotations

import abc
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

try:                                   # jax >= 0.6 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                    # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import PartitionSpec as _P

from repro.core import smm, ucr

__all__ = [
    "Backend", "BackendCaps", "available_backends", "get_backend",
    "register", "resolve", "TiledBackend", "SmmBackend",
    "SmmKernelBackend", "CodrMatmulBackend", "ShardedBackend",
]


# ---------------------------------------------------------------------------
# capabilities
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BackendCaps:
    """What a backend can execute, declaratively.

    ``max_stride``           ``None`` = any stride.
    ``integer_activations``  the backend runs the 8-bit feature datapath:
                             integer-valued inputs execute exactly,
                             anything else is int8-quantized first.
    ``native_kinds``         layer kinds the backend executes itself;
                             other kinds fall back per ``fallback_kinds``.
    ``fallback_kinds``       kinds delegated to the layer's own tiled
                             forward (empty = unsupported kinds error).
    ``packed_matmul``        the backend can execute a packed projection
                             leaf (:class:`repro.core.codr_linear.
                             PackedLinear`) via :meth:`Backend.matmul` —
                             the transformer serving lane
                             (``repro.api.compile_params`` gates on it).
    """

    max_stride: int | None = None
    integer_activations: bool = False
    native_kinds: frozenset = frozenset({"conv", "linear"})
    fallback_kinds: frozenset = frozenset()
    packed_matmul: bool = False
    description: str = ""

    def supports_stride(self, stride: int) -> bool:
        return self.max_stride is None or stride <= self.max_stride

    def supports_kind(self, kind: str) -> bool:
        return kind in self.native_kinds or kind in self.fallback_kinds


# ---------------------------------------------------------------------------
# backend protocol
# ---------------------------------------------------------------------------

def _finish(layer, y: jax.Array) -> jax.Array:
    """Shared epilogue: bias + activation (what every datapath appends
    after its accumulators drain)."""
    if layer.bias is not None:
        y = y + jnp.asarray(layer.bias)
    return jax.nn.relu(y) if layer.activation == "relu" else y


def _int_activations(x) -> tuple[np.ndarray, float]:
    """The accelerator's 8-bit feature path: integer-valued inputs within
    int8 range pass through exactly; anything else is symmetric
    int8-quantized (its scale folds into the output)."""
    xf = np.asarray(x, dtype=np.float32)
    if np.array_equal(xf, np.rint(xf)) and np.abs(xf).max() <= 127:
        return xf.astype(np.int32), 1.0
    q8, s = ucr.quantize_int8(xf)
    return q8.astype(np.int32), float(np.asarray(s))


class Backend(abc.ABC):
    """One way to execute CoDR layers.  Layers are duck-typed
    (:class:`repro.core.engine.CodrConv2D` / ``CodrLinear`` or anything
    exposing the same ``code`` / ``kind`` / ``stride`` surface).

    The contract, in full:

    * Subclasses MUST set a non-empty ``name`` (the registry key), a
      ``caps`` :class:`BackendCaps` describing what they execute, and
      implement :meth:`conv`.  :meth:`linear` defaults to the layer's
      own fused tiled matmul (declare ``"linear"`` in
      ``caps.fallback_kinds`` when relying on that).
    * Callers MUST gate on :meth:`supports` /
      :meth:`supports_model` before executing — ``compile`` and
      ``CompiledModel.run(backend=...)`` do, so an execution method may
      assume its layer passed the capability check and is free to fail
      arbitrarily (not just ``ValueError``) on layers that did not.
    * Numerics: every datapath must end with the shared
      :meth:`finish` epilogue (bias, then activation) in that op order —
      cross-backend parity tests depend on it.  Integer-activation
      backends (``caps.integer_activations``) additionally quantize
      non-integer inputs to int8 first; their outputs match the
      dequantized oracle only near-exactly, not bit-for-bit.
    """

    name: str = ""
    caps: BackendCaps = BackendCaps()

    # -- capability queries -------------------------------------------------
    def supports(self, layer) -> tuple[bool, str]:
        """``(ok, reason)`` — can this backend execute ``layer``?

        ``ok=False`` comes with a human-readable ``reason`` (the string
        ``compile`` raises with).  The default implementation checks
        ``caps``: the layer kind must be native or a declared fallback,
        and a conv layer's stride must not exceed ``caps.max_stride``.
        Override for capability rules the flags cannot express; never
        raise from here — report, don't throw.
        """
        if not self.caps.supports_kind(layer.kind):
            return False, (f"backend {self.name!r} has no {layer.kind!r} "
                           f"path (native: {sorted(self.caps.native_kinds)})")
        stride = getattr(layer, "stride", 1)
        if layer.kind == "conv" and not self.caps.supports_stride(stride):
            return False, (f"backend {self.name!r} supports stride <= "
                           f"{self.caps.max_stride}, layer {layer.name!r} "
                           f"has stride {stride}")
        return True, ""

    def supports_model(self, layers) -> tuple[bool, str]:
        """``(ok, reason)`` over a whole layer stack: the first failing
        layer's reason, or ``(True, "")`` when every layer passes."""
        for layer in layers:
            ok, reason = self.supports(layer)
            if not ok:
                return False, reason
        return True, ""

    # -- execution ----------------------------------------------------------
    @abc.abstractmethod
    def conv(self, layer, x: jax.Array) -> jax.Array:
        """Forward one conv layer from its code.

        ``x`` is NHWC ``(B, RI, CI, N)``; returns NHWC
        ``(B, RO, CO, M)`` float32 with VALID padding and the layer's
        stride, scale, bias, and activation applied (end with
        :meth:`finish`).  May assume :meth:`supports` passed."""

    def linear(self, layer, x: jax.Array) -> jax.Array:
        """Forward one linear layer, ``(B, N)`` → ``(B, M)`` float32,
        scale/bias/activation applied.  Default: delegate to the layer's
        own fused tiled matmul (the ``fallback_kinds`` path)."""
        return layer(x)

    def step(self, layer, x: jax.Array) -> jax.Array:
        """Dispatch one layer by ``layer.kind``.  Raises ``ValueError``
        on kinds that are neither ``"conv"`` nor ``"linear"`` — kinds
        the capability check already rejects for built-ins."""
        if layer.kind == "conv":
            return self.conv(layer, x)
        if layer.kind == "linear":
            return self.linear(layer, x)
        raise ValueError(f"unknown layer kind {layer.kind!r}")

    def finish(self, layer, y: jax.Array) -> jax.Array:
        """The shared epilogue every datapath appends after its
        accumulators drain: ``+ bias`` (if any), then the activation.
        Public so custom backends reproduce the exact op order —
        bit-for-bit parity across backends depends on it."""
        return _finish(layer, y)

    def matmul(self, x: jax.Array, w) -> jax.Array:
        """Execute one packed projection leaf
        (:class:`repro.core.codr_linear.PackedLinear`):
        ``(..., K) @ dequantize(w) → (..., out_features)`` in ``x``'s
        dtype.  This is the transformer serving entry point —
        ``models.common.linear`` routes packed params leaves here.

        The default is decode-then-matmul with *exactly* the dense
        ``linear`` numerics (dequantized f32 weight cast to ``x.dtype``,
        then ``jnp.dot``), so a backend relying on it — ``tiled``,
        ``sharded`` — produces logits bit-for-bit equal to serving the
        quantize-applied dense params.  Kernel backends override with a
        fused datapath (``codr_matmul`` decodes in VMEM inside the MXU
        tiles, f32 accumulation — near-exact, not bit-for-bit).  Only
        meaningful when ``caps.packed_matmul`` is set; ``compile_params``
        gates on that flag."""
        return jnp.dot(x, w.dense().astype(x.dtype))

    def gather(self, tokens: jax.Array, w) -> jax.Array:
        """Embedding lookup on a packed vocabulary table
        (:class:`repro.core.codr_linear.PackedEmbedding`): gather the
        packed rows for ``tokens`` and decode only those.  The default
        row-gather decode is bit-for-bit equal to indexing the
        quantize-applied dense table, so every backend inherits exact
        parity with the dense reference lane; ``models.common.
        embedding_lookup`` routes packed embed leaves here."""
        return w.lookup(tokens)

    def unembed(self, x: jax.Array, w) -> jax.Array:
        """Logit projection ``x @ dense(w).T`` against a packed output
        embedding — decode-then-matmul with the dense ``unembed``
        numerics (dequantized f32 table cast to ``x.dtype``), bit-equal
        to serving the quantize-applied dense table."""
        return jnp.dot(x, w.dense().T.astype(x.dtype))

    def run_model(self, model, batch: jax.Array) -> jax.Array:
        """Forward a batch through a :class:`~repro.core.engine.CodrModel`
        (or any object exposing ``_chain``): casts to float32, chains
        :meth:`step` over the layers, auto-flattening at the
        conv→linear boundary.  Override to add whole-model structure
        (the ``tiled``/``sharded`` backends jit the entire chain once
        and cache it on the model)."""
        return model._chain(jnp.asarray(batch, jnp.float32), self.step)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Backend] = {}


def register(backend: Backend, *, overwrite: bool = False) -> Backend:
    """Add a backend instance to the registry (name taken from the
    instance).  Future executors — sharded, async, TPU-tuned — register
    here and become selectable everywhere a backend name is accepted."""
    if not backend.name:
        raise ValueError("backend must set a non-empty .name")
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[backend.name] = backend
    return backend


def available_backends() -> tuple[str, ...]:
    """Registered backend names, registration order."""
    return tuple(_REGISTRY)


def get_backend(name: str) -> Backend:
    """Look up a registered backend by name.  Raises ``ValueError``
    naming the registered alternatives on a miss — the same error
    surface ``compile(..., backend="typo")`` shows."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; registered: "
                         f"{', '.join(_REGISTRY) or '(none)'}") from None


def resolve(backend: str | Backend) -> Backend:
    """Accept a registered name or a Backend instance."""
    if isinstance(backend, Backend):
        return backend
    return get_backend(backend)


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------

class TiledBackend(Backend):
    """Fused XLA tile dispatch (default): each layer's decoded tile stack
    collapses into ONE ``lax.conv`` / matmul per layer, the whole model
    chain jitted once per input shape (compile-once contract)."""

    name = "tiled"
    caps = BackendCaps(packed_matmul=True,
                       description="fused lax.conv/matmul tile dispatch, "
                                   "any stride, float datapath")

    def conv(self, layer, x):
        return layer(x)

    def run_model(self, model, batch):
        # whole-model jitted chain, cached on the model — XLA fuses across
        # layer boundaries; repeat same-shape requests re-trace nothing
        if model._run_tiled is None:
            model._run_tiled = jax.jit(
                lambda x: model._chain(x, lambda l, xx: l(xx)))
        return model._run_tiled(jnp.asarray(batch, jnp.float32))


class SmmBackend(Backend):
    """Faithful MPE/APE execution model in NumPy
    (:func:`repro.core.smm.conv2d_smm_batched`): differential
    scalar–matrix multiplies + crossbar routing, bit-exact in int32,
    broadcasting every routed window over the batch axis."""

    name = "smm"
    caps = BackendCaps(integer_activations=True,
                       native_kinds=frozenset({"conv"}),
                       fallback_kinds=frozenset({"linear"}),
                       description="NumPy faithful MPE/APE execution "
                                   "(8-bit feature path)")

    def conv(self, layer, x):
        xi, x_scale = _int_activations(x)
        scale = float(np.asarray(layer.code.scale)) * x_scale
        outs = smm.conv2d_smm_batched(np.moveaxis(xi, 3, 1), layer.code,
                                      layer.stride)
        return _finish(layer, jnp.asarray(np.moveaxis(outs, 1, 3),
                                          jnp.float32) * scale)


class SmmKernelBackend(Backend):
    """Pallas MPE/APE kernel (:mod:`repro.kernels.smm_conv`): the whole
    batch in one dispatch via a batch grid dimension, operands packed
    once per layer and cached on it."""

    name = "smm_kernel"
    _caps: BackendCaps | None = None

    @property
    def caps(self) -> BackendCaps:
        # resolved lazily from the kernel's own KERNEL_CAPS so merely
        # importing repro.core never pulls in jax.experimental.pallas
        if self._caps is None:
            from repro.kernels.smm_conv import ops as smm_ops
            kc = smm_ops.KERNEL_CAPS
            self._caps = BackendCaps(
                integer_activations=kc["integer_activations"],
                max_stride=kc["max_stride"],
                native_kinds=frozenset(kc["kinds"]),
                # linear layers fall back to the fused tiled matmul — a
                # backend policy, not a kernel fact
                fallback_kinds=frozenset({"linear"}),
                description=kc["description"])
        return self._caps

    def conv(self, layer, x):
        from repro.kernels.smm_conv import smm_conv_batched
        xi, x_scale = _int_activations(x)
        scale = float(np.asarray(layer.code.scale)) * x_scale
        y = smm_conv_batched(jnp.asarray(np.moveaxis(xi, 3, 1), jnp.float32),
                             layer.code, stride=layer.stride,
                             operands=layer.smm_operands())
        return _finish(layer, jnp.moveaxis(y, 1, 3) * scale)


class CodrMatmulBackend(Backend):
    """Pallas fused decode+matmul (:mod:`repro.kernels.codr_matmul`):
    linear layers execute from the fixed-width unique-index pack, the
    table gather fused into the MXU tiles.  Linear-only — a model with
    conv layers is rejected at compile time via :meth:`supports`."""

    name = "codr_matmul"
    _caps: BackendCaps | None = None

    @property
    def caps(self) -> BackendCaps:
        if self._caps is None:
            from repro.kernels.codr_matmul import ops as mm_ops
            kc = mm_ops.KERNEL_CAPS
            self._caps = BackendCaps(
                native_kinds=frozenset(kc["kinds"]),
                integer_activations=kc["integer_activations"],
                packed_matmul=kc.get("packed_matmul", False),
                description=kc["description"])
        return self._caps

    def conv(self, layer, x):                      # pragma: no cover
        raise NotImplementedError("codr_matmul is linear-only")

    def matmul(self, x, w):
        """Fused decode+matmul from the packed bitstream: the table
        gather happens in VMEM inside the MXU tiles (interpret mode on
        CPU).  f32 accumulation — matches the dense reference to float
        tolerance, tighter than the bf16 dot it replaces."""
        from repro.kernels.codr_matmul import codr_matmul
        if w.weight.packed.ndim != 2:
            raise ValueError(
                "codr_matmul executes per-matrix packed operands; got a "
                f"stacked pack of shape {w.weight.packed.shape} — slice "
                "the stack axis (lax.scan does) or decode via "
                "dense_weight() first")
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        y = codr_matmul(x2, w.weight)[:, : w.out_features]
        return y.reshape(*lead, w.out_features).astype(x.dtype)

    def linear(self, layer, x):
        from repro.core.codr_linear import pack_unique
        from repro.kernels.codr_matmul import codr_matmul
        packed = getattr(layer, "_mm_packed", None)
        if packed is None:
            # decoded (M, N) int8 → (K=N_in, N=M_out) pack; pad M_out to
            # a multiple of 32 — every per-word width pack_unique may
            # choose divides 32, so the pack always lines up whatever
            # bit-length the (possibly pad-grown) unique table needs —
            # and crop the extra columns after the matmul
            q = layer.decoded_weights().T            # (N_in, M_out) int8
            pad = (-q.shape[1]) % 32
            if pad:
                q = np.pad(q, ((0, 0), (0, pad)))
            packed = pack_unique(q, float(np.asarray(layer.code.scale)),
                                 dtype=jnp.float32)
            layer._mm_packed = packed
        m = layer.code.shape[0]
        y = codr_matmul(jnp.asarray(x, jnp.float32), packed)[:, :m]
        return _finish(layer, y)


class ShardedBackend(Backend):
    """Tile-parallel scale-out executor: each layer's decoded tile stack
    is partitioned across devices over the **output-tile axis** — the
    CoDR loop nest's natural model-parallel dimension, since every
    output-channel tile's results are produced exactly once (output
    stationary) while the input is broadcast to all tiles (semi input
    stationary, paper §III-B).  Mapping that dataflow onto a mesh:

    * the tile stack ``(n_tiles, t_m, N, RK, CK)`` is zero-padded to a
      multiple of the device count and ``jax.device_put`` once, sharded
      over its leading axis (:func:`repro.sharding.rules.shard_leading`);
    * the forward is a ``shard_map`` over the 1-D ``tile`` mesh
      (:func:`repro.sharding.rules.tile_mesh`): every device runs ONE
      ``lax.conv`` / matmul on its local tile slice with the batch
      replicated, and the output concatenates over the channel axis with
      no cross-device collective in the hot loop;
    * pad channels are cropped and the scale/bias/activation epilogue is
      applied on the gathered output — elementwise, so results are
      **bit-for-bit identical** to the ``tiled`` backend's fused
      single-device dispatch (per-output-channel reductions are
      independent of the channel split).

    On a single device the 1-element mesh makes ``shard_map`` the
    identity partitioning — the fallback that keeps 1-device CI green —
    and the same code scales to any local device count, including a
    forced host-platform mesh
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

    Constructor args:
        ``mesh``: a 1-D :class:`jax.sharding.Mesh` whose only axis is
        the tile axis; ``None`` (default) builds one over all local
        devices on first use.  Pass an explicit mesh to pin the executor
        to a device subset: ``register(ShardedBackend(mesh, name="..."))``.
    """

    name = "sharded"
    caps = BackendCaps(packed_matmul=True,
                       description="shard_map tile-parallel dispatch over "
                                   "the output-tile axis, any stride, "
                                   "float datapath, 1-device fallback")

    # fault-injection hook (class attr: zero cost until installed; see
    # repro.runtime.resilience — site "sharded.dispatch")
    _injector = None

    def __init__(self, mesh=None, *, name: str | None = None):
        self._mesh = mesh
        if name is not None:
            self.name = name

    def set_fault_injector(self, injector) -> "ShardedBackend":
        """Install (or clear, with ``None``) a ``FaultInjector`` firing
        the ``"sharded.dispatch"`` site on every whole-model dispatch —
        the hook chaos runs use to simulate a lost mesh device."""
        self._injector = injector
        return self

    @property
    def mesh(self):
        if self._mesh is None:
            from repro.sharding import rules
            self._mesh = rules.tile_mesh()
        return self._mesh

    @property
    def n_devices(self) -> int:
        from repro.sharding import rules
        return self.mesh.shape[rules.ENGINE_TILE_AXIS]

    # -- per-layer preparation ---------------------------------------------
    def _prepare(self, layer):
        """Shard ``layer``'s decoded tiles over the mesh (once per layer
        per mesh) and build the jitted shard_map forward.  Cached on the
        layer — repeat dispatches reuse the committed device buffers."""
        state = getattr(layer, "_shard_state", None)
        # Mesh defines value equality: an equal-but-distinct mesh (two
        # backends built over the same devices) still hits the cache
        if state is not None and state[0] == self.mesh:
            return state
        from repro.sharding import rules
        axis = rules.ENGINE_TILE_AXIS
        mesh = self.mesh
        t = layer.tiles.astype(np.float32)    # (n_tiles, t_m, N[, RK, CK])
        if layer.kind == "linear":
            t = t.reshape(t.shape[0], t.shape[1], -1)
        w_sh = rules.shard_leading(t, mesh, axis=axis)
        scale = float(np.asarray(layer.code.scale))
        m = layer.code.shape[0]

        if layer.kind == "conv":
            stride = (layer.stride, layer.stride)

            def local(x, tiles):
                # local slice (n_tiles/D, t_m, N, RK, CK) → one conv per
                # device; out_spec concatenates over the channel axis
                w = tiles.reshape(tiles.shape[0] * tiles.shape[1],
                                  *tiles.shape[2:])
                return jax.lax.conv_general_dilated(
                    x, w, window_strides=stride, padding="VALID",
                    dimension_numbers=("NHWC", "OIHW", "NHWC"))

            sm = _shard_map(local, mesh=mesh, in_specs=(_P(), _P(axis)),
                            out_specs=_P(None, None, None, axis))

            def fwd(x, w_sharded):
                return _finish(layer, sm(x, w_sharded)[..., :m] * scale)
        else:

            def local(x, tiles):
                w = tiles.reshape(tiles.shape[0] * tiles.shape[1], -1)
                return x @ w.T

            sm = _shard_map(local, mesh=mesh, in_specs=(_P(), _P(axis)),
                            out_specs=_P(None, axis))

            def fwd(x, w_sharded):
                return _finish(layer, sm(x, w_sharded)[:, :m] * scale)

        state = (mesh, w_sh, jax.jit(fwd))
        layer._shard_state = state
        return state

    # -- execution ----------------------------------------------------------
    def conv(self, layer, x):
        _, w_sh, fwd = self._prepare(layer)
        return fwd(jnp.asarray(x, jnp.float32), w_sh)

    linear = conv

    def run_model(self, model, batch):
        # whole-model jitted chain (compile-once, like TiledBackend) —
        # per-layer shard_maps inline into one computation, the sharded
        # tile buffers staying device-resident across requests
        if self._injector is not None:
            self._injector.fire("sharded.dispatch")
        state = getattr(model, "_run_sharded", None)
        if state is None or state[0] != self.mesh:
            for layer in model.layers:
                self._prepare(layer)
            fn = jax.jit(lambda x: model._chain(x, self.step))
            model._run_sharded = state = (self.mesh, fn)
        return state[1](jnp.asarray(batch, jnp.float32))


register(TiledBackend())
register(SmmBackend())
register(SmmKernelBackend())
register(CodrMatmulBackend())
register(ShardedBackend())
