"""RLE decode-throughput benchmark: scalar ``rle.decode_vector`` (the
parity oracle) vs the vectorized bulk decoder ``rle.decode_layer`` on
paper-CNN layer shapes (§V-A nets, paper-style sparse weights).

  PYTHONPATH=src python benchmarks/decode.py [--small] [--json PATH]

CSV lines (harness format): ``name,us_per_call,derived`` with decoded
MB/s, vectors/s and the bulk-vs-scalar speedup per layer; a JSON summary
(default ``BENCH_decode.json``) records the numbers so the perf
trajectory is tracked PR over PR.  Parity of the two decoders is
asserted on every benchmarked layer.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

try:
    from benchmarks.common import Timer, bench_meta, csv_line
except ImportError:                                   # run as a script
    import os
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.common import Timer, bench_meta, csv_line

from repro.core import rle, ucr

# (name, net, layer index, density) — paper §V-A geometry; spatial dims
# are irrelevant to weight decode so the shape table is used directly.
FULL_LAYERS = [
    ("alexnet_conv2", "alexnet", 1, 0.5),
    ("vgg16_conv3", "vgg16", 2, 0.2),
    ("googlenet_inc4", "googlenet", 4, 0.6),
]
SMALL_LAYERS = [
    ("alexnet_conv2_s", "alexnet", 1, 0.5),
]
SCALAR_SAMPLE = 192            # scalar path timed on a vector sample


def build_code(net: str, idx: int, density: float, *, small: bool,
               rng) -> ucr.LayerCode:
    from repro.configs.paper_cnns import PAPER_CNNS
    s = PAPER_CNNS[net][idx]
    m, n = (s.m, s.n) if not small else (max(s.m // 8, 4), max(s.n // 8, 2))
    w = rng.normal(size=(m, n, s.rk, s.ck)).astype(np.float32) * 0.5
    w[rng.random(w.shape) > density] = 0
    return ucr.encode_conv_layer(w, t_m=4, t_n=4)


def bench_layer(name: str, code: ucr.LayerCode) -> dict:
    n_vec = len(code.vectors)
    payload_mb = code.total_bits / 8 / 1e6

    sample = code.vectors[:min(SCALAR_SAMPLE, n_vec)]
    with Timer() as t_scalar:
        scalar_out = [rle.decode_vector(v) for v in sample]
    scalar_s = t_scalar.dt / len(sample) * n_vec      # extrapolated

    with Timer() as t_bulk:
        bulk = rle.decode_layer(code)
    for i, want in enumerate(scalar_out):             # bit-exact parity
        if not np.array_equal(bulk[i, : len(want)], want):
            raise AssertionError(f"{name}: bulk decode != scalar oracle "
                                 f"at vector {i}")

    return {
        "layer": name,
        "shape": list(code.shape),
        "n_vectors": n_vec,
        "payload_mb": payload_mb,
        "scalar_s": scalar_s,
        "bulk_s": t_bulk.dt,
        "scalar_mb_s": payload_mb / scalar_s,
        "bulk_mb_s": payload_mb / t_bulk.dt,
        "scalar_vectors_s": n_vec / scalar_s,
        "bulk_vectors_s": n_vec / t_bulk.dt,
        "speedup": scalar_s / t_bulk.dt,
    }


def main(small: bool = False, json_path: str | None = "BENCH_decode.json"
         ) -> list[dict]:
    rng = np.random.default_rng(0)
    results = []
    for name, net, idx, density in (SMALL_LAYERS if small else FULL_LAYERS):
        code = build_code(net, idx, density, small=small, rng=rng)
        r = bench_layer(name, code)
        results.append(r)
        print(csv_line(
            f"decode_bulk_{name}", r["bulk_s"] / r["n_vectors"] * 1e6,
            f"bulk_mb_s={r['bulk_mb_s']:.1f};"
            f"bulk_vectors_s={r['bulk_vectors_s']:.0f};"
            f"scalar_mb_s={r['scalar_mb_s']:.2f};"
            f"speedup={r['speedup']:.1f}x"))
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"benchmark": "decode", "small": small,
                       "meta": bench_meta(t_m=4, t_n=4),
                       "layers": results}, f, indent=2)
    return results


def cli(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="tiny layers (CI smoke run)")
    ap.add_argument("--json", default="BENCH_decode.json",
                    help="JSON output path ('' disables)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    main(small=args.small, json_path=args.json or None)


if __name__ == "__main__":
    cli()
