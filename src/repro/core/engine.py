"""CoDR inference engine: encode once, run many (paper §II-D + §III-B).

This module connects the previously separate pieces — the offline
UCR + customized-RLE encoder (:mod:`repro.core.ucr`,
:mod:`repro.core.rle`), the scalar–matrix-multiplication execution model
(:mod:`repro.core.smm`, :mod:`repro.kernels.smm_conv`), and the dataflow
SRAM accounting (:mod:`repro.core.dataflow`) — into an executable model:

* :class:`CodrConv2D` / :class:`CodrLinear` — one layer each.  At
  construction the float weights run through the paper's offline pipeline
  exactly once (quantize → tile → sort/densify/unify → Δ → RLE
  bitstreams).  The float weights are kept only as the test oracle; the
  layer *executes* from the bitstreams.
* **Decode-on-dispatch** — the first forward pass decodes each output
  tile's weight vectors from the real RLE bitstreams
  (:func:`repro.core.rle.decode_vector`), proving the stored code is
  executable, and caches the int8 tiles (offline decode is once-per-model,
  §II-D: "zero on-chip overhead").
* **Input/output-stationary tiled dispatch** — the forward pass maps the
  CoDR loop nest (Fig. 5a): output-channel tiles are the outer loop, each
  tile's outputs are produced exactly once (output stationary) while the
  full input batch is broadcast to every tile (semi input stationary).
  Implemented as a ``vmap`` over the stacked decoded tiles around
  ``jax.lax.conv_general_dilated``.
* :class:`CodrModel` — chains layers (conv → conv → … → linear) over
  NHWC batches, auto-flattening at the conv→linear boundary, with a dense
  ``jax.lax.conv`` reference oracle for every layer and per-layer SRAM
  access estimates from :func:`repro.core.dataflow.codr_accesses`.

Backends are first class (:mod:`repro.core.backends`): ``run`` resolves
its ``backend`` argument — a registered name or a ``Backend`` instance —
through the registry; there is no string dispatch here.  Built-ins:
``tiled`` (fused lax.conv, default), ``smm`` (NumPy faithful MPE/APE),
``smm_kernel`` (Pallas MPE/APE), ``codr_matmul`` (fused decode+matmul,
linear-only).  The ``smm*`` backends run the differential
scalar–matrix-multiply mechanism itself on the 8-bit feature datapath.

.. deprecated::
    Constructing ``CodrConv2D`` / ``CodrLinear`` / ``CodrModel`` directly
    is the legacy path.  New code should go through the spec → compile →
    serve API (:mod:`repro.core.api`, exported as ``repro.api``):
    ``codr.compile(ModelSpec(...), EncodeConfig(...))``.  These classes
    remain as thin shims over the same internals.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backends as _backends
from repro.core import dataflow, rle, ucr
from repro.core.dataflow import CODR_TILING, ConvShape

__all__ = [
    "CodrConv2D", "CodrLinear", "CodrModel", "LayerStats",
    "build_random_model", "paper_model_shapes",
]


# ---------------------------------------------------------------------------
# per-layer statistics
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerStats:
    name: str
    kind: str                      # "conv" | "linear"
    shape: tuple[int, ...]
    n_weights: int
    encoded_bits: int
    bits_per_weight: float
    density: float
    n_unique: int                  # sum of per-vector unique counts
    n_nonzero: int
    n_unique_budget: int = 256     # the U budget the layer encoded under
    t_m: int = 4                   # EFFECTIVE output tile (clamped to M —
    t_n: int = 4                   # never the requested t_m_linear)


def _layer_stats(name: str, kind: str, code: ucr.LayerCode,
                 n_unique_budget: int = 256) -> LayerStats:
    n_unique = sum(len(u.unique_vals) for u in code.ucr)
    n_nonzero = sum(u.n_nonzero for u in code.ucr)
    # the effective tile, not the requested one: a linear layer with
    # out-features < t_m_linear encodes (and costs) at M — reporting the
    # request here would skew the cost-model comparison the tuner uses
    return LayerStats(
        name=name, kind=kind, shape=code.shape, n_weights=code.n_weights,
        encoded_bits=code.total_bits, bits_per_weight=code.bits_per_weight,
        density=n_nonzero / max(code.n_weights, 1),
        n_unique=n_unique, n_nonzero=n_nonzero,
        n_unique_budget=n_unique_budget,
        t_m=min(code.t_m, code.shape[0]), t_n=code.t_n)


# ---------------------------------------------------------------------------
# bitstream → dense tiles (decode-on-dispatch)
# ---------------------------------------------------------------------------

def decode_all_tiles(code: ucr.LayerCode, *,
                     source: str = "bitstream") -> np.ndarray:
    """All tiles, stacked: int8 ``(n_tiles, t_m, N, RK, CK)``.

    ``source="bitstream"`` decodes the real RLE bitstreams — the whole
    layer in one vectorized pass (:func:`repro.core.rle.decode_layer`, no
    per-vector Python loop; the scalar ``rle.decode_vector`` survives
    only as the parity oracle in the tests); ``source="ucr"`` rebuilds
    from the retained UCR vectors (bit-identical — benchmark shortcut).
    """
    n_tiles = -(-code.shape[0] // code.t_m)
    n = code.shape[1]
    rk, ck = (code.shape[2], code.shape[3]) if len(code.shape) == 4 else (1, 1)
    pad_to = code.t_m * rk * ck
    if source == "bitstream":
        flat = rle.decode_layer(code, pad_to=pad_to)
    elif source == "ucr":
        flat = np.zeros((len(code.ucr), pad_to), dtype=np.int8)
        for i, u in enumerate(code.ucr):
            flat[i, : u.vector_len] = ucr.ucr_reconstruct(u)
    else:
        raise ValueError(f"unknown decode source {source!r} "
                         f"(expected 'bitstream' or 'ucr')")
    return np.ascontiguousarray(
        flat.reshape(n_tiles, n, code.t_m, rk, ck).transpose(0, 2, 1, 3, 4))


def decode_tile(code: ucr.LayerCode, mt: int, *,
                source: str = "bitstream") -> np.ndarray:
    """Decode output-channel tile ``mt`` of a layer's code through the
    vectorized bulk decoder (:func:`repro.core.rle.decode_layer`), fed
    only that tile's vectors — O(tile), not O(layer).  The old
    per-vector scalar bit-loop lives on only as the parity oracle in
    ``tests/test_engine.py``.

    Returns int8 ``(t_m, N, RK, CK)``; rows past the true output-channel
    count (ragged last tile) are zero.  Vector order inside a tile is
    ascending input channel — the order ``ucr._iter_tile_vectors`` emits.
    """
    n = code.shape[1]
    rk, ck = (code.shape[2], code.shape[3]) if len(code.shape) == 4 else (1, 1)
    pad_to = code.t_m * rk * ck
    if source == "bitstream":
        flat = rle.decode_layer(code.vectors[mt * n:(mt + 1) * n],
                                pad_to=pad_to)
    elif source == "ucr":
        flat = np.zeros((n, pad_to), dtype=np.int8)
        for i, u in enumerate(code.ucr[mt * n:(mt + 1) * n]):
            flat[i, : u.vector_len] = ucr.ucr_reconstruct(u)
    else:
        raise ValueError(f"unknown decode source {source!r} "
                         f"(expected 'bitstream' or 'ucr')")
    return np.ascontiguousarray(
        flat.reshape(n, code.t_m, rk, ck).transpose(1, 0, 2, 3))


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------

class CodrConv2D:
    """A conv layer executed from its CoDR code (valid padding, NHWC).

    ``w`` is float ``(M, N, RK, CK)`` (OIHW); encoding happens once here.
    """

    kind = "conv"

    def __init__(self, w: np.ndarray, bias: np.ndarray | None = None, *,
                 stride: int = 1, t_m: int = 4, t_n: int = 4,
                 activation: str | None = None, name: str = "conv",
                 decode_source: str = "bitstream", n_unique: int = 256,
                 rle_params: tuple[int, int, int] | None = None):
        w = np.asarray(w, dtype=np.float32)
        assert w.ndim == 4, "conv weights must be (M, N, RK, CK)"
        self.name = name
        self.stride = int(stride)
        self.activation = activation
        self.decode_source = decode_source
        self.n_unique = int(n_unique)
        self.code = ucr.encode_conv_layer(w, t_m=t_m, t_n=t_n,
                                          n_unique=n_unique,
                                          params=rle_params)
        self.bias = None if bias is None else np.asarray(bias, np.float32)
        self._w_ref = w                      # oracle only — never executed
        self._tiles: np.ndarray | None = None  # decoded int8 tile cache
        self._tiles_dev: jax.Array | None = None
        self._forward = None                   # jitted dispatch cache
        self._trace_count = 0                  # times the forward re-traced
        self._smm_ops = None                   # packed SMM kernel operands
        self._shard_state = None               # sharded-backend tile cache

    # -- offline decode -----------------------------------------------------
    @property
    def tiles(self) -> np.ndarray:
        if self._tiles is None:
            self._tiles = decode_all_tiles(self.code,
                                           source=self.decode_source)
        return self._tiles

    @property
    def tiles_device(self) -> jax.Array:
        if self._tiles_dev is None:
            # concrete even when first touched inside a jit trace (the
            # model-level chain) — the cached buffer must never be a tracer
            with jax.ensure_compile_time_eval():
                self._tiles_dev = jnp.asarray(self.tiles, jnp.float32)
        return self._tiles_dev

    def decoded_weights(self) -> np.ndarray:
        """Dense int8 ``(M, N, RK, CK)`` rebuilt from the bitstreams."""
        t = self.tiles
        m = self.code.shape[0]
        return t.reshape(-1, *t.shape[2:])[:m]

    def verify_roundtrip(self) -> None:
        """Bitstream decode must equal direct quantization (plus any
        unique-level restriction) of the floats."""
        q, _ = ucr.quantize_int8(self._w_ref)
        q = ucr.restrict_unique(q, self.n_unique)
        if not np.array_equal(self.decoded_weights(), q):
            raise AssertionError(f"{self.name}: UCR+RLE roundtrip mismatch")

    # -- stats --------------------------------------------------------------
    def stats(self) -> LayerStats:
        return _layer_stats(self.name, self.kind, self.code,
                            n_unique_budget=self.n_unique)

    def out_hw(self, ri: int, ci: int) -> tuple[int, int]:
        rk, ck = self.code.shape[2], self.code.shape[3]
        return ((ri - rk) // self.stride + 1, (ci - ck) // self.stride + 1)

    def conv_shape(self, ri: int, ci: int) -> ConvShape:
        m, n, rk, ck = self.code.shape
        return ConvShape(m, n, rk, ck, ri, ci, self.stride)

    # -- execution ----------------------------------------------------------
    @property
    def trace_count(self) -> int:
        """Times the jitted forward was (re-)traced.  Compile-once
        contract: one trace per distinct input shape, ever — repeat
        requests hit the compile cache."""
        return self._trace_count

    def _build_forward(self):
        scale = float(np.asarray(self.code.scale))
        m = self.code.shape[0]
        stride = (self.stride, self.stride)
        # concrete even when built lazily inside an outer (model-level)
        # jit trace — a traced constant here would leak into later traces
        with jax.ensure_compile_time_eval():
            bias = None if self.bias is None else jnp.asarray(self.bias)
        act = self.activation

        def forward(x, tiles_f32):
            # codrlint: disable=jit-purity — retrace counter: runs at trace time only, mutates host state, never the trace
            self._trace_count += 1
            # tiles (n_tiles, t_m, N, RK, CK) fuse into ONE conv dispatch:
            # the output-channel tiling stays the storage/SRAM format, and
            # every tile's output-channel slice y[..., mt*t_m:(mt+1)*t_m]
            # is still produced exactly once (output stationary) — but the
            # MXU sees a single large conv instead of n_tiles tiny ones
            t, tm = tiles_f32.shape[0], tiles_f32.shape[1]
            w = tiles_f32.reshape(t * tm, *tiles_f32.shape[2:])[:m]
            y = jax.lax.conv_general_dilated(
                x, w, window_strides=stride, padding="VALID",
                dimension_numbers=("NHWC", "OIHW", "NHWC")) * scale
            if bias is not None:
                y = y + bias
            if act == "relu":
                y = jax.nn.relu(y)
            return y

        return jax.jit(forward)

    def __call__(self, x: jax.Array) -> jax.Array:
        """``x``: NHWC ``(B, RI, CI, N)`` float32 → ``(B, RO, CO, M)``.

        Compile-once: the jitted dispatch is built on first call and its
        compile cache is keyed by input shape; the decoded tile buffer
        lives on device once (:attr:`tiles_device`) and is reused by every
        request — no per-request host→device traffic or re-tracing.
        """
        if self._forward is None:
            self._forward = self._build_forward()
        return self._forward(jnp.asarray(x, jnp.float32), self.tiles_device)

    def reference(self, x: jax.Array) -> jax.Array:
        """Dense ``jax.lax.conv`` oracle on the ORIGINAL float weights."""
        y = jax.lax.conv_general_dilated(
            jnp.asarray(x, jnp.float32), jnp.asarray(self._w_ref),
            window_strides=(self.stride, self.stride), padding="VALID",
            dimension_numbers=("NHWC", "OIHW", "NHWC"))
        if self.bias is not None:
            y = y + jnp.asarray(self.bias)
        return jax.nn.relu(y) if self.activation == "relu" else y

    def smm_operands(self):
        """Padded SMM kernel operands, packed once per layer and cached on
        device — every dispatch (any batch size) reuses them."""
        if self._smm_ops is None:
            from repro.kernels.smm_conv import pack_smm_operands
            deltas, entries, meta = pack_smm_operands(self.code,
                                                      self.code.shape[1])
            self._smm_ops = (jnp.asarray(deltas), jnp.asarray(entries), meta)
        return self._smm_ops

    def smm_forward(self, x: jax.Array, *, kernel: bool = False) -> jax.Array:
        """Deprecated shim: run the differential SMM mechanism via the
        backend registry — ``kernel=False`` → the ``smm`` backend (NumPy
        faithful execution), ``kernel=True`` → ``smm_kernel`` (Pallas).
        New code selects the backend by name at compile/run time instead
        (:mod:`repro.core.backends`)."""
        backend = _backends.get_backend("smm_kernel" if kernel else "smm")
        return backend.conv(self, x)


class CodrLinear:
    """A fully-connected layer executed from its CoDR code.

    ``w`` is float ``(M, N)`` = (out features, in features) — a conv with a
    1×1 kernel (paper Fig. 1); a weight *column* is one UCR vector.
    """

    kind = "linear"

    def __init__(self, w: np.ndarray, bias: np.ndarray | None = None, *,
                 t_m: int = 256, activation: str | None = None,
                 name: str = "linear", decode_source: str = "bitstream",
                 n_unique: int = 256,
                 rle_params: tuple[int, int, int] | None = None):
        w = np.asarray(w, dtype=np.float32)
        assert w.ndim == 2, "linear weights must be (M, N)"
        self.name = name
        self.activation = activation
        self.decode_source = decode_source
        self.n_unique = int(n_unique)
        self.code = ucr.encode_linear_layer(w, t_m=min(t_m, w.shape[0]),
                                            n_unique=n_unique,
                                            params=rle_params)
        self.bias = None if bias is None else np.asarray(bias, np.float32)
        self._w_ref = w
        self._tiles: np.ndarray | None = None
        self._tiles_dev: jax.Array | None = None
        self._forward = None
        self._trace_count = 0
        self._shard_state = None               # sharded-backend tile cache

    @property
    def tiles(self) -> np.ndarray:
        if self._tiles is None:
            self._tiles = decode_all_tiles(self.code,  # (T, t_m, N, 1, 1)
                                           source=self.decode_source)
        return self._tiles

    @property
    def tiles_device(self) -> jax.Array:
        if self._tiles_dev is None:         # (T, t_m, N), reshaped once
            t = self.tiles
            with jax.ensure_compile_time_eval():
                self._tiles_dev = jnp.asarray(
                    t.reshape(t.shape[0], t.shape[1], -1), jnp.float32)
        return self._tiles_dev

    def decoded_weights(self) -> np.ndarray:
        t = self.tiles
        m, n = self.code.shape[0], self.code.shape[1]
        return t.reshape(-1, n)[:m]

    def verify_roundtrip(self) -> None:
        q, _ = ucr.quantize_int8(self._w_ref)
        q = ucr.restrict_unique(q, self.n_unique)
        if not np.array_equal(self.decoded_weights(), q):
            raise AssertionError(f"{self.name}: UCR+RLE roundtrip mismatch")

    def stats(self) -> LayerStats:
        return _layer_stats(self.name, self.kind, self.code,
                            n_unique_budget=self.n_unique)

    @property
    def trace_count(self) -> int:
        return self._trace_count

    def _build_forward(self):
        scale = float(np.asarray(self.code.scale))
        m = self.code.shape[0]
        with jax.ensure_compile_time_eval():
            bias = None if self.bias is None else jnp.asarray(self.bias)
        act = self.activation

        def forward(x, tiles_f32):
            # codrlint: disable=jit-purity — retrace counter: runs at trace time only, mutates host state, never the trace
            self._trace_count += 1
            # (T, t_m, N) decoded tiles fused into one matmul; each tile's
            # output slice y[:, mt*t_m:(mt+1)*t_m] still written once
            t, tm = tiles_f32.shape[0], tiles_f32.shape[1]
            w = tiles_f32.reshape(t * tm, -1)[:m]
            y = (x @ w.T) * scale
            if bias is not None:
                y = y + bias
            if act == "relu":
                y = jax.nn.relu(y)
            return y

        return jax.jit(forward)

    def __call__(self, x: jax.Array) -> jax.Array:
        """``x``: ``(B, N)`` float32 → ``(B, M)`` (compile-once, see
        :meth:`CodrConv2D.__call__`)."""
        if self._forward is None:
            self._forward = self._build_forward()
        return self._forward(jnp.asarray(x, jnp.float32), self.tiles_device)

    def reference(self, x: jax.Array) -> jax.Array:
        y = jnp.asarray(x, jnp.float32) @ jnp.asarray(self._w_ref).T
        if self.bias is not None:
            y = y + jnp.asarray(self.bias)
        return jax.nn.relu(y) if self.activation == "relu" else y


# ---------------------------------------------------------------------------
# model = chained layers
# ---------------------------------------------------------------------------

class CodrModel:
    """A stack of CoDR layers with an end-to-end dense oracle.

    ``run`` executes from the RLE bitstreams (decoded on first dispatch);
    ``reference`` runs the original float weights through dense
    ``jax.lax.conv`` / matmul — the golden parity target within int8
    quantization tolerance.

    .. deprecated:: prefer ``repro.api.compile(spec, config)`` — it
        builds this class internally and returns a
        :class:`repro.core.api.CompiledModel` wrapper.
    """

    def __init__(self, layers: Sequence[CodrConv2D | CodrLinear]):
        self.layers = list(layers)
        self._run_tiled = None            # jitted whole-model chain cache
        self._run_sharded = None          # (mesh, jitted chain) — sharded

    def _chain(self, x: jax.Array, step) -> jax.Array:
        for layer in self.layers:
            if layer.kind == "linear" and x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            x = step(layer, x)
        return x

    @property
    def trace_count(self) -> int:
        """Total layer re-traces — flat across repeat same-shape calls."""
        return sum(l.trace_count for l in self.layers)

    def __call__(self, batch: jax.Array, *,
                 backend: str | _backends.Backend = "tiled") -> jax.Array:
        return self.run(batch, backend=backend)

    def run(self, batch: jax.Array, *,
            backend: str | _backends.Backend = "tiled") -> jax.Array:
        """Forward an NHWC batch through the compressed model.

        ``backend`` is resolved through the registry
        (:mod:`repro.core.backends`) — a registered name or a ``Backend``
        instance; there is no string dispatch here.  The default
        ``tiled`` backend compiles the whole model ONCE: the per-layer
        forwards inline into a single jitted chain (XLA fuses across
        layer boundaries), cached per input shape — repeat same-shape
        requests re-trace nothing, see :attr:`trace_count`.
        """
        return _backends.resolve(backend).run_model(self, batch)

    def reference(self, batch: jax.Array) -> jax.Array:
        """Dense float oracle (uncompressed weights)."""
        return self._chain(batch, lambda l, x: l.reference(x))

    def quantized_reference(self, batch: jax.Array) -> jax.Array:
        """Dense oracle on the DEQUANTIZED decoded weights — ``run`` must
        match this exactly up to float summation order."""
        def step(l, x):
            w = l.decoded_weights().astype(np.float32) \
                * float(np.asarray(l.code.scale))
            if l.kind == "conv":
                y = jax.lax.conv_general_dilated(
                    jnp.asarray(x, jnp.float32), jnp.asarray(w),
                    window_strides=(l.stride, l.stride), padding="VALID",
                    dimension_numbers=("NHWC", "OIHW", "NHWC"))
            else:
                y = jnp.asarray(x, jnp.float32) @ jnp.asarray(w).T
            if l.bias is not None:
                y = y + jnp.asarray(l.bias)
            return jax.nn.relu(y) if l.activation == "relu" else y

        return self._chain(batch, step)

    # -- bookkeeping --------------------------------------------------------
    def verify_roundtrip(self) -> None:
        for layer in self.layers:
            layer.verify_roundtrip()

    def stats(self) -> list[LayerStats]:
        return [l.stats() for l in self.layers]

    def total_bits(self) -> int:
        return sum(l.code.total_bits for l in self.layers)

    def bits_per_weight(self) -> float:
        n = sum(l.code.n_weights for l in self.layers)
        return self.total_bits() / max(n, 1)

    def sram_report(self, input_hw: tuple[int, int],
                    cfg: dataflow.TilingConfig = CODR_TILING,
                    per_layer_tiling: bool = False
                    ) -> list[tuple[str, dataflow.AccessCounts]]:
        """Per-layer CoDR SRAM access estimates for one sample, tracking
        spatial dims through the conv stack (linear = 1×1 conv on a 1×1
        feature map).  ``per_layer_tiling`` counts each layer under its
        own effective encode tile geometry (``LayerStats.t_m``/``t_n``)
        instead of the global Table I tiling — the measured side of the
        tuner's predicted-vs-measured comparison."""
        ri, ci = input_hw
        out = []
        for layer in self.layers:
            st = layer.stats()
            if layer.kind == "conv":
                shape = layer.conv_shape(ri, ci)
                ri, ci = layer.out_hw(ri, ci)
            else:
                m, n = layer.code.shape[0], layer.code.shape[1]
                shape = ConvShape(m, n, 1, 1, 1, 1, 1)
            tiling = dataflow.codr_tiling(st.t_m, st.t_n, base=cfg) \
                if per_layer_tiling else cfg
            out.append((layer.name, dataflow.codr_accesses(
                shape, tiling, float(layer.code.total_bits),
                float(st.n_unique), float(st.n_nonzero))))
        return out


# ---------------------------------------------------------------------------
# model builders
# ---------------------------------------------------------------------------

def paper_model_shapes(net: str = "alexnet", n_conv: int = 2,
                       ri: int | None = None, ci: int | None = None
                       ) -> list[ConvShape]:
    """Channel/kernel geometry of the first ``n_conv`` conv layers of a
    paper CNN (configs/paper_cnns.py), optionally with reduced spatial
    dims so test batches stay cheap (channel structure — what UCR
    compresses — is untouched)."""
    from repro.configs.paper_cnns import PAPER_CNNS
    shapes = []
    for s in PAPER_CNNS[net][:n_conv]:
        use_ri = ri if ri is not None else s.ri
        use_ci = ci if ci is not None else s.ci
        shapes.append(ConvShape(s.m, s.n, s.rk, s.ck, use_ri, use_ci,
                                s.stride))
        ri = ci = None                      # only the first layer is forced
    return shapes


def build_random_model(shapes: Sequence[ConvShape], n_out: int, *,
                       density: float = 0.4, rng=None,
                       t_m: int = 4, t_n: int = 4,
                       activation: str | None = "relu",
                       decode_source: str = "bitstream") -> CodrModel:
    """conv×len(shapes) → linear model with paper-style sparse Gaussian
    weights; consecutive shapes must be spatially consistent (each layer's
    input channels = previous layer's output channels).

    .. deprecated:: shim over ``ModelSpec.from_shapes`` + ``compile`` —
        the weight generation and validation live there now.
    """
    from repro.core import api
    spec = api.ModelSpec.from_shapes(shapes, n_out=n_out, density=density,
                                     rng=rng, activation=activation)
    cfg = api.EncodeConfig(t_m=t_m, t_n=t_n, decode_source=decode_source)
    return api.compile(spec, cfg).model
