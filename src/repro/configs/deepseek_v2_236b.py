"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed
experts top-6, first layer dense. [arXiv:2405.04434; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=12288,                       # dense prologue layer FFN
    vocab_size=102400,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    nope_head_dim=128, rope_head_dim=64, v_head_dim=128,
    n_experts=160, n_shared_experts=2, moe_top_k=6, moe_d_ff=1536,
    n_dense_layers=1, rope_theta=1e4,
)
