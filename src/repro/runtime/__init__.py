from repro.runtime.loop import TrainLoop, TrainLoopConfig
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.elastic import ElasticMeshManager, HostSet

__all__ = ["TrainLoop", "TrainLoopConfig", "StragglerMonitor",
           "ElasticMeshManager", "HostSet"]
