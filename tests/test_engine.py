"""End-to-end CoDR engine: encode once → decode from bitstreams → tiled
dispatch must match dense ``jax.lax.conv`` within int8 quantization
tolerance (and the dequantized oracle near-exactly)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ucr
from repro.core.dataflow import ConvShape
from repro.core.engine import (CodrConv2D, CodrLinear, CodrModel,
                               build_random_model, decode_all_tiles,
                               paper_model_shapes)
from repro.core.serving import CodrBatchServer


@pytest.fixture
def rng():
    """Function-scoped override of the session rng: the parity tolerances
    below are statistical, so every test must see the same draws whether
    it runs alone or inside the full suite."""
    return np.random.default_rng(0)


def _sparse_weights(rng, shape, density, scale=0.5):
    w = rng.normal(size=shape).astype(np.float32) * scale
    w[rng.random(shape) > density] = 0
    return w


def _rel_err(a, b):
    return float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-9))


# ---------------------------------------------------------------------------
# property-based round trip: UCR encode → RLE bitstream → decode →
# reconstruct == quantized weights, at multiple sparsity levels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("density", [0.05, 0.3, 0.7, 1.0])
@pytest.mark.parametrize("shape", [(8, 4, 3, 3), (5, 3, 2, 2), (16, 2, 1, 1)])
def test_bitstream_roundtrip_conv(shape, density, rng):
    w = _sparse_weights(rng, shape, density)
    code = ucr.encode_conv_layer(w, t_m=4, t_n=2)
    q, _ = ucr.quantize_int8(w)
    tiles = decode_all_tiles(code, source="bitstream")
    dense = tiles.reshape(-1, *shape[1:])[: shape[0]]
    assert np.array_equal(dense, q)
    # fast decode path is bit-identical
    assert np.array_equal(decode_all_tiles(code, source="ucr"), tiles)


def _decode_tile_scalar_oracle(code, mt):
    """The per-vector scalar decode loop (``rle.decode_vector`` per
    (tile, channel) vector) — retired from the engine in favor of the
    vectorized bulk path; kept HERE as the parity oracle."""
    import numpy as _np
    from repro.core import rle as _rle
    n = code.shape[1]
    rk, ck = (code.shape[2], code.shape[3]) if len(code.shape) == 4 else (1, 1)
    tm_eff = min(code.t_m, code.shape[0] - mt * code.t_m)
    w = _np.zeros((code.t_m, n, rk, ck), dtype=_np.int8)
    for nn in range(n):
        vec = _rle.decode_vector(code.vectors[mt * n + nn])
        w[:tm_eff, nn] = vec.reshape(tm_eff, rk, ck)
    return w


@pytest.mark.parametrize("shape,t_m", [((8, 4, 3, 3), 4), ((10, 3, 3, 3), 4),
                                       ((5, 2, 2, 2), 2)])
def test_decode_tile_matches_scalar_oracle(shape, t_m, rng):
    """engine.decode_tile now routes through the vectorized bulk decoder
    (rle.decode_layer); every tile — including the ragged last one — must
    be bit-identical to the scalar per-vector loop."""
    from repro.core.engine import decode_tile
    w = _sparse_weights(rng, shape, density=0.5)
    code = ucr.encode_conv_layer(w, t_m=t_m, t_n=2)
    n_tiles = -(-shape[0] // t_m)
    for mt in range(n_tiles):
        assert np.array_equal(decode_tile(code, mt),
                              _decode_tile_scalar_oracle(code, mt)), mt


@pytest.mark.parametrize("density", [0.1, 0.5, 0.9])
def test_bitstream_roundtrip_linear(density, rng):
    w = _sparse_weights(rng, (24, 16), density)
    layer = CodrLinear(w, t_m=8)
    layer.verify_roundtrip()
    q, _ = ucr.quantize_int8(w)
    assert np.array_equal(layer.decoded_weights(), q)


# ---------------------------------------------------------------------------
# golden parity: one layer vs dense jax.lax.conv, shapes from the paper CNNs
# ---------------------------------------------------------------------------

PAPER_LAYER_CASES = [
    # (net, spatial) — first conv of each paper CNN, reduced spatial dims
    ("alexnet", 23), ("vgg16", 12), ("googlenet", 17),
]


@pytest.mark.parametrize("net,ri", PAPER_LAYER_CASES)
def test_conv_layer_parity_paper_shapes(net, ri, rng):
    s = paper_model_shapes(net, n_conv=1, ri=ri, ci=ri)[0]
    w = _sparse_weights(rng, (s.m, s.n, s.rk, s.ck), density=0.4)
    layer = CodrConv2D(w, stride=s.stride, name=f"{net}_conv0")
    layer.verify_roundtrip()
    x = rng.normal(size=(4, ri, ri, s.n)).astype(np.float32)
    y = layer(x)
    # dequantized-weights oracle: same math, only float summation order
    wq = layer.decoded_weights().astype(np.float32) \
        * float(np.asarray(layer.code.scale))
    import jax
    yq = jax.lax.conv_general_dilated(
        jnp.asarray(x), jnp.asarray(wq), window_strides=(s.stride, s.stride),
        padding="VALID", dimension_numbers=("NHWC", "OIHW", "NHWC"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yq),
                               rtol=1e-4, atol=1e-4)
    # float-weights oracle: int8 quantization tolerance
    assert _rel_err(y, layer.reference(x)) < 0.08


def test_conv_layer_ragged_tiles_and_bias(rng):
    # m=10 not divisible by t_m=4 → ragged last tile must crop cleanly
    w = _sparse_weights(rng, (10, 3, 3, 3), density=0.6)
    b = rng.normal(size=10).astype(np.float32)
    layer = CodrConv2D(w, b, t_m=4, activation="relu")
    x = rng.normal(size=(2, 9, 9, 3)).astype(np.float32)
    y = layer(x)
    assert y.shape == (2, 7, 7, 10)
    assert float(jnp.min(y)) >= 0.0                    # relu applied
    assert _rel_err(y, layer.reference(x)) < 0.08


# ---------------------------------------------------------------------------
# faithful-mechanism backends agree with the tiled path
# ---------------------------------------------------------------------------

def test_smm_backends_exact_on_int_inputs(rng):
    w = _sparse_weights(rng, (8, 3, 3, 3), density=0.5)
    layer = CodrConv2D(w, t_m=4, t_n=2)
    x = rng.integers(-8, 8, size=(2, 10, 10, 3)).astype(np.float32)
    y = layer(x)
    assert float(jnp.abs(y - layer.smm_forward(x)).max()) == 0.0
    assert float(jnp.abs(y - layer.smm_forward(x, kernel=True)).max()) == 0.0


def test_model_smm_backend_within_activation_quantization(rng):
    shapes = [ConvShape(8, 3, 3, 3, 12, 12, 1), ConvShape(12, 8, 3, 3, 1, 1, 1)]
    model = build_random_model(shapes, n_out=6, density=0.5, rng=rng,
                               activation=None)
    x = rng.integers(-5, 6, size=(3, 12, 12, 3)).astype(np.float32)
    y = model.run(x)
    # 8-bit feature path re-quantizes between layers → small bounded error
    assert _rel_err(model.run(x, backend="smm"), y) < 0.05


# ---------------------------------------------------------------------------
# acceptance: 3-layer paper CNN, batch ≥ 8, vs dense reference
# ---------------------------------------------------------------------------

def test_codr_model_three_layer_paper_cnn(rng):
    shapes = paper_model_shapes("alexnet", n_conv=2, ri=35, ci=35)
    model = build_random_model(shapes, n_out=10, density=0.3, rng=rng)
    model.verify_roundtrip()                  # bitstream decode is lossless
    x = rng.normal(size=(8, 35, 35, 3)).astype(np.float32)
    y = model.run(x)
    assert y.shape == (8, 10)
    # exact parity (float order) vs dequantized decoded weights
    yq = model.quantized_reference(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yq),
                               rtol=1e-3, atol=1e-3)
    # int8 quantization tolerance vs the dense float reference
    assert _rel_err(y, model.reference(x)) < 0.08
    # the compressed code is genuinely smaller than int8
    assert model.bits_per_weight() < 8.0


def test_model_stats_and_sram_report(rng):
    shapes = [ConvShape(8, 3, 3, 3, 12, 12, 1), ConvShape(12, 8, 3, 3, 1, 1, 1)]
    model = build_random_model(shapes, n_out=6, density=0.5, rng=rng)
    stats = model.stats()
    assert [s.kind for s in stats] == ["conv", "conv", "linear"]
    assert all(s.encoded_bits > 0 and 0 < s.density <= 1 for s in stats)
    report = model.sram_report((12, 12))
    assert len(report) == 3
    for (name, acc), st in zip(report, stats):
        assert acc.total_sram > 0
        # streamed weight bits derive from this layer's real encoded size
        assert acc.dram_weight_bits == st.encoded_bits


# ---------------------------------------------------------------------------
# batched request path
# ---------------------------------------------------------------------------

def test_batch_server_matches_direct_run_and_orders_results(rng):
    shapes = [ConvShape(6, 3, 3, 3, 10, 10, 1)]
    model = build_random_model(shapes, n_out=4, density=0.5, rng=rng)
    samples = [rng.normal(size=(10, 10, 3)).astype(np.float32)
               for _ in range(7)]
    server = CodrBatchServer(model, max_batch=4)
    outs = server.serve(samples)
    assert len(outs) == 7
    assert server.batches_run == 2            # 4 + 3 (padded) requests
    direct = np.asarray(model.run(jnp.asarray(np.stack(samples))))
    for got, want in zip(outs, direct):
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_model_compile_once_no_retrace_on_repeat_shapes(rng):
    """Compile-once regression: a second ``CodrModel.__call__`` with the
    same input shape must not re-trace any layer forward (traced-fn
    counters); a new shape re-traces each layer exactly once."""
    shapes = [ConvShape(6, 3, 3, 3, 10, 10, 1)]
    model = build_random_model(shapes, n_out=4, density=0.5, rng=rng)
    x = rng.normal(size=(2, 10, 10, 3)).astype(np.float32)
    model(x)
    first = model.trace_count
    assert first == len(model.layers)          # one trace per layer
    for _ in range(3):
        model(x)
    assert model.trace_count == first          # cache hit, no re-trace
    model(rng.normal(size=(5, 10, 10, 3)).astype(np.float32))
    assert model.trace_count == 2 * first      # new batch shape: one more
    model(x)
    assert model.trace_count == 2 * first      # old shape still cached


def test_batch_server_buckets_mixed_size_requests(rng):
    """Mixed-shape request streams: outputs stay in submission order and
    a repeat stream compiles nothing new (size-bucketed dispatch)."""
    w = _sparse_weights(rng, (4, 2, 3, 3), density=0.5)
    model = CodrModel([CodrConv2D(w, t_m=2, activation="relu")])
    server = CodrBatchServer(model, max_batch=4)
    xs = [rng.normal(size=(10, 10, 2)).astype(np.float32) for _ in range(5)] \
        + [rng.normal(size=(12, 12, 2)).astype(np.float32) for _ in range(3)]
    order = rng.permutation(len(xs))
    outs = server.serve([xs[i] for i in order])
    assert len(outs) == len(xs)
    for got, i in zip(outs, order):
        want = np.asarray(model.run(jnp.asarray(xs[i][None])))[0]
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # 5 same-shape → batches of 4+1; 3 of the other shape → one bucket-4
    assert server.batches_run == 3
    assert set(server.bucket_counts) <= {1, 2, 4}
    traces = model.trace_count
    server.serve([xs[i] for i in order])       # identical stream again
    assert model.trace_count == traces         # no compile-cache thrash


def test_batch_server_incremental_submit(rng):
    shapes = [ConvShape(4, 2, 2, 2, 6, 6, 1)]
    model = build_random_model(shapes, n_out=3, density=0.8, rng=rng)
    server = CodrBatchServer(model, max_batch=2)
    xs = [rng.normal(size=(6, 6, 2)).astype(np.float32) for _ in range(3)]
    ids = [server.submit(x) for x in xs]
    assert ids == [0, 1, 2]
    outs = server.flush()
    assert len(outs) == 3 and not server.flush()
    assert server.requests_served == 3
