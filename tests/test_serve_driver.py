"""``repro.launch.serve`` driver: encdec cache handling + the --codr
decode-fused transformer serving path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.launch.serve import run_serve, run_serve_continuous
from repro.models import get_model

ENCDEC = "seamless-m4t-medium"


def test_serve_encdec_pads_self_cache_and_generates():
    """encdec serving continues from the prefill cache: the decoder
    self-attention KV is padded out to prompt+gen length (the old path
    left it at prompt length behind dead `if False` code and replayed
    against a zeroed cross cache)."""
    res = run_serve(arch=ENCDEC, batch=2, prompt_len=4, gen_len=3,
                    verbose=False)
    assert res["family"] == "encdec"
    assert res["gen"].shape == (2, 3)
    assert res["cache_self_len"] == 4 + 3      # padded to total
    assert np.isfinite(res["gen"]).all()


def test_serve_encdec_gen_len_zero():
    res = run_serve(arch=ENCDEC, batch=1, prompt_len=4, gen_len=0,
                    verbose=False)
    assert res["gen"].shape == (1, 0)
    assert res["cache_self_len"] == 4          # nothing to pad


def test_encdec_decode_from_padded_prefill_cache_matches_prefill(key):
    """The padded-cache decode step must reproduce a one-token-longer
    prefill: proves the pad leaves masked tail positions inert AND that
    the kept cross-attention cache carries the real encoder output."""
    import repro.models.common as common
    import repro.models.encdec as encdec_mod
    old = common.DEFAULT_DTYPE
    common.DEFAULT_DTYPE = jnp.float32
    encdec_mod.DEFAULT_DTYPE = jnp.float32
    try:
        cfg = smoke_variant(get_config(ENCDEC))
        cfg = dataclasses.replace(cfg, remat=False)
        api = get_model(cfg)
        params = api.init_params(key, cfg)
        prefix = jax.random.normal(key, (1, cfg.frontend_seq, cfg.d_model))
        tokens = jax.random.randint(key, (1, 5), 0, cfg.vocab_size)
        lg_full, _ = api.prefill(params, {"tokens": tokens,
                                          "prefix": prefix}, cfg)
        lg4, cache = api.prefill(params, {"tokens": tokens[:, :4],
                                          "prefix": prefix}, cfg)
        pad = 5 - cache["self"][0].shape[2]
        cache = {**cache, "self": tuple(
            jnp.pad(kv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            for kv in cache["self"])}
        lg_step, _ = api.decode_step(params, cache, tokens[:, 4],
                                     jnp.int32(4), cfg)
        ref = np.asarray(lg_full[:, -1], np.float32)
        got = np.asarray(lg_step, np.float32)
        rel = np.abs(got - ref).max() / max(np.abs(ref).max(), 1e-6)
        assert rel < 1e-4, rel
    finally:
        common.DEFAULT_DTYPE = old
        encdec_mod.DEFAULT_DTYPE = old


@pytest.mark.parametrize("backend", ["codr_matmul", "tiled"])
def test_serve_codr_lm_decode_fused(backend):
    """The acceptance path: an repro.models LM served end-to-end from
    the packed representation, HBM bytes measured on the pack."""
    res = run_serve(arch="qwen2.5-3b", batch=2, prompt_len=4, gen_len=3,
                    use_codr=True, codr_backend=backend, verbose=False)
    assert res["gen"].shape == (2, 3)
    assert res["backend"] == backend
    assert 0 < res["hbm_bytes"] < res["dense_bf16_bytes"]
    assert res["n_packed"] > 0


def test_serve_codr_encdec():
    res = run_serve(arch=ENCDEC, batch=1, prompt_len=4, gen_len=2,
                    use_codr=True, codr_backend="tiled", verbose=False)
    assert res["gen"].shape == (1, 2)
    assert res["hbm_bytes"] > 0


def test_serve_continuous_checked():
    """The CI smoke contract through the importable driver: concurrent
    mixed-length requests streamed off the slot pool, every output
    asserted bit-identical to the sequential reference (check=True
    raises on any divergence)."""
    res = run_serve_continuous(arch="qwen2.5-3b", n_requests=4, n_slots=2,
                               prompt_len=4, gen_len=3, check=True,
                               verbose=False)
    assert res["checked"] == 4
    assert len(res["gen"]) == 4
    assert all(len(s) == 3 for s in res["gen"])
    assert res["peak_active"] <= 2              # pool bound respected
    assert res["prefills_run"] == 4


def test_serve_continuous_packed_ckpt_int8(tmp_path):
    """--packed-ckpt end to end: first boot compiles + saves the
    artifact, serves from the int8 paged KV pool, and check verifies
    against the dense-cache reference; a second boot mmap-loads the
    same artifact and reproduces the first run's outputs exactly (the
    artifact, not the RNG, carries the weights)."""
    import os
    path = str(tmp_path / "ck.codr")
    res = run_serve_continuous(arch="qwen2.5-3b", n_requests=3, n_slots=2,
                               prompt_len=4, gen_len=3, check=True,
                               packed_ckpt=path, verbose=False)
    assert os.path.isdir(path)
    assert res["checked"] == 3
    assert res["kv_dtype"] == "int8"            # packed boot defaults paged
    assert res["kv_page_size"] == 4
    assert res["boot_s"] is not None
    assert res["kv_bytes"] > 0
    res2 = run_serve_continuous(arch="qwen2.5-3b", n_requests=3, n_slots=2,
                                prompt_len=4, gen_len=3, check=True,
                                packed_ckpt=path, verbose=False)
    assert res2["gen"] == res["gen"]


def test_serve_continuous_bf16_paged_matches_dense(tmp_path):
    """kv_dtype=bf16 with a page size is the escape hatch: identical
    streamed tokens to the dense-pool run, same params."""
    kw = dict(arch="qwen2.5-3b", n_requests=3, n_slots=2,
              prompt_len=4, gen_len=3, verbose=False)
    dense = run_serve_continuous(**kw)
    paged = run_serve_continuous(kv_dtype="bf16", kv_page_size=4,
                                 check=True, **kw)
    assert paged["gen"] == dense["gen"]
    assert paged["checked"] == 3
