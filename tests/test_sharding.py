"""Sharding rules + a real (tiny-mesh) pjit train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, smoke_variant
from repro.configs.base import ShapeConfig
from repro.launch.steps import (batch_spec, build_cell, input_specs,
                                serve_param_fsdp)
from repro.sharding.rules import param_spec


@pytest.fixture(scope="module")
def mesh16():
    """Abstract 16×16 mesh for spec (not placement) checks."""
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    return Mesh(devs, ("data", "model"))


def test_param_spec_column_parallel(mesh16):
    # scan-stacked params carry a leading (n_periods,) axis — unsharded
    s = param_spec("stack/b0/mixer/q_proj", (4, 2048, 4096), mesh16)
    assert s == P(None, "data", "model")
    s = param_spec("prologue/0/mixer/q_proj", (2048, 4096), mesh16)
    assert s == P("data", "model")


def test_param_spec_row_parallel(mesh16):
    s = param_spec("stack/b0/mixer/o_proj", (4, 4096, 2048), mesh16)
    assert s == P(None, "model", "data")


def test_param_spec_embed(mesh16):
    s = param_spec("embed", (151936, 2048), mesh16)
    assert s == P("model", "data")


def test_param_spec_experts(mesh16):
    s = param_spec("stack/b0/mlp/w_experts_in", (4, 160, 5120, 1536), mesh16)
    # stacked scan axis first → untouched; experts over model
    assert s[0] is None and s[1] == "model"


def test_param_spec_indivisible_left_unsharded(mesh16):
    s = param_spec("stack/b0/mixer/q_proj", (100, 100), mesh16)
    assert s == P(None, None)


def test_param_spec_norms_replicated(mesh16):
    assert param_spec("stack/b0/norm1/w", (2048,), mesh16) == P(None)


def test_batch_spec_divisibility(mesh16):
    assert batch_spec(mesh16, 256) == P(("data",))
    assert batch_spec(mesh16, 3) == P()


def test_input_specs_cover_all_shapes():
    from repro.configs.base import SHAPES
    for arch in ("qwen3-32b", "deepseek-v2-236b", "seamless-m4t-medium",
                 "internvl2-26b"):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            assert "tokens" in specs or "token" in specs


def test_serve_fsdp_heuristic(mesh16):
    assert serve_param_fsdp(get_config("command-r-plus-104b"), mesh16)
    assert not serve_param_fsdp(get_config("qwen2.5-3b"), mesh16)


def test_pjit_train_step_on_host_mesh(key):
    """Real execution of the sharded train step on a 1×1 mesh."""
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("tiny", 32, 2, "train")
    fn, arg_shapes, in_sh, _ = build_cell(cfg, shape, mesh)
    api_params, opt, batch_specs = arg_shapes
    # materialize real values matching the abstract shapes
    params = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), api_params)
    params = jax.tree.map(
        lambda p: jax.random.normal(key, p.shape, jnp.float32).astype(p.dtype)
        * 0.02, params)
    opt_state = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), opt)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
    with mesh:
        step = jax.jit(fn, in_shardings=in_sh)
        p2, o2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(o2["step"]) == 1
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, p2)
    assert max(jax.tree.leaves(delta)) > 0
