"""KV-cache slot pools for continuous batching.

A *pool* is just the pytree returned by a model's ``init_cache(cfg,
n_slots, max_len)`` — the batch axis doubles as the slot axis, so one
pooled ``decode_step`` call advances every active request at once (with
per-row positions, see ``attention.decode_positions``).  The helpers
here move single-request caches in and out of that pool:

* ``diff_axes`` discovers, per leaf, which axis is the batch axis —
  structurally, by comparing the shapes of a batch-1 and a batch-2
  cache from ``jax.eval_shape`` (stacked scan-carry leaves put
  ``n_periods`` first; prologue leaves lead with batch).
* ``write_slot`` block-writes a batch-1 cache (e.g. a prefill result at
  seq length P) into slot ``i`` of the pool.  Shorter-than-pool seq
  axes are written as-is at offset 0: decode attention masks positions
  beyond the slot's own ``pos``, so the stale tail is inert and results
  stay bit-identical to a solo decode.
* ``read_slot`` extracts slot ``i`` back out as a batch-1 cache.

No imports from ``repro.core`` — this is a models-layer utility.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def diff_axes(tree_a, tree_b):
    """Per-leaf axis where ``tree_a`` and ``tree_b`` shapes differ.

    Both trees must share their structure; each leaf pair must differ in
    rank-preserving fashion along exactly one axis (leaves with
    identical shapes are rejected — the batch axis must be
    discoverable).  Returns a pytree of ints with the same structure.
    Feed it ``jax.eval_shape`` results so no arrays are materialized::

        ax = diff_axes(jax.eval_shape(init, 1), jax.eval_shape(init, 2))
    """
    def one(la, lb):
        if la.ndim != lb.ndim:
            raise ValueError(f"rank mismatch {la.shape} vs {lb.shape}")
        diffs = [i for i, (a, b) in enumerate(zip(la.shape, lb.shape))
                 if a != b]
        if len(diffs) != 1:
            raise ValueError(
                f"need exactly one differing axis, got {la.shape} vs "
                f"{lb.shape}")
        return diffs[0]
    return jax.tree.map(one, tree_a, tree_b)


def write_slot(pool, cache, slot, axes):
    """Write batch-1 ``cache`` into ``pool`` at slot index ``slot``.

    ``axes`` is the ``diff_axes`` pytree locating each leaf's slot
    axis.  Leaves whose non-slot dims are shorter than the pool's (a
    seq-P prefill cache into a seq-max pool) land at offset 0, leaving
    the pool's tail untouched — masked out by decode attention."""
    slot = jnp.asarray(slot, jnp.int32)

    def one(pl, cl, ax):
        start = [jnp.int32(0)] * pl.ndim
        start[ax] = slot
        return jax.lax.dynamic_update_slice(
            pl, cl.astype(pl.dtype), tuple(start))
    return jax.tree.map(one, pool, cache, axes)


def read_slot(pool, slot, axes):
    """Extract slot ``slot`` of ``pool`` as a batch-1 cache (full pool
    sequence length — callers mask by position, they don't trim)."""
    slot = jnp.asarray(slot, jnp.int32)

    def one(pl, ax):
        return jax.lax.dynamic_slice_in_dim(pl, slot, 1, axis=ax)
    return jax.tree.map(one, pool, axes)
