"""codrlint fixture: a suppression WITHOUT the mandatory rationale."""


def swallow_no_rationale():
    try:
        risky()                     # noqa: F821
    except Exception:  # codrlint: disable=exception-hygiene
        pass
