"""CLI: ``python -m tools.codrlint [--json FILE|-] [--baseline FILE]
[--only check,check] [--no-baseline] [paths...]``

Exit codes: 0 clean (or fully baselined/suppressed), 1 findings, 2 bad
usage.  ``--json`` writes the machine-readable report (CI uploads it as
an artifact next to ``coverage.xml``).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from tools.codrlint.core import (DEFAULT_PATHS, registered_checkers, run)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.codrlint",
        description="CoDR repo static invariant checker")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files/directories to lint (default: src tools)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="write a JSON report to FILE ('-' for stdout)")
    ap.add_argument("--baseline", metavar="FILE", default=None,
                    help="baseline file (default: tools/codrlint/"
                         "baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: report every finding")
    ap.add_argument("--only", default=None,
                    help="comma-separated checker subset")
    ap.add_argument("--list-checks", action="store_true",
                    help="list registered checkers and exit")
    args = ap.parse_args(argv)

    if args.list_checks:
        for name, c in sorted(registered_checkers().items()):
            print(f"{name:<24} {c.description}")
        return 0

    only = tuple(s.strip() for s in args.only.split(",")) \
        if args.only else None
    baseline = False if args.no_baseline else (
        pathlib.Path(args.baseline) if args.baseline else None)
    try:
        report = run(tuple(args.paths) or DEFAULT_PATHS,
                     baseline=baseline, only=only)
    except ValueError as e:
        print(f"codrlint: {e}", file=sys.stderr)
        return 2

    if args.json:
        payload = json.dumps(report.to_json(), indent=1)
        if args.json == "-":
            print(payload)
        else:
            pathlib.Path(args.json).write_text(payload + "\n")

    for f in report.bad_suppressions:
        print(f.format())
    for f in report.findings:
        print(f.format())
    for fp in report.stale_baseline:
        print(f"note: stale baseline entry (no longer observed): {fp}")
    n = len(report.findings) + len(report.bad_suppressions)
    status = "OK" if report.ok else f"{n} finding(s)"
    print(f"codrlint: {status} — {report.checked_files} file(s), "
          f"{report.suppressed} suppressed, {report.baselined} baselined")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
