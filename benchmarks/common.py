"""Shared benchmark utilities: synthetic weight generation following the
paper's §V-A methodology — "(we) evaluate four weight densities by
randomly eliminating the non-zero weights and study different numbers of
unique weights by making the 8 − log2(U) least significant bits of
weights zero" — applied to Gaussian-initialized tensors (no pretrained
checkpoints ship offline; docs/DESIGN.md §6 notes the substitution: ratios, not
absolute rates, are the reproduction target)."""
from __future__ import annotations

import subprocess
import time

import numpy as np

from repro.core import ucr


def bench_meta(**extra) -> dict:
    """Provenance stamp for ``BENCH_*.json`` trajectories: the git SHA
    the numbers were measured at plus any benchmark-specific metadata
    (e.g. the encode config), so points stay comparable PR over PR."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=5, check=True).stdout.strip()
    except Exception:                                 # noqa: BLE001
        sha = "unknown"
    meta = {"git_sha": sha,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z")}
    meta.update(extra)
    return meta


def make_weights(shape, *, density: float, n_unique: int, rng) -> np.ndarray:
    """int8 weights with the paper's density / unique-count profile.

    Base distribution is Laplacian with a wide quantization range —
    matching the paper's Fig. 2 observation that 8-bit CNN weights are
    heavily concentrated (large zero fraction, strong repetition of
    small magnitudes) because per-tensor scales chase outliers."""
    w = rng.laplace(scale=3.0, size=shape).astype(np.float32)
    q = np.clip(np.round(w), -127, 127).astype(np.int8)
    if n_unique < 256:
        k = 8 - int(np.log2(n_unique))
        q = ((q.astype(np.int32) >> k) << k).astype(np.int8)  # zero LSBs
    keep = rng.random(shape) < density
    q = np.where(keep, q, 0).astype(np.int8)
    return q


# base 8-bit densities per net (paper Fig. 2: VGG16 8-bit sparsity
# reaches 94%; AlexNet/GoogleNet are less sparse) — the D sweeps multiply
# on top ("randomly eliminating the non-zero weights", §V-A)
BASE_DENSITY = {"alexnet": 0.50, "vgg16": 0.20, "googlenet": 0.60}


def sampled_layer_vectors(q: np.ndarray, t_m: int, t_n: int,
                          max_vectors: int = 1500, seed: int = 0):
    """UCR vectors for a sample of the layer's (tile, channel) vectors —
    bits are scaled back up by the sample fraction (statistically exact
    for iid-modified weights)."""
    m, n = q.shape[0], q.shape[1]
    kernel = int(np.prod(q.shape[2:])) if q.ndim > 2 else 1
    qr = q.reshape(m, n, kernel)
    total_vectors = (m // t_m + (m % t_m > 0)) * n
    rng = np.random.default_rng(seed)
    picks = min(max_vectors, total_vectors)
    chosen = rng.choice(total_vectors, size=picks, replace=False)
    n_tiles_m = -(-m // t_m)
    vectors = []
    for c in chosen:
        mt, nn = c % n_tiles_m, c // n_tiles_m
        vec = qr[mt * t_m:(mt + 1) * t_m, nn, :].reshape(-1)
        vectors.append(ucr.ucr_transform(vec))
    return vectors, total_vectors / picks


class Timer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        self.dt = time.monotonic() - self.t0


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"
