"""codrlint fixture: a Backend subclass whose caps are honest."""


class GoodBackend(Backend):                         # noqa: F821
    name = "fixture-good"
    caps = BackendCaps(packed_matmul=True,          # noqa: F821
                       native_kinds=frozenset({"conv"}))

    def matmul(self, a, b):
        return a @ b

    def conv(self, x, w):
        return x


class DynamicCapsBackend(Backend):                  # noqa: F821
    """Lazy caps property — flag checks are skipped by design; the
    KERNEL_CAPS shape rule covers its source of truth instead."""

    name = "fixture-dynamic"

    @property
    def caps(self):
        return resolve_caps(KERNEL_CAPS)            # noqa: F821

    def matmul(self, a, b):
        return a @ b


KERNEL_CAPS = {
    "kinds": ("conv", "matmul"),
    "integer_activations": True,
    "description": "fixture kernel capability table",
}
