"""jit'd public wrapper for the CoDR compressed matmul.

On CPU (this container) the Pallas kernel runs in interpret mode; on a
real TPU backend ``interpret=False`` compiles to Mosaic.
"""
from __future__ import annotations

import jax

from repro.core.codr_linear import PackedWeight
from repro.kernels.codr_matmul.kernel import codr_matmul_pallas

# Capability facts consumed by the backend registry
# (repro.core.backends.CodrMatmulBackend) — this kernel only has a matmul
# (linear-layer) datapath; conv layers never route here.
KERNEL_CAPS = {
    "kinds": ("linear",),
    "integer_activations": False,  # float activations, f32 accumulation
    "interpret_on_cpu": True,
    "packed_matmul": True,         # executes PackedLinear params leaves
    "description": "Pallas fused decode+matmul (unique-index pack, "
                   "output-stationary MXU tiles)",
}


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def codr_matmul(x: jax.Array, w: PackedWeight, *, bm: int = 128,
                bn: int = 128, bk: int = 128,
                interpret: bool | None = None) -> jax.Array:
    """``y = x @ decode(w)`` with the decode fused into the matmul tiles."""
    if interpret is None:
        interpret = not _on_tpu()
    return codr_matmul_pallas(
        x, w.packed, w.table, w.scale.reshape(-1),
        bits=w.bits, n=w.shape[1], bm=bm, bn=bn, bk=bk, interpret=interpret)
