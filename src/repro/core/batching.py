"""Continuous batching: a production decode loop over packed weights.

``ContinuousBatcher`` runs a fixed pool of KV-cache slots (one pooled
cache whose batch axis is the slot axis) and drives every *active* slot
forward with a single jitted ``decode_step`` per iteration:

* **join-on-prefill** — a new request is prefilled on its own (batch-1,
  its exact prompt length) and its cache block-written into a free slot
  (:func:`repro.models.cache.write_slot`); the pooled decode batch never
  stalls behind a long prompt, and in-flight requests never recompile.
* **leave-on-EOS** — a slot retires the moment its request samples
  ``eos_id`` or hits ``max_new_tokens``, freeing the slot for the next
  admission while the rest of the pool keeps decoding.
* **streaming** — :meth:`submit` returns a :class:`GenerationHandle`
  immediately; iterating it yields tokens as they are produced, and
  ``handle.result()`` blocks for the full sequence.

Per-request results are **bit-identical** to a solo decode of the same
prompt on the same params (:meth:`ContinuousBatcher.generate_reference`
is that oracle, sharing the batcher's compiled functions): decode
attention masks every cache position beyond a slot's own ``pos``, so a
neighbour slot's content — or the stale tail a previous tenant left —
contributes exactly 0.0, and XLA's per-row computation does not mix
rows.  The slot state machine and streaming contract are documented in
``docs/DESIGN.md`` §3.4.

The async chassis (condition-variable worker, lazy start, stop/drain/
restart, exception isolation) is :class:`repro.core.serving
.AsyncWorkerLoop`, shared with ``CodrBatchServer``.
"""
from __future__ import annotations

import dataclasses
import queue as queue_mod
import time
from concurrent import futures

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.serving import AsyncWorkerLoop

_DONE = object()                    # stream sentinel: generation finished


class GenerationHandle:
    """Streaming handle for one request.

    * iterate it (``for tok in handle``) to stream tokens as the pool
      produces them — the iterator ends at EOS/max-tokens and re-raises
      a generation failure;
    * ``handle.result(timeout)`` blocks for the full token list;
    * ``handle.finish_reason`` is ``"eos"``, ``"length"``,
      ``"cancelled"`` or ``"error"`` once finished.

    Tokens are plain Python ints.  When the batcher was built with
    ``record_logits=True``, ``handle.logits`` holds one float32 vocab
    row per emitted token (the bit-identity witness).
    """

    def __init__(self, rid: int, prompt_len: int, max_new_tokens: int):
        self.rid = rid
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.finish_reason: str | None = None
        self.future: futures.Future = futures.Future()
        self.logits: list[np.ndarray] = []
        self._tokens: list[int] = []
        self._stream: queue_mod.SimpleQueue = queue_mod.SimpleQueue()

    # -- worker side --------------------------------------------------------
    def _emit(self, tok: int, logits_row: np.ndarray | None = None) -> None:
        self._tokens.append(tok)
        if logits_row is not None:
            self.logits.append(logits_row)
        self._stream.put(tok)

    def _finish(self, reason: str) -> None:
        self.finish_reason = reason
        self.future.set_result(list(self._tokens))
        self._stream.put(_DONE)

    def _fail(self, exc: BaseException, reason: str = "error") -> None:
        self.finish_reason = reason
        self.future.set_exception(exc)
        self._stream.put(exc)

    # -- caller side --------------------------------------------------------
    def __iter__(self):
        while True:
            item = self._stream.get()
            if item is _DONE:
                return
            if isinstance(item, BaseException):
                raise item
            yield item

    def result(self, timeout: float | None = None) -> list[int]:
        """Block until generation finishes; returns all emitted tokens."""
        return self.future.result(timeout)

    @property
    def tokens(self) -> list[int]:
        """Tokens emitted so far (snapshot; may still be growing)."""
        return list(self._tokens)

    def done(self) -> bool:
        return self.future.done()


@dataclasses.dataclass
class _Slot:
    """One occupied pool slot (ACTIVE state of the slot machine)."""
    handle: GenerationHandle
    eos_id: int | None
    last_tok: int                   # token fed to the next decode step
    pos: int                        # cache position that step writes
    n_gen: int                      # tokens emitted so far
    deadline: float | None = None   # absolute monotonic deadline


@dataclasses.dataclass
class _Pending:
    """A submitted request waiting for a free slot (QUEUED state)."""
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: int | None
    handle: GenerationHandle
    deadline: float | None = None   # absolute monotonic deadline


class ContinuousBatcher(AsyncWorkerLoop):
    """Slot-pooled continuous-batching decode loop over an LM.

    ``params`` may be a raw params pytree or an
    :class:`repro.core.api.CompiledParams` (packed weights; its
    ``.params`` pytree is served through the backend registry exactly as
    in ``launch/serve.py --codr``).  Decoder-only families only — the
    encoder-decoder cache (per-request encoder output) has no pooled
    form here.

    The worker admits up to ``prefill_per_step`` queued requests per
    iteration (each prefilled at its own prompt length, outside the
    decode batch), then advances every active slot with ONE pooled
    ``decode_step`` whose per-slot positions ride in a ``(n_slots,)``
    vector.  ``join_deadline_s > 0`` lets a partially-filled pool wait
    that long after an admission for co-riders before decoding resumes
    (a latency/throughput knob mirroring ``CodrBatchServer``'s
    ``flush_deadline_s``).

    A failed *prefill* fails only its own request's handle; a failed
    pooled *decode step* fails the handles of exactly the slots that
    were active in it.  The worker survives both and keeps serving.
    """

    _thread_name = "codr-continuous-batcher"

    def __init__(self, params, cfg, *, n_slots: int = 4, max_len: int = 128,
                 eos_id: int | None = None, prefill_per_step: int = 1,
                 join_deadline_s: float = 0.0, record_logits: bool = False,
                 max_pending: int | None = None,
                 kv_dtype: str = "bf16", kv_page_size: int | None = None,
                 kv_pages: int | None = None):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if max_len < 2:
            raise ValueError("max_len must be >= 2")
        if kv_dtype not in ("bf16", "int8"):
            raise ValueError(f"kv_dtype must be 'bf16' or 'int8', "
                             f"got {kv_dtype!r}")
        if kv_dtype == "int8" and kv_page_size is None:
            kv_page_size = 16            # int8 storage is always paged
        if max_pending is not None and max_pending < 1:
            raise ValueError("max_pending must be >= 1 (or None)")
        if cfg.family == "encdec" or cfg.frontend:
            raise NotImplementedError(
                "ContinuousBatcher supports decoder-only LM configs "
                f"(got family={cfg.family!r}, frontend={cfg.frontend!r})")
        super().__init__()
        from repro.models import get_model          # lazy: core → models
        from repro.models import cache as cache_mod
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.prefill_per_step = max(1, prefill_per_step)
        self.join_deadline_s = join_deadline_s
        self.record_logits = record_logits
        self.max_pending = max_pending      # bounded admission (None=∞)
        # CompiledParams duck-typing: serve from its packed pytree
        self._params = getattr(params, "params", params)
        self._api = get_model(cfg)
        self._cache_mod = cache_mod
        self._prefill_fn = jax.jit(
            lambda p, t: self._api.prefill(p, {"tokens": t}, cfg))
        self._step_fn = jax.jit(
            lambda p, pool, tok, pos: self._api.decode_step(
                p, pool, tok, pos, cfg))
        if kv_page_size is not None:
            # paged KV: pool of fixed-size pages + per-slot page tables
            # (docs/DESIGN.md §2.2).  The page table lives host-side
            # (self._kv_table) — admission allocates, retirement frees
            # by repointing rows at the scratch page — and is pushed
            # into the device pool before every decode step.
            self._paged = cache_mod.PagedSpec(
                page_size=kv_page_size, max_len=max_len, n_slots=n_slots,
                kv_dtype=kv_dtype, n_pages=kv_pages)
            self._paged.total_pages     # validate geometry up front
            self._page_pool = cache_mod.PagePool(self._paged)
            self._slot_pages: list[list[int] | None] = [None] * n_slots  # guarded-by: _cv
            self._kv_table = np.zeros((n_slots, self._paged.max_pages),  # guarded-by: _cv
                                      np.int32)
            self._write_fn = jax.jit(
                lambda pool, c, slot, pages: cache_mod.write_slot_paged(
                    pool, c, slot, pages))
            self._pool = self._api.init_cache(cfg, n_slots, max_len,
                                              paged=self._paged)
        else:
            self._paged = None
            # slot axis per cache leaf, discovered structurally (stacked
            # scan-carry leaves lead with n_periods, prologue leaves
            # with batch) — no arrays materialized
            self._axes = cache_mod.diff_axes(
                jax.eval_shape(lambda: self._api.init_cache(cfg, 1,
                                                            max_len)),
                jax.eval_shape(lambda: self._api.init_cache(cfg, 2,
                                                            max_len)))
            self._write_fn = jax.jit(
                lambda pool, c, slot: cache_mod.write_slot(
                    pool, c, slot, self._axes))
            self._pool = self._api.init_cache(cfg, n_slots, max_len)
        self._slots: list[_Slot | None] = [None] * n_slots  # guarded-by: _cv
        self._pending: list[_Pending] = []  # guarded-by: _cv
        self._next_id = 0                   # guarded-by: _cv
        self._abort_active = False          # guarded-by: _cv
        self._last_admit_t: float | None = None   # guarded-by: _cv
        # stats (written by the worker under _cv)
        self.steps_run = 0                  # guarded-by: _cv
        self.prefills_run = 0               # guarded-by: _cv
        self.requests_finished = 0          # guarded-by: _cv
        self.peak_active = 0                # guarded-by: _cv
        self.requests_shed = 0              # guarded-by: _cv
        self.requests_expired = 0           # guarded-by: _cv

    # -- submission ---------------------------------------------------------
    def submit(self, prompt, *, max_new_tokens: int = 16,
               eos_id: int | None = None,
               deadline_s: float | None = None) -> GenerationHandle:
        """Queue one prompt (1-D int token array).  Returns immediately
        with a :class:`GenerationHandle`; the worker starts lazily.
        ``eos_id`` overrides the batcher default for this request.

        Admission validates the request against the slot geometry up
        front: the prompt plus its ``max_new_tokens`` headroom must fit
        the pool's ``max_len`` (a request that would overflow its KV
        slot mid-stream is rejected here with a clear ``ValueError``,
        never admitted).  ``deadline_s`` bounds the request's total
        latency — a request still queued (or still generating) when its
        deadline passes fails with ``DeadlineExceeded``
        (``finish_reason == "deadline"``) instead of holding a slot.
        With ``max_pending`` set, a full admission queue sheds with
        ``RejectedError`` rather than growing without bound.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if prompt.size + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt_len {prompt.size} + max_new_tokens "
                f"{max_new_tokens} = {prompt.size + max_new_tokens} "
                f"exceeds pool max_len {self.max_len}: the request would "
                f"overflow its KV slot mid-stream (shorten the prompt or "
                f"lower max_new_tokens)")
        deadline = None
        if deadline_s is not None:
            if deadline_s <= 0:
                raise ValueError("deadline_s must be > 0 (or None)")
            deadline = time.monotonic() + deadline_s
        with self._cv:
            if self._stopping:
                raise RuntimeError(
                    "batcher is stopping; submit rejected (handle would "
                    "never resolve)")
            if (self.max_pending is not None
                    and len(self._pending) >= self.max_pending):
                self.requests_shed += 1
                from repro.runtime.resilience import RejectedError
                raise RejectedError(
                    f"admission queue full ({len(self._pending)}/"
                    f"{self.max_pending} pending); retry once a slot "
                    "frees", retry_after_s=self.join_deadline_s or 0.05)
            handle = GenerationHandle(self._next_id, int(prompt.size),
                                      max_new_tokens)
            self._next_id += 1
            self._pending.append(_Pending(
                prompt, max_new_tokens,
                self.eos_id if eos_id is None else eos_id, handle,
                deadline))
            if self._worker is None or not self._worker.is_alive():
                self._start_locked()
            self._cv.notify_all()
        return handle

    @property
    def active(self) -> int:
        with self._cv:
            return sum(s is not None for s in self._slots)

    @property
    def pending(self) -> int:
        with self._cv:
            return len(self._pending)

    def kv_bytes(self) -> int:
        """Measured bytes of the KV pool as stored — page data + scales
        + tables (paged) or the contiguous slot buffers (dense).  The
        cache-side counterpart of ``CompiledParams.hbm_bytes()``."""
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(self._pool))

    # -- paged-KV bookkeeping (all under self._cv) ---------------------------
    def _pages_ok_locked(self) -> bool:
        """Can the head pending request reserve its full page budget?"""
        if self._paged is None or not self._pending:
            return True
        req = self._pending[0]
        need = self._paged.pages_for(req.prompt.size + req.max_new_tokens)
        return self._page_pool.available >= need

    def _release_pages_locked(self, slot_idx: int) -> None:
        """Free a retired/failed slot's pages and repoint its page-table
        row at the scratch page, so the pooled decode step's dead write
        for this now-inactive slot cannot land in a page that a new
        request may already own."""
        if self._paged is None:
            return
        pages = self._slot_pages[slot_idx]
        if pages:
            self._page_pool.free(pages)
        self._slot_pages[slot_idx] = None
        self._kv_table[slot_idx, :] = self._cache_mod.SCRATCH_PAGE

    # -- AsyncWorkerLoop hooks ----------------------------------------------
    def _cancel_pending_locked(self) -> None:
        self._abort_active = True
        for p in self._pending:
            p.handle._fail(futures.CancelledError(), reason="cancelled")
        self._pending.clear()

    def _fail_live_locked(self, exc: BaseException) -> None:
        # worker died past the restart budget: every queued AND active
        # handle gets the failure — result() and the stream iterator
        # must never hang on a dead loop, even mid-generation
        for p in self._pending:
            if not p.handle.done():
                p.handle._fail(exc)
        self._pending.clear()
        for i, s in enumerate(self._slots):
            if s is not None:
                self._slots[i] = None
                self._release_pages_locked(i)
                if not s.handle.done():
                    s.handle._fail(exc)

    def _guarded(self, fn):
        """Run one dispatch under the retry/supervisor ladder; exactly
        ``fn()`` when neither is configured."""
        pol, sup = self._retry_policy, self._supervisor
        if pol is None and sup is None:
            return fn()
        from repro.runtime import resilience
        return resilience.retry_call(fn, policy=pol, supervisor=sup)

    def _loop(self) -> None:
        with self._cv:
            self._abort_active = False
        while True:
            # injection site "batcher.worker": fires with no queue or
            # slot state held mid-mutation, so a crash here restarts
            # cleanly with every pending request and active slot intact
            self._fire("batcher.worker")
            with self._cv:
                while not self._stopping:
                    has_free = any(s is None for s in self._slots)
                    n_active = sum(s is not None for s in self._slots)
                    if (self._pending and has_free
                            and self._pages_ok_locked()):
                        break                       # admission work
                    if n_active:
                        # join deadline: a partially-filled pool lingers
                        # briefly after an admission so co-riders can
                        # join the decode batch
                        if (self.join_deadline_s > 0 and has_free
                                and self._last_admit_t is not None):
                            wait = (self._last_admit_t
                                    + self.join_deadline_s
                                    - time.monotonic())
                            if wait > 0:
                                self._cv.wait(wait)
                                continue
                        break                       # decode work
                    self._cv.wait()
                if self._stopping:
                    if self._abort_active:
                        for i, s in enumerate(self._slots):
                            if s is not None:
                                s.handle._fail(futures.CancelledError(),
                                               reason="cancelled")
                                self._slots[i] = None
                                self._release_pages_locked(i)
                        return
                    if (not self._pending
                            and not any(s is not None for s in self._slots)):
                        return                      # drained
                admits: list[tuple[int, _Pending, np.ndarray | None]] = []
                for _ in range(self.prefill_per_step):
                    free = [i for i, s in enumerate(self._slots)
                            if s is None]
                    if not free or not self._pending:
                        break
                    if not self._pages_ok_locked():
                        break      # head request waits for page frees
                    req = self._pending.pop(0)
                    if (req.deadline is not None
                            and time.monotonic() >= req.deadline):
                        # expired while queued: never burn a prefill on
                        # a request nobody is waiting for
                        self.requests_expired += 1
                        from repro.runtime.resilience import \
                            DeadlineExceeded
                        req.handle._fail(DeadlineExceeded(
                            "deadline expired before admission"),
                            reason="deadline")
                        continue
                    # reserve the slot (and, paged, its whole page
                    # budget — all-or-nothing, so a request can never
                    # run out of pages mid-stream) under the lock;
                    # prefill happens outside it
                    kv_row = None
                    if self._paged is not None:
                        need = self._paged.pages_for(
                            req.prompt.size + req.max_new_tokens)
                        pages = self._page_pool.alloc(need)
                        assert pages is not None  # _pages_ok_locked held
                        self._slot_pages[free[0]] = pages
                        kv_row = np.full((self._paged.max_pages,),
                                         self._cache_mod.SCRATCH_PAGE,
                                         np.int32)
                        kv_row[:need] = pages
                        self._kv_table[free[0]] = kv_row
                    self._slots[free[0]] = _Slot(
                        req.handle, req.eos_id, last_tok=-1,
                        pos=-1, n_gen=0, deadline=req.deadline)
                    admits.append((free[0], req, kv_row))
            for slot_idx, req, kv_row in admits:
                self._admit(slot_idx, req, kv_row)
            self._decode_active()

    # -- worker internals ---------------------------------------------------
    def _admit(self, slot_idx: int, req: _Pending,
               kv_row: np.ndarray | None = None) -> None:
        """Prefill one request and install it in its reserved slot.  A
        prefill failure (after any configured retries — re-running the
        prefill + slot write is idempotent) releases the slot and fails
        only this handle.  ``kv_row`` is the page-table row built while
        the slot was reserved under ``_cv`` — passed in so the prefill
        never reads ``self._kv_table`` outside the lock."""

        def _attempt():
            self._fire("batcher.prefill")
            logits, cache = self._prefill_fn(
                self._params, jnp.asarray(req.prompt[None, :]))
            if self._paged is not None:
                self._pool = self._write_fn(
                    self._pool, cache, jnp.int32(slot_idx),
                    jnp.asarray(kv_row))
            else:
                self._pool = self._write_fn(self._pool, cache,
                                            jnp.int32(slot_idx))
            return np.asarray(logits, np.float32).reshape(-1)

        try:
            row = self._guarded(_attempt)
        except Exception as e:      # noqa: BLE001 — lands on the handle
            with self._cv:
                self._slots[slot_idx] = None
                self._release_pages_locked(slot_idx)
            req.handle._fail(e)
            return
        tok = int(np.argmax(row))
        with self._cv:
            slot = self._slots[slot_idx]
            slot.last_tok = tok
            slot.pos = int(req.prompt.size)
            slot.n_gen = 1
            self.prefills_run += 1
            self._last_admit_t = time.monotonic()
            n_active = sum(s is not None for s in self._slots)
            self.peak_active = max(self.peak_active, n_active)
        req.handle._emit(tok, row if self.record_logits else None)
        self._maybe_retire(slot_idx, tok)

    def _decode_active(self) -> None:
        with self._cv:
            # deadline sweep: a slot whose request expired mid-stream
            # retires NOW — it must not hold a slot for tokens nobody
            # will read
            expired = [(i, s) for i, s in enumerate(self._slots)
                       if s is not None and s.deadline is not None
                       and time.monotonic() >= s.deadline]
            for i, s in expired:
                self._slots[i] = None
                self._release_pages_locked(i)
                self.requests_finished += 1
                self.requests_expired += 1
            if expired:
                from repro.runtime.resilience import DeadlineExceeded
                for _, s in expired:
                    s.handle._fail(DeadlineExceeded(
                        f"deadline expired after {s.n_gen} token(s)"),
                        reason="deadline")
                self._cv.notify_all()
            active = [(i, s) for i, s in enumerate(self._slots)
                      if s is not None]
            kv_table = (self._kv_table.copy() if self._paged is not None
                        else None)
        if not active:
            return
        toks = np.zeros((self.n_slots,), np.int32)
        poss = np.zeros((self.n_slots,), np.int32)
        for i, s in active:
            toks[i] = s.last_tok
            poss[i] = s.pos
        if kv_table is not None:
            # push the authoritative host page table into the device
            # pool: retired slots now point at scratch, fresh admits at
            # their reserved pages
            self._pool = self._cache_mod.set_tables(self._pool, kv_table)

        def _attempt():
            # retry-safe: self._pool is only replaced on success, so a
            # failed step recomputes from identical state → identical
            # bits on the retry (the pooled step is deterministic)
            self._fire("batcher.decode")
            logits, pool = self._step_fn(
                self._params, self._pool, jnp.asarray(toks),
                jnp.asarray(poss))
            return np.asarray(logits, np.float32), pool

        t0 = time.monotonic()
        try:
            rows, self._pool = self._guarded(_attempt)
        except Exception as e:      # noqa: BLE001 — exactly this batch
            with self._cv:
                for i, s in active:
                    self._slots[i] = None
                    self._release_pages_locked(i)
                    self.requests_finished += 1
                for _, s in active:
                    s.handle._fail(e)
            return
        sup = self._supervisor
        if sup is not None:
            sup.record_latency(time.monotonic() - t0)
        with self._cv:
            self.steps_run += 1
        for i, s in active:
            tok = int(np.argmax(rows[i]))
            s.pos += 1
            s.n_gen += 1
            s.last_tok = tok
            s.handle._emit(tok,
                           rows[i].copy() if self.record_logits else None)
            self._maybe_retire(i, tok)

    def _maybe_retire(self, slot_idx: int, tok: int) -> None:
        with self._cv:
            s = self._slots[slot_idx]
            if s is None:
                return
            reason = None
            if s.eos_id is not None and tok == s.eos_id:
                reason = "eos"
            elif s.n_gen >= s.handle.max_new_tokens:
                reason = "length"
            if reason is None:
                return
            self._slots[slot_idx] = None        # slot → FREE
            self._release_pages_locked(slot_idx)
            self.requests_finished += 1
            self._cv.notify_all()
        s.handle._finish(reason)

    # -- solo oracle --------------------------------------------------------
    def generate_reference(self, prompt, *, max_new_tokens: int = 16,
                           eos_id: int | None = None,
                           record_logits: bool = False):
        """Solo decode of ``prompt``: a fresh ``n_slots`` pool with only
        slot 0 active, driven by the SAME compiled prefill/decode
        functions the batcher uses.  This is the bit-identity oracle —
        any pooled run of the same request must emit exactly these
        tokens (and, with ``record_logits``, these logits bits).
        Returns ``(tokens, logits_rows)``."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size + max_new_tokens > self.max_len:
            raise ValueError("prompt + max_new_tokens exceeds max_len")
        eos = self.eos_id if eos_id is None else eos_id
        pool = self._api.init_cache(self.cfg, self.n_slots, self.max_len,
                                    paged=self._paged)
        logits, cache = self._prefill_fn(self._params,
                                         jnp.asarray(prompt[None, :]))
        if self._paged is not None:
            # deterministic solo allocation: the first pages after
            # scratch.  Physical page ids never enter the math (pages
            # are slot-private, scales per-page), so the pooled run is
            # bit-identical whatever ids its allocator happened to pick.
            need = self._paged.pages_for(prompt.size + max_new_tokens)
            row = np.full((self._paged.max_pages,),
                          self._cache_mod.SCRATCH_PAGE, np.int32)
            row[:need] = np.arange(1, need + 1)
            pool = self._write_fn(pool, cache, jnp.int32(0),
                                  jnp.asarray(row))
        else:
            pool = self._write_fn(pool, cache, jnp.int32(0))
        row = np.asarray(logits, np.float32).reshape(-1)
        toks: list[int] = []
        rows: list[np.ndarray] = []
        tok, pos = int(np.argmax(row)), int(prompt.size)
        toks.append(tok)
        if record_logits:
            rows.append(row)
        while len(toks) < max_new_tokens and tok != eos:
            tvec = np.zeros((self.n_slots,), np.int32)
            pvec = np.zeros((self.n_slots,), np.int32)
            tvec[0], pvec[0] = tok, pos
            logits, pool = self._step_fn(self._params, pool,
                                         jnp.asarray(tvec),
                                         jnp.asarray(pvec))
            r = np.asarray(logits, np.float32)[0]
            tok, pos = int(np.argmax(r)), pos + 1
            toks.append(tok)
            if record_logits:
                rows.append(r.copy())
        return toks, rows

    def replay_logits(self, prompt, tokens) -> np.ndarray:
        """Teacher-forced replay: run ``prompt`` then feed the given
        ``tokens`` verbatim (no argmax feedback), returning the
        ``(len(tokens), vocab)`` float32 logits the pipeline produced
        at each step.

        This is the differential-check primitive for lossy KV modes:
        free-running int8 greedy decode legitimately diverges from the
        dense reference after a few near-tied steps, but the *per-step*
        logits under the same forced token stream must stay within the
        int8 quantization floor of the dense run — so ``--check`` and
        the tier-1 differential tests compare ``replay_logits`` rows
        instead of token strings.  Row 0 is the prefill logits row
        (dense compute, paged caches untouched), so it is bit-exact
        across KV modes by construction."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        tokens = [int(t) for t in tokens]
        if not tokens:
            return np.zeros((0, self.cfg.vocab_size), np.float32)
        if prompt.size + len(tokens) > self.max_len:
            raise ValueError("prompt + replay tokens exceed max_len")
        pool = self._api.init_cache(self.cfg, self.n_slots, self.max_len,
                                    paged=self._paged)
        logits, cache = self._prefill_fn(self._params,
                                         jnp.asarray(prompt[None, :]))
        if self._paged is not None:
            need = self._paged.pages_for(prompt.size + len(tokens))
            row = np.full((self._paged.max_pages,),
                          self._cache_mod.SCRATCH_PAGE, np.int32)
            row[:need] = np.arange(1, need + 1)
            pool = self._write_fn(pool, cache, jnp.int32(0),
                                  jnp.asarray(row))
        else:
            pool = self._write_fn(pool, cache, jnp.int32(0))
        rows = [np.asarray(logits, np.float32).reshape(-1)]
        pos = int(prompt.size)
        for tok in tokens[:-1]:
            tvec = np.zeros((self.n_slots,), np.int32)
            pvec = np.zeros((self.n_slots,), np.int32)
            tvec[0], pvec[0] = tok, pos
            logits, pool = self._step_fn(self._params, pool,
                                         jnp.asarray(tvec),
                                         jnp.asarray(pvec))
            rows.append(np.asarray(logits, np.float32)[0].copy())
            pos += 1
        return np.stack(rows) if rows else np.zeros((0, 0), np.float32)
