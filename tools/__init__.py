"""Repo tooling package (``python -m tools.codrlint`` needs it to be a
regular package; the standalone scripts keep working unchanged)."""
