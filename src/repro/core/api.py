"""Spec → compile → serve: the unified CoDR engine API.

The paper's contract is *encode once offline, execute from bitstreams
forever* (§II-D).  This module exposes that contract as a three-stage
pipeline — the same compiler-like shape SCNN and UCNN frame their
accelerators with (compressed format → dataflow plan → PE execution):

1. :class:`ModelSpec` — a declarative layer graph.  Constructible from
   raw arrays (:meth:`LayerSpec.conv` / :meth:`LayerSpec.dense`), from
   ``configs.paper_cnns`` geometry (:meth:`ModelSpec.from_shapes`,
   :meth:`ModelSpec.from_paper_cnn`), or from **any conv/dense params
   pytree** (:meth:`ModelSpec.from_params` — the checkpoint-ingestion
   path).  No encoding happens here; a spec is cheap and inspectable.
2. :class:`EncodeConfig` — every offline-encoder knob in one place:
   the paper's U budget (``n_unique``), the tile geometry (``t_m`` /
   ``t_n`` / ``t_m_linear``), fixed-vs-searched RLE bit-lengths
   (``rle_params``), and the decode source.
3. :func:`compile` — runs the offline pipeline exactly once and returns
   a :class:`CompiledModel`: an executable with ``.run`` (from the
   bitstreams), ``.reference`` / ``.quantized_reference`` (oracles),
   ``.stats`` / ``.sram_report`` (accounting), and ``.serve`` (the
   batched request path, sync and async).  The execution backend is a
   first-class, registry-resolved object (:mod:`repro.core.backends` —
   its module docstring has a worked "register your own backend"
   example); capability mismatches (stride limits, linear-only kernels)
   fail at compile time with the reason, and the ``sharded`` backend
   scales the tile dispatch across local devices
   (``docs/DESIGN.md`` §3).

Import as ``repro.api``::

    import repro.api as codr

    spec = codr.ModelSpec.from_params(params)       # any conv/dense pytree
    compiled = codr.compile(spec, codr.EncodeConfig(n_unique=16))
    y = compiled.run(x)                             # from RLE bitstreams
    server = compiled.serve(max_batch=8)            # batched requests
"""
from __future__ import annotations

import dataclasses
import re as _re
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backends as _backends
from repro.core import engine as _engine
from repro.core.codr_linear import PackedEmbedding as _PackedEmbedding
from repro.core.codr_linear import PackedLinear as _PackedLinear
from repro.core.codr_linear import pack_embedding as _pack_embedding
from repro.core.codr_linear import pack_projection as _pack_projection

__all__ = [
    "LayerSpec", "ModelSpec", "EncodeConfig", "CompiledModel", "compile",
    "CompiledParams", "compile_params",
]


# ---------------------------------------------------------------------------
# stage 1: the declarative spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class LayerSpec:
    """One declarative layer: float weights + geometry, nothing encoded.

    ``kind="conv"``   → ``weight`` is OIHW ``(M, N, RK, CK)``.
    ``kind="linear"`` → ``weight`` is ``(M, N)`` = (out, in features).
    """

    kind: str
    weight: np.ndarray
    bias: np.ndarray | None = None
    stride: int = 1
    activation: str | None = None
    name: str = ""

    def __post_init__(self):
        w = np.asarray(self.weight, dtype=np.float32)
        object.__setattr__(self, "weight", w)
        if self.kind not in ("conv", "linear"):
            raise ValueError(f"kind must be 'conv' or 'linear', "
                             f"got {self.kind!r}")
        want_ndim = 4 if self.kind == "conv" else 2
        if w.ndim != want_ndim:
            raise ValueError(f"{self.kind} weight must be {want_ndim}-D, "
                             f"got shape {w.shape} for layer "
                             f"{self.name or '?'}")
        if self.stride < 1:
            raise ValueError(f"stride must be >= 1, got {self.stride}")
        if self.bias is not None:
            b = np.asarray(self.bias, dtype=np.float32)
            if b.shape != (w.shape[0],):
                raise ValueError(f"bias shape {b.shape} != ({w.shape[0]},) "
                                 f"for layer {self.name or '?'}")
            object.__setattr__(self, "bias", b)

    # -- constructors -------------------------------------------------------
    @classmethod
    def conv(cls, weight, bias=None, *, stride: int = 1,
             activation: str | None = None, name: str = "conv"):
        return cls("conv", weight, bias, stride=stride,
                   activation=activation, name=name)

    @classmethod
    def dense(cls, weight, bias=None, *, activation: str | None = None,
              name: str = "dense"):
        return cls("linear", weight, bias, activation=activation, name=name)

    @property
    def out_features(self) -> int:
        return int(self.weight.shape[0])

    @property
    def in_features(self) -> int:
        return int(self.weight.shape[1])


class ModelSpec:
    """A declarative stack of :class:`LayerSpec` — conv layers first,
    then linear (the engine auto-flattens at the boundary)."""

    def __init__(self, layers: Sequence[LayerSpec]):
        self.layers = list(layers)
        if not self.layers:
            raise ValueError("ModelSpec needs at least one layer")
        seen_linear = False
        prev = None
        for ls in self.layers:
            if ls.kind == "conv":
                if seen_linear:
                    raise ValueError(f"conv layer {ls.name!r} after a "
                                     f"linear layer — conv layers must "
                                     f"precede the linear head")
                if prev is not None and ls.in_features != prev.out_features:
                    raise ValueError(
                        f"layer {ls.name!r} expects {ls.in_features} input "
                        f"channels, previous layer {prev.name!r} produces "
                        f"{prev.out_features}")
                prev = ls
            else:
                seen_linear = True

    def __len__(self) -> int:
        return len(self.layers)

    def __iter__(self):
        return iter(self.layers)

    def __repr__(self) -> str:
        inner = ", ".join(f"{ls.name or ls.kind}:{ls.kind}"
                          f"{tuple(ls.weight.shape)}" for ls in self.layers)
        return f"ModelSpec([{inner}])"

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_shapes(cls, shapes, n_out: int, *, density: float = 0.4,
                    rng=None, activation: str | None = "relu",
                    scale: float = 0.5) -> "ModelSpec":
        """Paper-style sparse Gaussian weights over ``ConvShape``
        geometry (``configs.paper_cnns``) + a linear head sized from the
        spatial chain; consecutive shapes must be channel-consistent."""
        rng = np.random.default_rng(0) if rng is None else rng
        layers: list[LayerSpec] = []
        ri, ci = shapes[0].ri, shapes[0].ci
        for i, s in enumerate(shapes):
            w = rng.normal(size=(s.m, s.n, s.rk, s.ck)
                           ).astype(np.float32) * scale
            w[rng.random(w.shape) > density] = 0
            layers.append(LayerSpec.conv(w, stride=s.stride,
                                         activation=activation,
                                         name=f"conv{i}"))
            ri = (ri - s.rk) // s.stride + 1
            ci = (ci - s.ck) // s.stride + 1
            if ri < 1 or ci < 1:
                raise ValueError(f"input {shapes[0].ri}x{shapes[0].ci} too "
                                 f"small: feature map vanishes at layer {i}")
        feat = ri * ci * shapes[-1].m
        wl = rng.normal(size=(n_out, feat)).astype(np.float32) * 0.1
        wl[rng.random(wl.shape) > density] = 0
        layers.append(LayerSpec.dense(wl, name="fc"))
        return cls(layers)

    @classmethod
    def from_paper_cnn(cls, net: str, *, n_conv: int = 2, n_out: int = 10,
                       ri: int | None = None, ci: int | None = None,
                       density: float = 0.4, rng=None,
                       activation: str | None = "relu") -> "ModelSpec":
        """Random weights on the published layer geometry of a paper CNN
        (``configs.paper_cnns``: alexnet / vgg16 / googlenet)."""
        shapes = _engine.paper_model_shapes(net, n_conv=n_conv, ri=ri, ci=ci)
        return cls.from_shapes(shapes, n_out, density=density, rng=rng,
                               activation=activation)

    @classmethod
    def from_params(cls, params, *, stride=1, activation=None,
                    linear_layout: str = "out_in",
                    min_size: int = 0) -> "ModelSpec":
        """Ingest **any conv/dense params pytree** (the checkpoint path).

        Walks the pytree in flatten order; every 4-D leaf becomes a conv
        layer (OIHW) and every 2-D leaf a linear layer.  A 1-D leaf in
        the same subtree whose length matches a weight's output features
        becomes that layer's bias.  This subsumes the ingestion half of
        ``serving.codr_compress_params`` — compression accounting for the
        resulting spec comes from ``compile(spec, cfg).stats()``.

        ``stride``        int for all conv layers, or ``{name: int}``.
        ``activation``    ``None``/str for all layers, or ``{name: str}``
                          (names are '/'-joined pytree paths to the
                          weight's subtree, e.g. ``"conv0"``).
        ``linear_layout`` ``"out_in"`` (M, N) — the engine convention —
                          or ``"in_out"`` for ``repro.models``-style
                          ``(d_in, d_out)`` matrices (transposed here).
        ``min_size``      skip weight leaves smaller than this (parallel
                          to ``codr_compress_params``' tiny-leaf filter).
        """
        if linear_layout not in ("out_in", "in_out"):
            raise ValueError(f"linear_layout must be 'out_in' or 'in_out', "
                             f"got {linear_layout!r}")

        def natural_key(name: str):
            # JAX flattens dicts in sorted-key order, which puts
            # "conv10" before "conv2"; compare digit runs numerically so
            # numbered layers keep their intended sequence
            return tuple(tuple((0, int(p)) if p.isdigit() else (1, p)
                               for p in _re.split(r"(\d+)", comp) if p)
                         for comp in name.split("/"))

        flat, _ = jax.tree_util.tree_flatten_with_path(params)
        groups: dict[str, dict] = {}
        for path, leaf in flat:
            arr = np.asarray(leaf)
            keys = [str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path]
            gname = "/".join(keys[:-1]) if len(keys) > 1 else "/".join(keys)
            g = groups.setdefault(gname, {"weights": [], "biases": []})
            if arr.ndim in (2, 4) and arr.size >= min_size:
                g["weights"].append((keys[-1] if len(keys) > 1 else gname,
                                     arr))
            elif arr.ndim == 1:
                g["biases"].append(arr)

        def opt(option, name, default):
            if isinstance(option, dict):
                return option.get(name, default)
            return option

        layers: list[LayerSpec] = []
        for gname in sorted(groups, key=natural_key):
            g = groups[gname]
            for wname, w in g["weights"]:
                name = gname if len(g["weights"]) == 1 else \
                    f"{gname}/{wname}"
                if w.ndim == 2 and linear_layout == "in_out":
                    w = np.ascontiguousarray(w.T)
                # pair by matching length, CONSUMING the bias so two
                # same-shaped weights in one subtree never share one
                bi = next((i for i, b in enumerate(g["biases"])
                           if b.shape == (w.shape[0],)), None)
                bias = None if bi is None else g["biases"].pop(bi)
                if w.ndim == 4:
                    layers.append(LayerSpec.conv(
                        w, bias, stride=opt(stride, name, 1),
                        activation=opt(activation, name, None), name=name))
                else:
                    layers.append(LayerSpec.dense(
                        w, bias, activation=opt(activation, name, None),
                        name=name))
        if not layers:
            raise ValueError("from_params found no 2-D/4-D weight leaves "
                             "in the pytree")
        return cls(layers)


# ---------------------------------------------------------------------------
# stage 2: the encoder configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EncodeConfig:
    """Every offline-encoder knob, in one declarative place.

    ``n_unique``    the paper's U budget (Fig. 6): total quantization
                    levels including zero; 256 = plain int8.
    ``t_m, t_n``    conv output/input-channel tile sizes (§II-D step i).
    ``t_m_linear``  output-feature tile for linear layers (clamped to M).
    ``rle_params``  fixed (delta, rep, index) RLE bit-lengths; ``None``
                    runs the per-layer, per-structure search of §III-C.
    ``decode_source``  ``"bitstream"`` decodes the real RLE streams
                    (default, proves the stored code executes);
                    ``"ucr"`` rebuilds from retained UCR vectors.
    """

    n_unique: int = 256
    t_m: int = 4
    t_n: int = 4
    t_m_linear: int = 256
    rle_params: tuple[int, int, int] | None = None
    decode_source: str = "bitstream"

    def __post_init__(self):
        # n_unique=2 would leave only the zero level (restrict_unique
        # collapses every int8 level to 0 at that setting) — a silently
        # dead model; 3 = zero plus one level per sign is the real floor
        if not 3 <= self.n_unique <= 256:
            raise ValueError(f"n_unique must be in [3, 256], "
                             f"got {self.n_unique}")
        for field in ("t_m", "t_n", "t_m_linear"):
            v = getattr(self, field)
            if not isinstance(v, (int, np.integer)) or isinstance(v, bool):
                raise ValueError(f"{field} must be an integer >= 1, "
                                 f"got {v!r} ({type(v).__name__})")
            if v < 1:
                raise ValueError(f"{field} must be >= 1, got {v} — tile "
                                 f"sizes are channel counts, not flags")
        if self.rle_params is not None:
            try:
                p = tuple(self.rle_params)
            except TypeError:
                p = (self.rle_params,)
            if len(p) != 3:
                raise ValueError(
                    f"rle_params must be a (delta, rep, index) triple of "
                    f"bit-lengths, got {self.rle_params!r}")
            for stream, b in zip(("delta", "rep", "index"), p):
                if not isinstance(b, (int, np.integer)) \
                        or isinstance(b, bool) or not 1 <= b <= 16:
                    raise ValueError(
                        f"rle_params {stream} bit-length must be an "
                        f"integer in [1, 16], got {b!r} (the escape "
                        f"fallback is 8-bit; widths past 16 can never "
                        f"win the §III-C search)")
            object.__setattr__(self, "rle_params",
                               tuple(int(b) for b in p))
        if self.decode_source not in ("bitstream", "ucr"):
            raise ValueError(f"unknown decode_source "
                             f"{self.decode_source!r}")

    def metadata(self) -> dict:
        """JSON-friendly dict — stamped into ``BENCH_*.json`` so perf
        points stay comparable across encode configurations."""
        d = dataclasses.asdict(self)
        d["rle_params"] = (list(self.rle_params)
                          if self.rle_params is not None else None)
        return d


def _plan_config(plan, name: str, default: EncodeConfig) -> EncodeConfig:
    """Resolve a layer's per-layer config from a plan.

    A plan is anything with ``config_for(name, default)`` — e.g.
    :class:`repro.tune.TunePlan` — or a plain ``{name: EncodeConfig}``
    dict.  Layers the plan does not cover get ``default``, so a global
    config is exactly the degenerate empty/one-entry plan.
    """
    if plan is None:
        return default
    config_for = getattr(plan, "config_for", None)
    if config_for is not None:
        cfg = config_for(name, default)
    else:
        cfg = plan.get(name, default)
    if not isinstance(cfg, EncodeConfig):
        raise TypeError(f"plan entry for layer {name!r} must be an "
                        f"EncodeConfig, got {type(cfg).__name__}")
    return cfg


# ---------------------------------------------------------------------------
# stage 3: compile → executable
# ---------------------------------------------------------------------------

class CompiledModel:
    """The executable a :func:`compile` call returns: encode happened
    exactly once, every ``run`` executes from the stored bitstreams via
    the backend bound at compile time (overridable per call).

    Input/output conventions (shared by ``run`` and both oracles):
    batches are float32, NHWC ``(B, RI, CI, N)`` when the first layer is
    a conv (``N`` = its input channels) or ``(B, N)`` for linear-only
    models; activations auto-flatten to ``(B, features)`` at the
    conv→linear boundary.  Outputs are ``(B, out_features)`` of the last
    layer (or NHWC for conv-only models).  Non-float inputs are cast;
    integer-activation backends (``smm``/``smm_kernel``) quantize
    non-integer inputs to int8 internally.
    """

    def __init__(self, model: "_engine.CodrModel", spec: ModelSpec,
                 config: EncodeConfig, backend: _backends.Backend,
                 plan=None):
        self.model = model
        self.spec = spec
        self.config = config
        self.backend = backend
        self.plan = plan              # per-layer tune plan, or None

    # -- execution ----------------------------------------------------------
    def run(self, batch, *, backend=None) -> jax.Array:
        """Forward a batch from the RLE bitstreams.

        ``backend`` (a registered name or a ``Backend`` instance)
        overrides the compile-time choice for this call only; the
        override is capability-checked against the model first, so a
        ``ValueError`` with the reason — unknown name, unsupported
        stride, linear-only kernel handed a conv — is raised *before*
        any dispatch.  Shapes per the class docstring; the first call
        per (backend, input shape) pays that backend's compile cost,
        repeats hit its cache."""
        be = self.backend if backend is None else _backends.resolve(backend)
        if be is not self.backend:
            ok, reason = be.supports_model(self.model.layers)
            if not ok:
                raise ValueError(reason)
        return be.run_model(self.model, batch)

    __call__ = run

    def reference(self, batch) -> jax.Array:
        """Dense float oracle: the ORIGINAL uncompressed weights through
        dense ``lax.conv``/matmul.  ``run`` matches this within int8
        quantization tolerance (tighter as ``n_unique`` grows)."""
        return self.model.reference(batch)

    def quantized_reference(self, batch) -> jax.Array:
        """Dense oracle on the dequantized decoded weights — ``run`` must
        match this up to float summation order (and bit-for-bit for
        integer-valued inputs on the integer datapaths)."""
        return self.model.quantized_reference(batch)

    def serve(self, *, max_batch: int = 8, flush_deadline_s: float = 0.01,
              max_pending: int | None = None):
        """Batched request path over this executable
        (:class:`repro.core.serving.CodrBatchServer`).

        ``max_batch``         dispatch size cap AND the async path's load
                              trigger.
        ``flush_deadline_s``  async latency trigger: the longest a
                              pending :meth:`CodrBatchServer.submit_async`
                              request waits before a partial batch is
                              flushed anyway.
        ``max_pending``       bounded admission: with a full queue,
                              ``submit``/``submit_async`` shed the request
                              with ``RejectedError`` (retry-after hint)
                              instead of queueing unboundedly.  ``None``
                              (default) keeps the queue unbounded.

        The synchronous path (``submit``/``flush``) ignores the deadline —
        the caller owns batching cadence there.  Resilience hooks (fault
        injection, retry/quarantine, crash restart, supervised mesh
        degradation) install via
        ``server.configure_resilience(...)`` — see
        ``repro.runtime.resilience`` and ``docs/DESIGN.md`` §3.5.
        """
        from repro.core.serving import CodrBatchServer
        return CodrBatchServer(self, max_batch=max_batch,
                               flush_deadline_s=flush_deadline_s,
                               max_pending=max_pending)

    # -- accounting ---------------------------------------------------------
    @property
    def trace_count(self) -> int:
        """Total layer (re-)traces of the ``tiled`` dispatch — the
        compile-once regression signal: flat across repeat same-shape
        requests, +1 per layer per new input shape."""
        return self.model.trace_count

    def stats(self):
        """Per-layer :class:`repro.core.engine.LayerStats` (real encoded
        bits from the bitstreams, density, unique counts)."""
        return self.model.stats()

    def total_bits(self) -> int:
        """Real encoded size of the whole model, in bits — counted on
        the variable-width RLE streams (docs/DESIGN.md §2), not on any
        execution-side representation."""
        return self.model.total_bits()

    def bits_per_weight(self) -> float:
        """``total_bits`` over the weight count — the paper's Fig. 6
        compression metric."""
        return self.model.bits_per_weight()

    def sram_report(self, input_hw, **kw):
        """Per-layer SRAM access estimates (paper §IV) for one sample of
        spatial size ``input_hw = (RI, CI)``; spatial dims are tracked
        through the conv stack automatically."""
        return self.model.sram_report(input_hw, **kw)

    def layer_table(self, input_hw: tuple[int, int] | None = None) -> str:
        """Human-readable per-layer accounting: the U budget and
        effective tile each layer encoded under, its measured
        bits/weight, and — when the model was compiled with a tune plan
        — the tuner's predicted bits/weight and SRAM accesses next to
        the measured numbers, so a plan is inspectable without
        re-running the benchmark.

        ``input_hw`` enables the measured-SRAM column (per-layer
        effective tiling, same counting as :meth:`sram_report`); without
        it conv SRAM cannot be counted and the column shows ``-``.
        """
        plan_layers = getattr(self.plan, "layers", None) or {}
        measured_sram: dict[str, float] = {}
        if input_hw is not None:
            measured_sram = {
                name: acc.total_sram
                for name, acc in self.model.sram_report(
                    input_hw, per_layer_tiling=True)}
        hdr = (f"{'layer':<16} {'kind':<7} {'U':>4} {'t_m':>5} "
               f"{'bits/w':>7} {'pred b/w':>9} {'sram':>12} "
               f"{'pred sram':>12}")
        lines = [hdr, "-" * len(hdr)]
        for st in self.stats():
            lp = plan_layers.get(st.name)
            pred_bpw = (f"{lp.predicted_bits_per_weight:9.2f}"
                        if lp is not None else f"{'-':>9}")
            pred_sram = (f"{lp.predicted_sram:12.3e}"
                         if lp is not None else f"{'-':>12}")
            sram = (f"{measured_sram[st.name]:12.3e}"
                    if st.name in measured_sram else f"{'-':>12}")
            lines.append(
                f"{st.name:<16} {st.kind:<7} {st.n_unique_budget:>4} "
                f"{st.t_m:>5} {st.bits_per_weight:7.2f} {pred_bpw} "
                f"{sram} {pred_sram}")
        lines.append(f"{'total':<16} {'':<7} {'':>4} {'':>5} "
                     f"{self.bits_per_weight():7.2f}")
        return "\n".join(lines)

    def verify_roundtrip(self) -> None:
        """Assert decode(bitstreams) == quantize(original floats) for
        every layer; raises ``AssertionError`` naming the first layer
        that mismatches.  Cheap — run it whenever in doubt."""
        self.model.verify_roundtrip()

    def __repr__(self) -> str:
        return (f"CompiledModel({len(self.model.layers)} layers, "
                f"{self.bits_per_weight():.2f} bits/weight, "
                f"backend={self.backend.name!r})")


def compile(spec: ModelSpec, config: EncodeConfig | None = None, *,
            backend: str | _backends.Backend = "tiled",
            plan=None) -> CompiledModel:
    """Run the offline pipeline once over a spec; return the executable.

    The backend is resolved through the registry and capability-checked
    against the spec BEFORE any encoding work, so a stride the backend
    cannot lower or a conv layer handed to a linear-only kernel fails
    fast with the reason.

    ``plan`` — optional per-layer encode configs: a
    :class:`repro.tune.TunePlan` (anything with
    ``config_for(name, default)``) or a plain ``{name: EncodeConfig}``
    dict.  Layers the plan does not name encode under ``config``, so
    the global-config path is exactly the degenerate empty plan —
    bit-identical output, same code path.
    """
    config = EncodeConfig() if config is None else config
    be = _backends.resolve(backend)
    ok, reason = be.supports_model(spec.layers)
    if not ok:
        raise ValueError(f"cannot compile: {reason}")

    layers: list = []
    for i, ls in enumerate(spec.layers):
        name = ls.name or f"layer{i}"
        cfg = _plan_config(plan, name, config)
        if ls.kind == "conv":
            layers.append(_engine.CodrConv2D(
                ls.weight, ls.bias, stride=ls.stride, t_m=cfg.t_m,
                t_n=cfg.t_n, activation=ls.activation, name=name,
                decode_source=cfg.decode_source,
                n_unique=cfg.n_unique, rle_params=cfg.rle_params))
        else:
            layers.append(_engine.CodrLinear(
                ls.weight, ls.bias, t_m=cfg.t_m_linear,
                activation=ls.activation, name=name,
                decode_source=cfg.decode_source,
                n_unique=cfg.n_unique, rle_params=cfg.rle_params))
    return CompiledModel(_engine.CodrModel(layers), spec, config, be,
                         plan=plan)


# ---------------------------------------------------------------------------
# the transformer lane: compile a params pytree in place
# ---------------------------------------------------------------------------

#: path substrings identifying projection leaves in ``repro.models``
#: params (q/k/v/o, MLA a/b, up/gate/down, SSM in/x/dt/out, router and
#: expert stacks).  Embedding matrices deliberately do NOT match: they
#: execute as gathers (`jnp.take`), not matmuls, so they stay dense —
#: quantize-applied like every other large leaf, just not packed.
PACK_INCLUDE = ("proj", "router", "w_experts")
EMBED_INCLUDE = ("embed",)        # (V, d) leaves packed for row-gather


class _ConvLeafShim:
    """Duck-typed layer handed to ``Backend.supports`` so a conv-shaped
    leaf in ``compile_params`` fails with the same capability error
    surface ``compile`` uses."""

    kind = "conv"
    stride = 1

    def __init__(self, name: str):
        self.name = name


@dataclasses.dataclass
class CompiledParams:
    """What :func:`compile_params` returns: the params pytree with every
    projection leaf replaced by its packed bitstream form
    (:class:`repro.core.codr_linear.PackedLinear`), plus the accounting.

    ``params`` drops into ``repro.models`` forwards unchanged —
    ``models.common.linear`` intercepts the packed leaves and resolves
    them through the backend registry, and ``prefill``/``decode_step``
    stay jit-compatible (packed operands are pytree leaves with static
    aux, so repeat decode steps never retrace).  HBM accounting here is
    *measured* on the stored representation (``hbm_bytes``), not
    estimated.
    """

    params: object
    reports: list                 # serving.TensorReport per packed leaf
    packed_paths: list
    quantized_paths: list         # quantize-applied but served dense
    config: EncodeConfig
    backend: str
    plan: object = None           # per-leaf tune plan, or None
    embed_paths: list = dataclasses.field(default_factory=list)

    def packed_leaves(self):
        """``(path_str, PackedLinear | PackedEmbedding)`` pairs,
        flatten order."""
        packed = (_PackedLinear, _PackedEmbedding)
        flat, _ = jax.tree_util.tree_flatten_with_path(
            self.params, is_leaf=lambda l: isinstance(l, packed))
        return [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                          for k in path), leaf)
                for path, leaf in flat if isinstance(leaf, packed)]

    # -- measured accounting ------------------------------------------------
    def hbm_bytes(self) -> int:
        """Real bytes of the packed representation (indices + tables +
        scales) — the number the serving path reports."""
        return sum(pl.hbm_bytes for _, pl in self.packed_leaves())

    def dense_bf16_bytes(self) -> int:
        return sum(pl.n_weights * 2 for _, pl in self.packed_leaves())

    def n_packed_weights(self) -> int:
        return sum(pl.n_weights for _, pl in self.packed_leaves())

    def bits_per_weight(self) -> float:
        return self.hbm_bytes() * 8 / max(self.n_packed_weights(), 1)

    def compression_vs_bf16(self) -> float:
        return self.dense_bf16_bytes() / max(self.hbm_bytes(), 1)

    def summary(self) -> str:
        """Human-readable accounting: the RLE/baseline comparison (when
        accounting ran) plus the measured packed-representation bytes."""
        lines = []
        if self.reports:
            from repro.core.serving import codr_report
            lines.append(codr_report(self.reports))
        lines.append(
            f"packed {len(self.packed_paths)} projection tensors + "
            f"{len(self.embed_paths)} embedding tables "
            f"({self.n_packed_weights() / 1e6:.2f}M weights) for backend "
            f"{self.backend!r}: {self.hbm_bytes() / 1e6:.3f} MB HBM "
            f"measured ({self.bits_per_weight():.2f} bits/weight, "
            f"{self.compression_vs_bf16():.1f}x vs bf16); "
            f"{len(self.quantized_paths)} more tensors quantize-applied, "
            f"served dense")
        return "\n".join(lines)

    def __repr__(self) -> str:
        return (f"CompiledParams({len(self.packed_paths)} packed + "
                f"{len(self.quantized_paths)} quantized leaves, "
                f"{self.bits_per_weight():.2f} bits/weight, "
                f"backend={self.backend!r})")


def compile_params(params, config: EncodeConfig | None = None, *,
                   backend: str | _backends.Backend = "codr_matmul",
                   plan=None,
                   min_size: int | None = None,
                   include: Sequence[str] = PACK_INCLUDE,
                   exclude: Sequence[str] = (),
                   pack_embeddings: bool = True,
                   sample_rows: int | None = 4096,
                   accounting: bool = True) -> CompiledParams:
    """Offline-encode a ``repro.models`` params pytree for serving from
    the compressed representation — the transformer lane of
    :func:`compile` (docs/DESIGN.md §2).

    Every projection leaf (path matches ``include`` and not ``exclude``,
    ``ndim >= 2``, ``size >= min_size``) is quantized under the
    ``config`` U budget and converted to packed bitstream form
    (:class:`~repro.core.codr_linear.PackedLinear`); 2-D leaves matching
    ``EMBED_INCLUDE`` become row-gatherable
    :class:`~repro.core.codr_linear.PackedEmbedding` tables (packed
    lookups are bit-identical to indexing the quantize-applied dense
    table — disable with ``pack_embeddings=False``); every *other*
    large leaf gets the quantization applied in place (gather-consumed
    tensors serve dense), exactly as ``serving.codr_compress_params``
    would — so decode-fused and quantize-applied serving see
    bit-identical weights.  Leading stack
    dims (scanned layer stacks, expert stacks) pack per-matrix under one
    shared quantization, so ``lax.scan`` slices packs like any other
    stacked leaf.

    The ``backend`` must declare ``caps.packed_matmul`` (``codr_matmul``
    — the fused decode+matmul kernel — or ``tiled``/``sharded``, the
    decode-then-matmul reference lane); a conv-shaped leaf that matches
    ``include`` raises that backend's capability error at compile time.
    ``min_size`` defaults to ``serving.MIN_COMPRESS_SIZE``;
    ``sample_rows``/``accounting`` bound the per-tensor RLE accounting
    (the *packed bytes* are always measured in full).

    ``plan`` — optional per-leaf encode configs keyed by the
    '/'-joined pytree path (a :class:`repro.tune.TunePlan` or a plain
    dict, same contract as :func:`compile`); each leaf packs or
    quantizes under its own U budget, leaves the plan does not name use
    ``config``.
    """
    from repro.core import serving as _serving

    config = EncodeConfig() if config is None else config
    be = _backends.resolve(backend)
    if not be.caps.packed_matmul:
        raise ValueError(
            f"backend {be.name!r} has no packed-projection matmul path "
            f"(caps.packed_matmul is False); packed-capable backends: "
            f"{', '.join(n for n in _backends.available_backends() if _backends.get_backend(n).caps.packed_matmul)}")
    if min_size is None:
        min_size = _serving.MIN_COMPRESS_SIZE

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    new_leaves, reports = [], []
    packed_paths, quantized_paths, embed_paths = [], [], []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = np.asarray(leaf)
        cfg = _plan_config(plan, pstr, config)
        wanted = (any(tok in pstr for tok in include)
                  and not any(tok in pstr for tok in exclude))
        if arr.ndim < 2 or arr.size < min_size:
            new_leaves.append(leaf)
            continue
        if (pack_embeddings and arr.ndim == 2
                and any(tok in pstr for tok in EMBED_INCLUDE)
                and not any(tok in pstr for tok in exclude)):
            pe = _pack_embedding(arr, n_unique=cfg.n_unique,
                                 backend=be.name)
            new_leaves.append(pe)
            embed_paths.append(pstr)
            if accounting:
                acc = _serving.account_tensor(arr, n_unique=cfg.n_unique,
                                              sample_rows=sample_rows)
                acc["pack_bits"] = pe.hbm_bytes * 8
                reports.append(_serving.TensorReport(
                    path=pstr, n_weights=arr.size, **acc))
            continue
        if not wanted:
            # quantize-applied, served dense (the codr_compress_params
            # lane) — recurrent state inits, conv stacks, and
            # embeddings when pack_embeddings is off
            mat = arr.reshape(-1, arr.shape[-1])
            deq, _ = _serving._quantize_only(mat, cfg.n_unique)
            new_leaves.append(jnp.asarray(deq.reshape(arr.shape),
                                          dtype=leaf.dtype))
            quantized_paths.append(pstr)
            continue
        if arr.ndim == 4 and max(arr.shape[-2:]) < 16:
            # OIHW conv kernel — BOTH trailing dims are small spatial
            # extents, unlike a stacked expert projection (L, E, d, f)
            # whose trailing matrix dims are wide — surface the
            # backend's capability error
            ok, reason = be.supports(_ConvLeafShim(pstr))
            raise ValueError(reason if not ok else
                             f"compile_params packs linear projections "
                             f"only; conv leaf {pstr!r} must go through "
                             f"ModelSpec.from_params → compile")
        pl = _pack_projection(arr, n_unique=cfg.n_unique,
                              backend=be.name)
        new_leaves.append(pl)
        packed_paths.append(pstr)
        if accounting:
            acc = _serving.account_tensor(arr.reshape(-1, arr.shape[-1]),
                                          n_unique=cfg.n_unique,
                                          sample_rows=sample_rows)
            acc["pack_bits"] = pl.hbm_bytes * 8  # measured, not estimated
            reports.append(_serving.TensorReport(
                path=pstr, n_weights=arr.size, **acc))
    if not packed_paths:
        raise ValueError(
            "compile_params found no packable projection leaves "
            f"(include={tuple(include)!r}, min_size={min_size}) — for "
            "conv/dense checkpoint pytrees use ModelSpec.from_params")
    return CompiledParams(jax.tree_util.tree_unflatten(treedef, new_leaves),
                          reports, packed_paths, quantized_paths, config,
                          be.name, plan, embed_paths=embed_paths)
