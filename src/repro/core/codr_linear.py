"""CoDR-compressed linear layers for JAX models.

Three representations of the same weights, used at different levels:

1. **RLE streams** (`repro.core.rle`) — the paper's exact variable-width
   format.  Used for DRAM/storage accounting and the offline encoder; a
   variable-width bitstream is not expressible as a static-shape XLA
   buffer, so it does not appear in compiled graphs (documented in
   docs/DESIGN.md §2).
2. **Fixed-width unique-index pack** — the TPU-native adaptation: weights
   stored as ``b``-bit indices into a per-tensor sorted unique table,
   packed into uint32 words.  ``b = ceil(log2(U))`` is searched like the
   paper's encoding parameter, subject to TPU word alignment.  This is the
   format the Pallas kernel decodes in VMEM; HBM traffic = b/8 bytes per
   weight.
3. **Plain int8 + scale** — weight-only quantization fallback, XLA-visible
   in the dry-run serving graphs (1 byte/weight HBM traffic).

The unique-table format realises *weight repetition* and *sparsity*
(zero is just another table entry) in the kernel; *similarity* (Δ
encoding) lives in representation 1, where variable-width coding is
possible.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PackedWeight", "pack_unique", "unpack_unique",
           "codr_matmul_ref", "choose_bits"]


@dataclasses.dataclass
class PackedWeight:
    """Fixed-width unique-index packed weight for a (K, N) matrix."""

    packed: jax.Array      # (K, N * bits // 32) uint32
    table: jax.Array       # (2**bits,) float32/bf16 unique values (padded)
    scale: jax.Array       # per-tensor or per-column scale
    bits: int
    shape: tuple[int, int]

    @property
    def hbm_bytes(self) -> int:
        return self.packed.size * 4 + self.table.size * 2 + self.scale.size * 4

    @property
    def dense_bf16_bytes(self) -> int:
        return int(np.prod(self.shape)) * 2

    @property
    def compression_vs_bf16(self) -> float:
        return self.dense_bf16_bytes / self.hbm_bytes


def choose_bits(n_unique: int) -> int:
    """Smallest TPU-friendly index width covering ``n_unique`` values.
    Widths are restricted to divisors of 32 (clean word packing)."""
    for b in (1, 2, 4, 8, 16):
        if n_unique <= (1 << b):
            return b
    raise ValueError(f"too many unique values: {n_unique}")


def pack_unique(q: np.ndarray, scale: np.ndarray | float,
                dtype=jnp.bfloat16) -> PackedWeight:
    """Pack an int8 (K, N) weight matrix into the unique-index format."""
    q = np.asarray(q)
    assert q.ndim == 2, q.shape
    k, n = q.shape
    table = np.unique(q)                            # sorted ascending
    bits = choose_bits(len(table))
    per_word = 32 // bits
    if n % per_word:
        raise ValueError(f"N={n} not divisible by {per_word} ({bits}-bit pack)")
    idx = np.searchsorted(table, q).astype(np.uint32)   # (K, N)
    idx = idx.reshape(k, n // per_word, per_word)
    shifts = (np.arange(per_word, dtype=np.uint32) * bits)[None, None, :]
    packed = (idx << shifts).astype(np.uint32).sum(axis=-1, dtype=np.uint32)
    padded = np.zeros(1 << bits, dtype=np.float32)
    padded[: len(table)] = table
    return PackedWeight(
        packed=jnp.asarray(packed),
        table=jnp.asarray(padded, dtype=dtype),
        scale=jnp.asarray(scale, dtype=jnp.float32),
        bits=bits, shape=(k, n))


@partial(jax.jit, static_argnames=("bits", "n"))
def unpack_unique(packed: jax.Array, table: jax.Array, *, bits: int,
                  n: int) -> jax.Array:
    """Decode packed indices → dense weight matrix (table gather)."""
    per_word = 32 // bits
    shifts = jnp.arange(per_word, dtype=jnp.uint32) * bits
    mask = jnp.uint32((1 << bits) - 1)
    idx = (packed[:, :, None] >> shifts[None, None, :]) & mask
    idx = idx.reshape(packed.shape[0], n)
    return jnp.take(table, idx.astype(jnp.int32), axis=0)


def codr_matmul_ref(x: jax.Array, w: PackedWeight) -> jax.Array:
    """Reference decode-then-matmul (the Pallas kernel fuses these)."""
    dense = unpack_unique(w.packed, w.table, bits=w.bits, n=w.shape[1])
    y = jnp.dot(x.astype(jnp.float32), dense.astype(jnp.float32))
    return (y * w.scale).astype(x.dtype)
