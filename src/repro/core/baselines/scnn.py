"""SCNN weight compression (paper §V-B).

"SCNN does not compress the non-zero weights and stores the number of
zero values between two subsequent non-zero weights in 4 bits."  When a
zero-run exceeds 15 a zero-valued placeholder weight is inserted (the
standard SCNN escape)."""
from __future__ import annotations

import numpy as np

RUN_BITS = 4
WEIGHT_BITS = 8
MAX_RUN = (1 << RUN_BITS) - 1


def scnn_compress_bits(q: np.ndarray) -> int:
    """Encoded size in bits of an int8 weight tensor under SCNN's scheme."""
    flat = np.asarray(q).reshape(-1)
    nz = np.nonzero(flat)[0]
    if len(nz) == 0:
        return WEIGHT_BITS + RUN_BITS  # single placeholder
    runs = np.diff(nz, prepend=-1) - 1           # zeros before each nonzero
    # placeholders for overflowing runs: each covers MAX_RUN zeros + a
    # zero weight entry
    placeholders = int((runs // (MAX_RUN + 1)).sum())
    n_entries = len(nz) + placeholders
    return n_entries * (WEIGHT_BITS + RUN_BITS)
