from repro.data.pipeline import (DataConfig, SyntheticTokenDataset,
                                 make_batch_specs, host_batch_iterator)

__all__ = ["DataConfig", "SyntheticTokenDataset", "make_batch_specs",
           "host_batch_iterator"]
