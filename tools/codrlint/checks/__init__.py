"""codrlint checkers — importing this package registers them all
(import-time registration, mirroring ``repro.core.backends``)."""
from tools.codrlint.checks import (capability,  # noqa: F401
                                   exception_hygiene, exports, jit_purity,
                                   lock_discipline, pytree)

__all__ = ["capability", "exception_hygiene", "exports", "jit_purity",
           "lock_discipline", "pytree"]
