"""Straggler detection & mitigation.

On a 1000+-node cluster the slowest host sets the step time (synchronous
SPMD).  The monitor keeps an EWMA of per-host step-report times; hosts
whose reported time exceeds ``threshold ×`` the fleet median for
``patience`` consecutive steps are flagged.  Mitigation is a policy
callback — the default recommendation ladder is:

  1. ``rebalance``  — shrink the flagged host's data shard (batch
     re-split, cheap, reversible),
  2. ``evict``      — hand the host to :class:`ElasticMeshManager` for a
     re-mesh without it (checkpoint → re-shard → resume).

On this single-host container the monitor is exercised by the tests with
synthetic timing streams; the interfaces are what a real deployment wires
to its control plane.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    ewma_alpha: float = 0.2
    threshold: float = 1.5         # × fleet median
    patience: int = 5              # consecutive flagged steps before action
    evict_threshold: float = 3.0   # × median → recommend eviction


class StragglerMonitor:
    def __init__(self, n_hosts: int, cfg: StragglerConfig | None = None):
        self.cfg = cfg or StragglerConfig()
        self.n_hosts = n_hosts
        self.ewma = np.zeros(n_hosts)
        self.flag_streak = np.zeros(n_hosts, dtype=np.int64)
        self.initialized = False

    def observe(self, host_step_times: np.ndarray) -> dict:
        """Feed one step's per-host wall times; returns actions."""
        t = np.asarray(host_step_times, dtype=np.float64)
        if not self.initialized:
            self.ewma[:] = t
            self.initialized = True
        else:
            a = self.cfg.ewma_alpha
            self.ewma = (1 - a) * self.ewma + a * t
        med = np.median(self.ewma)
        if med <= 0:
            # degenerate fleet (all-zero / mostly-zero timings, e.g. a
            # cold start or a clock that hasn't ticked): any positive
            # entry would ratio to +inf against a zero median and flag
            # spuriously — report no evidence instead, and reset streaks
            # so garbage samples never accumulate toward an action
            self.flag_streak[:] = 0
            return {"median": float(med),
                    "ratio": np.ones(self.n_hosts), "actions": {}}
        ratio = self.ewma / med
        flagged = ratio > self.cfg.threshold
        self.flag_streak = np.where(flagged, self.flag_streak + 1, 0)
        actions = {}
        for h in np.nonzero(self.flag_streak >= self.cfg.patience)[0]:
            if ratio[h] > self.cfg.evict_threshold:
                actions[int(h)] = "evict"
            else:
                actions[int(h)] = "rebalance"
        return {"median": float(med), "ratio": ratio, "actions": actions}
