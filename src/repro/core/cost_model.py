"""Energy/area cost model (paper §V, Figs. 7–8).

Constants follow the paper where it states them (DRAM 160 pJ/B, 45 nm,
250 kB feature SRAMs + 200 kB weight SRAM, 2.85 mm² equal-area designs)
and standard 45 nm numbers elsewhere (Horowitz, "Computing's energy
problem", ISSCC'14; CACTI 6.0 for SRAM scaling).  Absolute joules are
model estimates; the *relative* CoDR/UCNN/SCNN comparisons are the
reproduction target.
"""
from __future__ import annotations

import dataclasses

from repro.core.dataflow import AccessCounts, ConvShape, TilingConfig, \
    codr_accesses

# --- 45 nm energy constants (pJ) -------------------------------------------
DRAM_PJ_PER_BYTE = 160.0          # paper §V-A
SRAM_8B_PJ = 10.0                 # 8-bit random access, 250 kB bank (CACTI)
SRAM_ROW_PJ = 20.0                # 64-bit sequential wide-row read, 200 kB
RF_8B_PJ = 0.3                    # small register file access
MULT_INT8_PJ = 0.2                # Horowitz ISSCC'14
ADD_INT16_PJ = 0.05
XBAR_PJ = 0.08                    # per routed partial product


@dataclasses.dataclass
class EnergyBreakdown:
    name: str
    dram_uj: float
    sram_uj: float
    rf_uj: float
    alu_uj: float
    crossbar_uj: float

    @property
    def total_uj(self) -> float:
        return (self.dram_uj + self.sram_uj + self.rf_uj + self.alu_uj
                + self.crossbar_uj)

    def as_dict(self) -> dict:
        return {
            "name": self.name, "dram_uj": self.dram_uj, "sram_uj": self.sram_uj,
            "rf_uj": self.rf_uj, "alu_uj": self.alu_uj,
            "crossbar_uj": self.crossbar_uj, "total_uj": self.total_uj,
        }


def energy(acc: AccessCounts) -> EnergyBreakdown:
    """Per-layer energy from access counts."""
    dram_bytes = acc.dram_weight_bits / 8.0 + acc.dram_feature_bytes
    dram = dram_bytes * DRAM_PJ_PER_BYTE
    sram = (acc.input_sram + acc.output_sram) * SRAM_8B_PJ \
        + acc.weight_sram_rows * SRAM_ROW_PJ
    rf = (acc.input_rf + acc.weight_rf + acc.output_rf) * RF_8B_PJ
    alu = acc.mults * MULT_INT8_PJ + acc.accums * ADD_INT16_PJ
    xbar = acc.crossbar * XBAR_PJ
    return EnergyBreakdown(acc.name, dram * 1e-6, sram * 1e-6, rf * 1e-6,
                           alu * 1e-6, xbar * 1e-6)


def layer_cost(shape: ConvShape, tiling: TilingConfig,
               compressed_bits: float, n_unique: float,
               n_nonzero: float) -> dict:
    """One candidate point for the encoding tuner: SRAM access count and
    energy under the CoDR dataflow for a layer encoded to
    ``compressed_bits`` with the given tile geometry.  Returns a flat
    dict (``sram``/``energy_uj`` plus the underlying breakdowns) so
    :mod:`repro.tune` can rank candidates without re-deriving either."""
    acc = codr_accesses(shape, tiling, compressed_bits, n_unique,
                        n_nonzero)
    e = energy(acc)
    return {"sram": acc.total_sram, "energy_uj": e.total_uj,
            "accesses": acc, "energy": e}


def weight_sram_cost_ratio(bits_per_weight: float,
                           row_bits: int = 64) -> float:
    """How much cheaper one *weight* access is than one 8-bit feature
    access (paper reports 20.61× for CoDR, 12.17× UCNN, 4.34× SCNN)."""
    per_weight_pj = SRAM_ROW_PJ * bits_per_weight / row_bits
    return SRAM_8B_PJ / per_weight_pj
