"""§Roofline — derive the three roofline terms per (arch × shape × mesh)
from the dry-run's compiled artifacts (experiments/dryrun/*.json).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s      (cost_analysis is
               per-device for SPMD-partitioned modules)
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / link_bw

MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (serve);
the ratio MODEL_FLOPS / global_HLO_FLOPs exposes remat/dispatch waste.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv_line
from repro.configs import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

_FIX = {
    "compute": "raise arithmetic intensity (bigger per-chip batch/seq "
               "shard) or cast more matmuls to bf16",
    "memory": "cut HBM traffic: CoDR weight compression, int8 KV cache, "
              "fewer remat passes, fused attention",
    "collective": "reshard to cheaper collectives: 2D weight-stationary "
                  "serving, overlap psum with compute, bf16 grads",
}


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token/seq


def load_records(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_terms(rec: dict) -> dict | None:
    if rec.get("status") != "OK":
        return None
    chips = rec["n_devices"]
    la = rec.get("hlo_loop_aware") or {}
    # loop-aware parse preferred; xla cost_analysis counts while bodies
    # once and under-reports scanned-layer models by n_layers×
    fl = la.get("flops") or rec["cost"]["flops"] or 0.0
    by = la.get("bytes") or rec["cost"]["bytes_accessed"] or 0.0
    cb = rec["collectives"]["total_bytes"] or 0.0
    t_c = fl / PEAK_FLOPS_BF16
    t_m = by / HBM_BW
    t_x = cb / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"])
    ratio = mf / max(fl * chips, 1.0)
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom, "model_flops": mf, "useful_ratio": ratio,
            "fix": _FIX[dom],
            "roofline_frac": max(t_c, t_m, t_x) and t_c / max(t_c, t_m, t_x)}


def markdown_table(recs: list[dict]) -> str:
    rows = ["| arch | shape | mesh | compute s | memory s | collective s |"
            " dominant | useful FLOPs ratio | peak GB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for rec in recs:
        if rec.get("status") == "SKIP":
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} |"
                        f" — | — | — | SKIP | — | — |")
            continue
        t = roofline_terms(rec)
        if t is None:
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} |"
                        f" FAIL | | | | | |")
            continue
        peak = (rec["memory"]["peak_bytes"] or 0) / 1e9
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | **{t['dominant']}** "
            f"| {t['useful_ratio']:.2f} | {peak:.1f} |")
    return "\n".join(rows)


def main(print_fn=print) -> list[str]:
    recs = load_records()
    lines = []
    for rec in recs:
        t = roofline_terms(rec)
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("tag"):
            name += f"/{rec['tag']}"
        if rec.get("status") == "SKIP":
            lines.append(csv_line(name, 0.0, "SKIP"))
        elif t is None:
            lines.append(csv_line(name, 0.0, "FAIL"))
        else:
            step_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
            lines.append(csv_line(
                name, step_s * 1e6,
                f"dom={t['dominant']};compute={t['compute_s']:.3e}"
                f";memory={t['memory_s']:.3e}"
                f";collective={t['collective_s']:.3e}"
                f";useful={t['useful_ratio']:.2f}"))
        print_fn(lines[-1])
    return lines


if __name__ == "__main__":
    main()
