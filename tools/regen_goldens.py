"""Regenerate the golden-bitstream vectors under ``tests/golden/``.

    PYTHONPATH=src python tools/regen_goldens.py

The goldens freeze the on-disk/byte layout of every serialized format
in the engine — RLE streams, fixed-width unique-index packs, int8 KV
pages, and the packed checkpoint artifact — so an accidental encoding
change fails ``tests/test_golden_formats.py`` byte-for-byte instead of
silently corrupting every previously written artifact.

If a format change is INTENTIONAL: bump ``CODR_FORMAT_VERSION`` in
``src/repro/checkpoint/packed.py``, rerun this script, and say why in
the PR.  bf16 arrays are stored as uint16 bit-pattern views (``.npz``
cannot carry the dtype); the builders below are the single source of
truth for both the goldens and the test's "current bytes" side.
"""
from __future__ import annotations

import os

import numpy as np

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tests", "golden")


def _bits(a: np.ndarray) -> np.ndarray:
    """npz-safe bit-pattern view (bf16 → uint16; others unchanged)."""
    if str(a.dtype) == "bfloat16":
        return np.asarray(a).view(np.uint16)
    return np.asarray(a)


def build_rle_golden() -> dict[str, np.ndarray]:
    """One UCR vector through ``rle.encode_vector``: all three stream
    payloads plus their chosen params and exact bit counts."""
    from repro.core import rle

    unique_vals = np.array([-90, -17, -5, 3, 12, 101], np.int64)
    reps = np.array([2, 1, 4, 3, 2, 1], np.int64)
    # per-unique ascending positions, sum(reps)=13 indexes in [0, 24)
    indexes = np.array([1, 20, 7, 0, 3, 9, 15, 2, 11, 23, 5, 18, 4],
                       np.int64)
    enc = rle.encode_vector(unique_vals, reps, indexes, vector_len=24)
    out: dict[str, np.ndarray] = {}
    for name, stream in (("deltas", enc.deltas), ("reps", enc.reps),
                         ("indexes", enc.indexes)):
        out[f"{name}_packed"] = np.asarray(stream.packed, np.uint8)
        out[f"{name}_meta"] = np.array(
            [stream.nbits, stream.param, stream.count, stream.mode_bits],
            np.int64)
    out["total_bits"] = np.array([enc.total_bits], np.int64)
    return out


def build_packed_weight_golden() -> dict[str, np.ndarray]:
    """``pack_projection`` on a fixed matrix: the uint32 word stream,
    the unique-value table bits, and the scale."""
    from repro.core.codr_linear import pack_projection

    rng = np.random.default_rng(7)
    w = (rng.normal(size=(12, 10)) * 0.2).astype(np.float32)
    pl = pack_projection(w, n_unique=16)
    return {
        "packed": _bits(pl.weight.packed),
        "table": _bits(pl.weight.table),
        "scale": _bits(pl.weight.scale),
        "meta": np.array([pl.weight.bits, *pl.weight.shape,
                          pl.out_features], np.int64),
    }


def build_paged_kv_golden() -> dict[str, np.ndarray]:
    """A deterministic int8 paged-KV write sequence: final page bytes
    and per-page scales after 10 token writes over 2 slots."""
    import jax.numpy as jnp

    from repro.models import cache

    spec = cache.PagedSpec(page_size=4, max_len=12, n_slots=2,
                           kv_dtype="int8")
    pkv = cache.paged_kv_init(spec, (2, 3))
    table = np.arange(1, 1 + 2 * spec.max_pages,
                      dtype=np.int32).reshape(2, spec.max_pages)
    pkv = cache.set_tables(pkv, jnp.asarray(table))
    rng = np.random.default_rng(21)
    for t in range(10):
        row = rng.normal(size=(2, 1, 2, 3)).astype(np.float32)
        pkv = pkv.update(jnp.asarray(row, jnp.bfloat16), jnp.int32(t))
    return {
        "data": np.asarray(pkv.data),
        "scale": np.asarray(pkv.scale),
        "table": np.asarray(pkv.table),
    }


def build_checkpoint_golden() -> dict[str, np.ndarray]:
    """The packed checkpoint manifest + array bytes for a tiny
    deterministic params tree (one projection, one embedding, one dense
    leaf) — the full artifact byte layout, filesystem-free."""
    import json

    import repro.api as codr
    from repro.checkpoint.packed import build_manifest

    rng = np.random.default_rng(3)
    params = {
        "blk": {"q_proj": (rng.normal(size=(16, 12)) * 0.1
                           ).astype(np.float32)},
        "embed": (rng.normal(size=(24, 8)) * 0.1).astype(np.float32),
        "norm": np.ones((12,), np.float32),
    }
    cp = codr.compile_params(params, codr.EncodeConfig(n_unique=16),
                             min_size=0, sample_rows=None)
    manifest, arrays = build_manifest(cp)
    out = {"manifest": np.frombuffer(
        json.dumps(manifest, indent=1).encode(), np.uint8)}
    for i, a in enumerate(arrays):
        out[f"arr_{i}"] = _bits(a)
    return out


BUILDERS = {
    "rle_stream": build_rle_golden,
    "packed_weight": build_packed_weight_golden,
    "paged_kv_int8": build_paged_kv_golden,
    "packed_checkpoint": build_checkpoint_golden,
}


def main() -> None:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for name, build in BUILDERS.items():
        path = os.path.join(GOLDEN_DIR, f"{name}.npz")
        np.savez(path, **build())
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
