"""Kernel-level benchmark: the CoDR compressed matmul's data-movement
win.  Interpret-mode Pallas timings are meaningless, so on CPU we time
the jnp reference path (decode + matmul vs dense matmul) and report the
structural quantities that matter on the TPU target: HBM bytes moved per
weight and the roofline-model speedup for a weight-bound decode matmul."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line
from repro.core import ucr
from repro.core.codr_linear import pack_unique
from repro.core.serving import restrict_unique
from repro.kernels.codr_matmul.ref import codr_matmul_ref

HBM_BW = 819e9
PEAK = 197e12


def _time(fn, *args, iters=5):
    fn(*args).block_until_ready()
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.monotonic() - t0) / iters


def main(print_fn=print) -> list[str]:
    rng = np.random.default_rng(3)
    lines = []
    k, n = 2048, 2048
    w = rng.normal(size=(k, n)).astype(np.float32) * 0.02
    q, s = ucr.quantize_int8(w)
    for n_unique, batch in ((16, 8), (4, 8), (16, 128)):
        qr = restrict_unique(q, n_unique)
        pw = pack_unique(qr, s, dtype=jnp.float32)
        x = jnp.asarray(rng.normal(size=(batch, k)).astype(np.float32))
        dense = jnp.asarray(qr.astype(np.float32) * s)

        t_ref = _time(jax.jit(lambda xx: codr_matmul_ref(
            xx, pw.packed, pw.table, pw.scale.reshape(-1),
            bits=pw.bits, n=n)), x)
        t_dense = _time(jax.jit(lambda xx: xx @ dense), x)

        # structural (TPU-target) model: weight-bound decode matmul time is
        # bytes/BW; compression shrinks it by the pack ratio.
        bytes_dense = k * n * 2
        bytes_codr = pw.packed.size * 4
        t_mem_dense = bytes_dense / HBM_BW
        t_mem_codr = bytes_codr / HBM_BW
        t_compute = 2 * batch * k * n / PEAK
        speedup = max(t_mem_dense, t_compute) / max(t_mem_codr, t_compute)
        name = f"kernel_codr_matmul/U{n_unique}/B{batch}"
        derived = (f"pack_ratio={pw.compression_vs_bf16:.2f}"
                   f";tpu_model_speedup={speedup:.2f}"
                   f";cpu_ref_overhead={t_ref/t_dense:.2f}")
        lines.append(csv_line(name, t_ref * 1e6, derived))
        print_fn(lines[-1])

    # SMM op-count benchmark (the paper's ALU story on a conv layer)
    wconv = rng.normal(size=(64, 32, 3, 3)).astype(np.float32)
    wconv[rng.random(wconv.shape) < 0.6] = 0
    code = ucr.encode_conv_layer(wconv, t_m=4, t_n=4)
    from repro.core.smm import smm_op_counts
    c = smm_op_counts(code, feature_elems=400)
    lines.append(csv_line(
        "kernel_smm_conv/op_counts", 0.0,
        f"mults_vs_dense={c['mults']/c['dense_mults']:.3f}"
        f";density={c['density']:.2f}"
        f";unique_ratio={c['unique_ratio']:.2f}"))
    print_fn(lines[-1])
    return lines


if __name__ == "__main__":
    main()
