"""Quantized paged KV cache: differential + bit-identity contracts.

The contracts under test (docs/DESIGN.md §2.2):

* ``kv_dtype="bf16"`` paged is the escape hatch — every logit bit must
  equal the dense contiguous cache's, pooled or solo, GQA or MLA.
* ``kv_dtype="int8"`` is lossy but *deterministic*: a pooled run is
  bit-identical to a solo run in the same mode, and a teacher-forced
  replay of the dense reference's tokens stays within a stated fraction
  of the dense logit spread (the int8 quantization floor measures
  ~0.01; the bound asserts 0.10).
* The page pool is all-or-nothing at admission and pages are freed on
  retirement — a pool smaller than the concurrent demand serializes
  requests instead of corrupting them.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.batching import ContinuousBatcher
from repro.models import cache, get_model

GQA, MLA = "qwen2.5-3b", "deepseek-v2-236b"


def _params(arch, key):
    cfg = smoke_variant(get_config(arch))
    return cfg, get_model(cfg).init_params(key, cfg)


def _prompt(n, vocab, seed=0):
    return np.random.default_rng(seed).integers(
        0, vocab, size=n).astype(np.int32)


# ---------------------------------------------------------------------------
# PagedKV unit level: write/gather round-trips
# ---------------------------------------------------------------------------

def _roundtrip(kv_dtype, page_size, seq_len, feat, rng, *, n_slots=2):
    spec = cache.PagedSpec(page_size=page_size,
                           max_len=-(-seq_len // page_size) * page_size,
                           n_slots=n_slots, kv_dtype=kv_dtype)
    pkv = cache.paged_kv_init(spec, feat)
    table = np.arange(1, 1 + n_slots * spec.max_pages,
                      dtype=np.int32).reshape(n_slots, spec.max_pages)
    pkv = cache.set_tables(pkv, jnp.asarray(table))
    dense = rng.normal(size=(n_slots, seq_len, *feat)).astype(np.float32)
    dense = np.asarray(jnp.asarray(dense, jnp.bfloat16), np.float32)
    for t in range(seq_len):
        pkv = pkv.update(jnp.asarray(dense[:, t:t + 1], jnp.bfloat16),
                         jnp.int32(t))
    got = np.asarray(pkv.gather()[:, :seq_len], np.float32)
    return dense, got


def test_paged_bf16_roundtrip_bitwise(rng):
    dense, got = _roundtrip("bf16", 4, 10, (3, 5), rng)
    np.testing.assert_array_equal(got, dense)


def test_paged_int8_roundtrip_within_quant_floor(rng):
    dense, got = _roundtrip("int8", 4, 10, (3, 5), rng)
    # per-page scale is grow-only amax/127; one requantization per later
    # row write adds at most another step — 2 quant steps of headroom
    err = np.abs(got - dense).max()
    assert err <= 2.0 * np.abs(dense).max() / 127.0
    assert err > 0                           # int8 is genuinely lossy


def test_paged_int8_tail_positions_zero(rng):
    # gather pads to whole pages then crops to seq_len: the crop is what
    # keeps summation shapes identical to the dense cache
    spec = cache.PagedSpec(page_size=4, max_len=8, n_slots=1,
                           kv_dtype="int8")
    pkv = cache.paged_kv_init(spec, (2,))
    pkv = cache.set_tables(pkv, jnp.asarray([[1, 2]], np.int32))
    pkv = pkv.update(jnp.ones((1, 1, 2), jnp.bfloat16), jnp.int32(0))
    g = np.asarray(pkv.gather(), np.float32)
    assert g.shape == (1, 8, 2)
    np.testing.assert_array_equal(g[:, 1:], 0.0)


def test_page_pool_all_or_nothing_and_free():
    spec = cache.PagedSpec(page_size=4, max_len=8, n_slots=2)
    pool = cache.PagePool(spec)                    # 4 usable + scratch
    assert pool.available == 4
    a, b = pool.alloc(2), pool.alloc(2)
    assert a is not None and b is not None
    assert cache.SCRATCH_PAGE not in a + b
    assert pool.alloc(1) is None                   # nothing left — refuse
    assert pool.available == 0                     # ...and nothing leaked
    pool.free(a)
    assert pool.available == 2
    assert pool.alloc(2) is not None


def test_paged_spec_validation():
    with pytest.raises(ValueError):
        cache.PagedSpec(page_size=0, max_len=8, n_slots=1)
    with pytest.raises(ValueError):
        cache.PagedSpec(page_size=4, max_len=8, n_slots=1, kv_dtype="fp4")
    with pytest.raises(ValueError):
        # pool smaller than one request's worst case can never admit
        cache.PagedSpec(page_size=4, max_len=16, n_slots=1,
                        n_pages=2).total_pages


# ---------------------------------------------------------------------------
# batcher level: bf16 bit-identity, int8 determinism + differential bound
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", [GQA, MLA])
def test_bf16_paged_bit_identical_to_dense(arch, key):
    cfg, params = _params(arch, key)
    prompt = _prompt(6, cfg.vocab_size)
    dense = ContinuousBatcher(params, cfg, n_slots=2, max_len=32)
    paged = ContinuousBatcher(params, cfg, n_slots=2, max_len=32,
                              kv_dtype="bf16", kv_page_size=4)
    ref_toks, _ = dense.generate_reference(prompt, max_new_tokens=6)
    got_toks, _ = paged.generate_reference(prompt, max_new_tokens=6)
    assert got_toks == ref_toks
    np.testing.assert_array_equal(
        paged.replay_logits(prompt, ref_toks),
        dense.replay_logits(prompt, ref_toks))


@pytest.mark.parametrize("arch", [GQA, MLA])
def test_int8_paged_teacher_forced_within_bound(arch, key):
    cfg, params = _params(arch, key)
    prompt = _prompt(6, cfg.vocab_size, seed=1)
    dense = ContinuousBatcher(params, cfg, n_slots=2, max_len=32)
    paged = ContinuousBatcher(params, cfg, n_slots=2, max_len=32,
                              kv_dtype="int8", kv_page_size=4)
    ref_toks, _ = dense.generate_reference(prompt, max_new_tokens=6)
    ref_rows = dense.replay_logits(prompt, ref_toks)
    got_rows = paged.replay_logits(prompt, ref_toks)
    # prefill logits never touch the paged cache — bit-exact
    np.testing.assert_array_equal(got_rows[0], ref_rows[0])
    spread = float(ref_rows.max() - ref_rows.min())
    dev = float(np.abs(got_rows - ref_rows).max()) / spread
    assert dev < 0.10, dev


def test_int8_pooled_bit_identical_to_int8_solo(key):
    # lossy versus *dense*, but deterministic versus itself: the pooled
    # run must reproduce the same-mode solo reference exactly, whatever
    # physical page ids the allocator picked
    cfg, params = _params(GQA, key)
    b = ContinuousBatcher(params, cfg, n_slots=3, max_len=32,
                          kv_dtype="int8", kv_page_size=4)
    with b:
        prompts = [_prompt(4 + i, cfg.vocab_size, seed=i)
                   for i in range(5)]
        hs = [b.submit(p, max_new_tokens=5) for p in prompts]
        outs = [h.result(timeout=120) for h in hs]
    for p, s in zip(prompts, outs):
        ref, _ = b.generate_reference(p, max_new_tokens=5)
        assert s == ref


def test_page_exhaustion_serializes_not_corrupts(key):
    # pool sized for ONE request's worst case: admission must gate on
    # page availability and retirement must free pages, so both requests
    # finish (serialized) with solo-identical outputs
    cfg, params = _params(GQA, key)
    # 4 usable pages; each request needs 3 (prompt 5 + gen 5 = 10 toks)
    b = ContinuousBatcher(params, cfg, n_slots=2, max_len=16,
                          kv_dtype="int8", kv_page_size=4, kv_pages=5)
    with b:
        prompts = [_prompt(5, cfg.vocab_size, seed=i) for i in range(2)]
        hs = [b.submit(p, max_new_tokens=5) for p in prompts]
        outs = [h.result(timeout=120) for h in hs]
    assert b.peak_active == 1                      # never ran concurrently
    for p, s in zip(prompts, outs):
        ref, _ = b.generate_reference(p, max_new_tokens=5)
        assert s == ref


def test_kv_bytes_int8_smaller_than_bf16(key):
    cfg, params = _params(GQA, key)
    bf = ContinuousBatcher(params, cfg, n_slots=2, max_len=32)
    i8 = ContinuousBatcher(params, cfg, n_slots=2, max_len=32,
                           kv_dtype="int8", kv_page_size=4)
    assert 0 < i8.kv_bytes() < bf.kv_bytes()


def test_paged_rejections(key):
    cfg, params = _params(GQA, key)
    with pytest.raises(ValueError, match="kv_dtype"):
        ContinuousBatcher(params, cfg, kv_dtype="fp8")
    api = get_model(cfg)
    spec = cache.PagedSpec(page_size=4, max_len=16, n_slots=2)
    with pytest.raises(ValueError):
        api.init_cache(cfg, 3, 16, paged=spec)     # batch != n_slots
    ecfg = smoke_variant(get_config("seamless-m4t-medium"))
    with pytest.raises(NotImplementedError):
        get_model(ecfg).init_cache(ecfg, 2, 16, paged=spec)


# ---------------------------------------------------------------------------
# hypothesis property: paged round-trip across geometry (optional dep)
# ---------------------------------------------------------------------------

def test_paged_roundtrip_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(page_size=st.integers(1, 8), seq_len=st.integers(1, 16),
           n_heads=st.integers(1, 3), head_dim=st.integers(1, 4),
           kv_dtype=st.sampled_from(["bf16", "int8"]),
           seed=st.integers(0, 2**31 - 1))
    def prop(page_size, seq_len, n_heads, head_dim, kv_dtype, seed):
        rng = np.random.default_rng(seed)
        dense, got = _roundtrip(kv_dtype, page_size, seq_len,
                                (n_heads, head_dim), rng, n_slots=1)
        if kv_dtype == "bf16":
            np.testing.assert_array_equal(got, dense)
        else:
            amax = np.abs(dense).max()
            assert np.abs(got - dense).max() <= 2.0 * amax / 127.0 + 1e-7

    prop()
