"""Golden-bitstream suite: every serialized format byte-for-byte.

Each test rebuilds a format's bytes from the deterministic builders in
``tools/regen_goldens.py`` and compares them against the frozen
``tests/golden/*.npz`` vectors.  A mismatch means the encoding changed
— that silently breaks every artifact already on disk, so the change
must be deliberate: bump ``CODR_FORMAT_VERSION`` and regenerate via
``tools/regen_goldens.py``.
"""
import importlib.util
import os

import numpy as np
import pytest

_TOOLS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tools", "regen_goldens.py")
_spec = importlib.util.spec_from_file_location("regen_goldens", _TOOLS)
regen = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(regen)

REGEN_MSG = ("format changed — bump CODR_FORMAT_VERSION and regenerate "
             "via `tools/regen_goldens.py`")


def _assert_matches_golden(name: str) -> None:
    path = os.path.join(regen.GOLDEN_DIR, f"{name}.npz")
    assert os.path.exists(path), (
        f"missing golden {path} — generate it via tools/regen_goldens.py")
    golden = np.load(path)
    current = regen.BUILDERS[name]()
    assert sorted(golden.files) == sorted(current.keys()), (
        f"{name}: golden keys {sorted(golden.files)} != current "
        f"{sorted(current.keys())} — {REGEN_MSG}")
    for k in golden.files:
        g, c = golden[k], np.asarray(current[k])
        assert g.dtype == c.dtype and g.shape == c.shape, (
            f"{name}/{k}: dtype/shape drift ({g.dtype}{g.shape} vs "
            f"{c.dtype}{c.shape}) — {REGEN_MSG}")
        assert g.tobytes() == c.tobytes(), (
            f"{name}/{k}: bytes differ from the frozen golden — "
            f"{REGEN_MSG}")


@pytest.mark.parametrize("name", sorted(regen.BUILDERS))
def test_format_bytes_frozen(name):
    _assert_matches_golden(name)


def test_checkpoint_manifest_carries_format_version():
    import json

    from repro.checkpoint.packed import CODR_FORMAT_VERSION
    blob = bytes(np.load(os.path.join(
        regen.GOLDEN_DIR, "packed_checkpoint.npz"))["manifest"])
    manifest = json.loads(blob.decode())
    assert manifest["magic"] == "codr-packed"
    # the frozen golden pins the CURRENT version: bumping the version
    # without regenerating the goldens fails here by design
    assert manifest["format_version"] == CODR_FORMAT_VERSION, REGEN_MSG


def test_goldens_decode_not_just_match(rng):
    # the frozen RLE bytes must still DECODE to the original vector —
    # byte equality alone would also pass for two matching bugs
    from repro.core import rle
    g = np.load(os.path.join(regen.GOLDEN_DIR, "rle_stream.npz"))

    def stream(name, mode_abs=False):
        nbits, param, count, mode_bits = (int(v) for v in g[f"{name}_meta"])
        return rle.Stream(packed=g[f"{name}_packed"], nbits=nbits,
                          param=param, count=count, mode_bits=mode_bits)

    deltas = rle.decode_escape_stream(stream("deltas"))
    uniq = np.cumsum(np.concatenate(
        [[rle.delta_untransform_first(int(deltas[0]))], deltas[1:]]))
    np.testing.assert_array_equal(
        uniq, np.array([-90, -17, -5, 3, 12, 101]))
    reps = rle.decode_rep_stream(stream("reps"))
    np.testing.assert_array_equal(reps, np.array([2, 1, 4, 3, 2, 1]))
