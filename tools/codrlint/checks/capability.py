"""capability-consistency: Backend classes implement what their caps claim.

The registry contract (``repro.core.backends``, docs/DESIGN.md §3.1)
couples three things that only agree by convention: a backend's
``BackendCaps`` flags, the methods it actually overrides, and the
``KERNEL_CAPS`` dicts the kernel packages publish.  This checker pins
the statically-checkable part of that contract:

* **name** — every concrete ``Backend`` subclass must bind a non-empty
  ``name`` (class literal or ``self.name = ...`` in ``__init__``); two
  classes must not claim the same literal name (the registry would need
  ``overwrite=True``, which is reserved for the elastic re-mesh rungs).
* **matmul ⇒ packed_matmul** — a class that overrides ``matmul`` (the
  packed-projection entry point) must declare ``packed_matmul=True`` in
  its literal ``caps``; overriding the packed path while advertising
  ``packed_matmul=False`` means ``compile_params`` will refuse a
  backend that actually works (or worse, the flag lies the other way
  after a refactor).
* **dead native kind** — literal ``caps`` whose ``native_kinds``
  include a kind whose method body is just ``raise NotImplementedError``
  (claiming a path that cannot execute).
* **KERNEL_CAPS shape** — every ``KERNEL_CAPS`` dict literal must carry
  the keys the lazy caps properties consume (``kinds``,
  ``integer_activations``, ``description``).

Classes whose ``caps`` is computed (a property resolving KERNEL_CAPS
lazily) are skipped by the flag checks — the KERNEL_CAPS shape check
covers their source of truth instead.
"""
from __future__ import annotations

import ast

from tools.codrlint.core import (Checker, Finding, ModuleInfo, Project,
                                 dotted_name, literal_or_none,
                                 register_checker)

BACKEND_ROOT = "Backend"
KERNEL_CAPS_KEYS = {"kinds", "integer_activations", "description"}


def _base_names(cls: ast.ClassDef) -> list[str]:
    out = []
    for b in cls.bases:
        if isinstance(b, ast.Name):
            out.append(b.id)
        elif isinstance(b, ast.Attribute):
            out.append(b.attr)
    return out


def _is_backend_subclass(cls_name: str, project: Project,
                         seen=None) -> bool:
    seen = seen or set()
    if cls_name in seen:
        return False
    seen.add(cls_name)
    for _, cls in project.class_index.get(cls_name, ()):
        for b in _base_names(cls):
            if b == BACKEND_ROOT or _is_backend_subclass(b, project, seen):
                return True
    return False


def _class_literal(cls: ast.ClassDef, name: str) -> ast.AST | None:
    for item in cls.body:
        if isinstance(item, ast.Assign):
            for t in item.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    return item.value
        elif (isinstance(item, ast.AnnAssign) and item.value is not None
              and isinstance(item.target, ast.Name)
              and item.target.id == name):
            return item.value
    return None


def _caps_kwargs(value: ast.AST) -> dict | None:
    """``BackendCaps(...)`` call → literal kwargs (non-literal values
    dropped); None when caps is not a literal BackendCaps call."""
    if not (isinstance(value, ast.Call)
            and dotted_name(value.func).split(".")[-1] == "BackendCaps"):
        return None
    out = {}
    for kw in value.keywords:
        if kw.arg is None:
            continue
        lit = literal_or_none(kw.value)
        if lit is None and isinstance(kw.value, ast.Call):
            # frozenset({...}) — unwrap the one-arg literal
            if dotted_name(kw.value.func) == "frozenset" and kw.value.args:
                lit = literal_or_none(kw.value.args[0])
        if lit is not None:
            out[kw.arg] = lit
    return out


def _methods(cls: ast.ClassDef) -> dict[str, ast.FunctionDef]:
    return {i.name: i for i in cls.body
            if isinstance(i, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _only_raises_not_implemented(fn: ast.FunctionDef) -> bool:
    body = [s for s in fn.body
            if not (isinstance(s, ast.Expr)
                    and isinstance(s.value, ast.Constant))]  # drop docstring
    if len(body) != 1 or not isinstance(body[0], ast.Raise):
        return False
    exc = body[0].exc
    callee = exc.func if isinstance(exc, ast.Call) else exc
    return dotted_name(callee).endswith("NotImplementedError")


def _sets_name_in_init(cls: ast.ClassDef) -> bool:
    init = _methods(cls).get("__init__")
    if init is None:
        return False
    for node in ast.walk(init):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if (isinstance(t, ast.Attribute) and t.attr == "name"
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    return True
    return False


class CapabilityChecker(Checker):
    name = "capability-consistency"
    description = ("Backend subclasses: name set, matmul override ⇔ "
                   "packed_matmul flag, no dead native kinds, KERNEL_CAPS "
                   "dicts well-formed")

    def finalize(self, project: Project):
        findings: list[Finding] = []
        names_seen: dict[str, tuple[str, int]] = {}
        for cls_name, defs in sorted(project.class_index.items()):
            if not _is_backend_subclass(cls_name, project):
                continue
            for mod, cls in defs:
                findings.extend(self._check_backend(mod, cls, names_seen))
        for mod in project.modules:
            if mod.tree is None:
                continue
            findings.extend(self._check_kernel_caps(mod))
        return findings

    def _check_backend(self, mod: ModuleInfo, cls: ast.ClassDef,
                       names_seen: dict) -> list[Finding]:
        findings: list[Finding] = []
        methods = _methods(cls)
        # abstract intermediaries (no name, no caps, no registration)
        # are tolerated only if they define no execution methods — the
        # built-ins all bind a literal name
        name_lit = literal_or_none(_class_literal(cls, "name") or
                                   ast.Constant(value=None))
        if not name_lit and not _sets_name_in_init(cls):
            findings.append(Finding(
                "capability-consistency", mod.rel, cls.lineno,
                f"{cls.name}:name",
                f"Backend subclass {cls.name} binds no non-empty 'name' "
                f"(class literal or self.name in __init__) — it cannot "
                f"be registered"))
        elif isinstance(name_lit, str) and name_lit:
            prev = names_seen.get(name_lit)
            if prev is not None:
                findings.append(Finding(
                    "capability-consistency", mod.rel, cls.lineno,
                    f"{cls.name}:dup-name",
                    f"backend name {name_lit!r} claimed by both "
                    f"{prev[0]} and {cls.name} — registry collision"))
            else:
                names_seen[name_lit] = (cls.name, cls.lineno)

        caps = _caps_kwargs(_class_literal(cls, "caps") or
                            ast.Constant(value=None))
        if caps is None:
            return findings                # dynamic caps → KERNEL_CAPS rule
        if "matmul" in methods and not caps.get("packed_matmul", False):
            findings.append(Finding(
                "capability-consistency", mod.rel,
                methods["matmul"].lineno, f"{cls.name}:matmul",
                f"{cls.name} overrides matmul (the packed-projection "
                f"entry point) but its BackendCaps does not declare "
                f"packed_matmul=True — compile_params would reject it"))
        native = caps.get("native_kinds")
        if native:
            for kind in sorted(native):
                fn = methods.get(kind)
                if fn is not None and _only_raises_not_implemented(fn):
                    findings.append(Finding(
                        "capability-consistency", mod.rel, fn.lineno,
                        f"{cls.name}:dead-{kind}",
                        f"{cls.name}.caps claims native kind {kind!r} "
                        f"but .{kind}() only raises NotImplementedError"))
        return findings

    def _check_kernel_caps(self, mod: ModuleInfo) -> list[Finding]:
        findings: list[Finding] = []
        for node in mod.tree.body:
            if not isinstance(node, ast.Assign):
                continue
            if not any(isinstance(t, ast.Name) and t.id == "KERNEL_CAPS"
                       for t in node.targets):
                continue
            lit = literal_or_none(node.value)
            if not isinstance(lit, dict):
                findings.append(Finding(
                    "capability-consistency", mod.rel, node.lineno,
                    "KERNEL_CAPS:literal",
                    "KERNEL_CAPS must be a literal dict (the lazy caps "
                    "properties and this checker both read it statically)"))
                continue
            missing = KERNEL_CAPS_KEYS - set(lit)
            if missing:
                findings.append(Finding(
                    "capability-consistency", mod.rel, node.lineno,
                    "KERNEL_CAPS:keys",
                    f"KERNEL_CAPS is missing required key(s) "
                    f"{sorted(missing)} (consumed by the backend caps "
                    f"properties)"))
        return findings


register_checker(CapabilityChecker())
