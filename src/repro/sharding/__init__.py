from repro.sharding.rules import (ShardCtx, current_ctx, maybe_constrain,
                                  param_spec, set_ctx, use_ctx)

__all__ = ["ShardCtx", "current_ctx", "maybe_constrain", "param_spec",
           "set_ctx", "use_ctx"]
