"""Step functions + abstract state + shardings for every (arch × shape ×
mesh) cell — shared by the dry-run, the trainer, and the server."""
from __future__ import annotations

import dataclasses
import functools
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import get_model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.sharding import ShardCtx, use_ctx
from repro.sharding.rules import named_sharding_tree

SERVE_DTYPE = jnp.bfloat16
TRAIN_PARAM_DTYPE = jnp.bfloat16      # bf16 params + fp32 master in opt

_WEIGHT_DTYPES = {"bf16": jnp.bfloat16, "int8": jnp.int8, "int4": jnp.int4}
_CACHE_DTYPES = {"bf16": jnp.bfloat16, "int8": jnp.int8}


@dataclasses.dataclass(frozen=True)
class CellOptions:
    """§Perf levers applied at the lowering boundary (model-level levers
    — decode_attn / moe_decode_2d / block_causal — live on ModelConfig).

    ``serve_weight_dtype`` — storage dtype of ≥2-D serving weights
    (int8 = weight-only quantization; int4 ≈ the CoDR U16 unique-index
    pack: 4 bits/weight HBM traffic).  Scales are folded per-tensor and
    are O(d_out) — negligible in the roofline; numerical fidelity of the
    quantized path is validated by the codr_matmul kernel tests.
    ``cache_dtype`` — KV-cache storage dtype.
    """

    serve_weight_dtype: str = "bf16"
    cache_dtype: str = "bf16"

    def tag(self) -> str:
        parts = []
        if self.serve_weight_dtype != "bf16":
            parts.append(f"w{self.serve_weight_dtype}")
        if self.cache_dtype != "bf16":
            parts.append(f"c{self.cache_dtype}")
        return "-".join(parts)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh: Mesh, batch_size: int) -> P:
    axes = batch_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch_size % total == 0:
        return P(axes)
    # fall back to the largest prefix of the axes that divides
    for cut in range(len(axes) - 1, 0, -1):
        total = int(np.prod([mesh.shape[a] for a in axes[:cut]]))
        if batch_size % total == 0:
            return P(axes[:cut])
    return P()


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — never allocate)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train" or shape.kind == "prefill":
        specs = {}
        if cfg.family == "encdec":
            # encoder consumes S frames; decoder gets a short target prefix
            specs["prefix"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.bfloat16)
            specs["tokens"] = jax.ShapeDtypeStruct((b, min(s, 1024)),
                                                   jnp.int32)
        elif cfg.frontend:
            fs = min(cfg.frontend_seq, s // 2)
            specs["prefix"] = jax.ShapeDtypeStruct((b, fs, cfg.d_model),
                                                   jnp.bfloat16)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s - fs), jnp.int32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return specs
    # decode: one new token against a seq_len cache
    return {"token": jax.ShapeDtypeStruct((b,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    specs = input_specs(cfg, shape)
    bspec = batch_spec(mesh, shape.global_batch)
    out = {}
    for k, v in specs.items():
        if k == "pos":
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = NamedSharding(mesh, P(*(bspec + (None,) * (v.ndim - 1))))
    return out


# ---------------------------------------------------------------------------
# cache shardings
# ---------------------------------------------------------------------------

def _cache_leaf_spec(shape: tuple[int, ...], mesh: Mesh, batch: int,
                     stacked: bool) -> P:
    """KV caches (B,S,H,D) / (B,S,C); recurrent states (B,...).
    ``stacked`` leaves carry a leading (n_periods,) scan axis that must
    stay unsharded (scan slices it per iteration)."""
    bspec = batch_spec(mesh, batch)
    baxes = bspec[0] if bspec else None
    msize = mesh.shape.get("model", 1)
    ndim = len(shape)
    spec: list = [None] * ndim
    base = 1 if stacked else 0
    dims = shape[base:]
    if baxes is not None:
        covered = int(np.prod([mesh.shape[a] for a in
                               (baxes if isinstance(baxes, tuple)
                                else (baxes,))]))
        if dims and dims[0] % covered == 0 and covered > 1:
            spec[base] = baxes
    if len(dims) >= 3 and dims[1] > 1024:
        # (B, S, ...) long-sequence cache: heads over model if they fit,
        # else sequence over model
        if len(dims) == 4 and dims[2] % msize == 0 and msize > 1:
            spec[base + 2] = "model"
        elif dims[1] % msize == 0 and msize > 1:
            spec[base + 1] = "model"
    elif len(dims) >= 2 and msize > 1:
        # recurrent state: model on the widest trailing dim that divides
        widest = int(np.argmax(dims[1:])) + 1
        if dims[widest] % msize == 0:
            spec[base + widest] = "model"
    return P(*spec)


def cache_shardings(cache_shapes, mesh: Mesh, batch: int):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        stacked = pstr.startswith("stack")
        out.append(NamedSharding(
            mesh, _cache_leaf_spec(leaf.shape, mesh, batch, stacked)))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# abstract params / optimizer state
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig, dtype=TRAIN_PARAM_DTYPE):
    """Abstract param tree.  Sub-byte / int dtypes apply only to ≥2-D
    projection weights; norms/biases stay bf16."""
    api = get_model(cfg)
    shapes = jax.eval_shape(partial(api.init_params, cfg=cfg),
                            jax.random.PRNGKey(0))

    def leaf(s):
        if jnp.issubdtype(dtype, jnp.integer) and s.ndim < 2:
            return jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        return jax.ShapeDtypeStruct(s.shape, dtype)

    return jax.tree.map(leaf, shapes)


def abstract_opt_state(params, opt_cfg: AdamWConfig):
    return jax.eval_shape(partial(adamw_init, cfg=opt_cfg), params)


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig,
                   dtype=SERVE_DTYPE):
    api = get_model(cfg)
    return jax.eval_shape(
        partial(api.init_cache, cfg, shape.global_batch, shape.seq_len,
                dtype=dtype))


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh: Mesh,
                    opt_cfg: AdamWConfig | None = None):
    opt_cfg = opt_cfg or AdamWConfig()
    api = get_model(cfg)
    ctx = ShardCtx(mesh)

    def train_step(params, opt_state, batch):
        with use_ctx(ctx):
            loss, grads = jax.value_and_grad(
                lambda p: api.train_loss(p, batch, cfg))(params)
            params, opt_state, metrics = adamw_update(
                params, grads, opt_state, opt_cfg)
            metrics["loss"] = loss
            return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh: Mesh):
    api = get_model(cfg)
    ctx = ShardCtx(mesh)

    def prefill_step(params, batch):
        with use_ctx(ctx):
            logits, cache = api.prefill(params, batch, cfg)
            return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Mesh):
    api = get_model(cfg)
    ctx = ShardCtx(mesh)

    def serve_step(params, cache, token, pos):
        with use_ctx(ctx):
            return api.decode_step(params, cache, token, pos, cfg)

    return serve_step


# ---------------------------------------------------------------------------
# full cell assembly (used by dryrun / benchmarks)
# ---------------------------------------------------------------------------

def serve_param_fsdp(cfg: ModelConfig, mesh: Mesh,
                     bytes_per_param: float = 2.0) -> bool:
    """2-D-shard serving weights when a model-axis-only shard would not
    fit HBM comfortably (see docs/DESIGN.md §5).  Replicating over ``data``
    (when it fits) removes the per-decode-step weight all-gathers —
    weight compression (int8/int4 = the CoDR serving formats) widens the
    set of models that qualify: the paper's trade, at cluster scale."""
    msize = mesh.shape.get("model", 1)
    bytes_per_chip = cfg.param_count() * bytes_per_param / max(msize, 1)
    return bytes_per_chip > 8e9


def build_cell(arch: str, shape: ShapeConfig, mesh: Mesh,
               opt_cfg: AdamWConfig | None = None,
               options: CellOptions | None = None):
    """Returns (step_fn, arg_shapes, in_shardings, out_shardings_hint)."""
    cfg = get_config(arch) if isinstance(arch, str) else arch
    options = options or CellOptions()
    serve_dtype = _WEIGHT_DTYPES[options.serve_weight_dtype]
    cache_dtype = _CACHE_DTYPES[options.cache_dtype]
    if shape.kind == "train":
        params = abstract_params(cfg, TRAIN_PARAM_DTYPE)
        opt_cfg = opt_cfg or AdamWConfig()
        opt = abstract_opt_state(params, opt_cfg)
        batch = input_specs(cfg, shape)
        p_sh = named_sharding_tree(params, mesh, fsdp=True)
        # moments/master shard like params
        o_sh = {
            "m": named_sharding_tree(opt["m"], mesh, fsdp=True),
            "v": named_sharding_tree(opt["v"], mesh, fsdp=True),
            "step": NamedSharding(mesh, P()),
        }
        if "master" in opt:
            o_sh["master"] = named_sharding_tree(opt["master"], mesh,
                                                 fsdp=True)
        b_sh = batch_shardings(cfg, shape, mesh)
        fn = make_train_step(cfg, mesh, opt_cfg)
        return fn, (params, opt, batch), (p_sh, o_sh, b_sh), None

    bpp = {"bf16": 2.0, "int8": 1.0, "int4": 0.5}[options.serve_weight_dtype]
    fsdp = serve_param_fsdp(cfg, mesh, bpp)
    params = abstract_params(cfg, serve_dtype)
    moe2d = bool(cfg.moe_decode_2d and shape.kind == "decode")
    p_sh = named_sharding_tree(params, mesh, fsdp=fsdp, moe2d=moe2d)
    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        b_sh = batch_shardings(cfg, shape, mesh)
        fn = make_prefill_step(cfg, mesh)
        return fn, (params, batch), (p_sh, b_sh), None

    # decode
    cache = abstract_cache(cfg, shape, dtype=cache_dtype)
    c_sh = cache_shardings(cache, mesh, shape.global_batch)
    specs = input_specs(cfg, shape)
    tok_sh = NamedSharding(mesh, batch_spec(mesh, shape.global_batch))
    pos_sh = NamedSharding(mesh, P())
    fn = make_decode_step(cfg, mesh)
    return (fn, (params, cache, specs["token"], specs["pos"]),
            (p_sh, c_sh, tok_sh, pos_sh), None)
