"""Deterministic sharded data pipeline.

Synthetic-but-structured token streams (a mixture of Zipfian unigram
draws and repeated n-gram motifs so the LM loss actually decreases),
generated *per host shard* from a (seed, epoch, step, shard) counter —
no cross-host coordination needed and any step is reproducible after an
elastic restart (the cursor is part of the checkpoint).

The same module provides the modality-frontend stubs: precomputed
frame/patch embeddings per the assignment spec.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1              # host data shards
    shard_id: int = 0
    zipf_a: float = 1.2
    motif_len: int = 16
    motif_prob: float = 0.5
    frontend: str | None = None
    frontend_seq: int = 0
    d_model: int = 0


class SyntheticTokenDataset:
    """Stateless step-indexed batch generator (host-side numpy)."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_shards == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_shards
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # fixed motif bank shared by all shards (function of seed only)
        self.motifs = base.integers(0, v, size=(64, cfg.motif_len))
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks ** cfg.zipf_a
        self.unigram = p / p.sum()

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, 7919 * step + cfg.shard_id))
        b, s, v = self.local_batch, cfg.seq_len, cfg.vocab_size
        toks = rng.choice(v, size=(b, s), p=self.unigram).astype(np.int32)
        # splice in repeated motifs → learnable structure
        n_splice = int(s * cfg.motif_prob / cfg.motif_len)
        for i in range(b):
            for _ in range(max(n_splice, 1)):
                m = self.motifs[rng.integers(0, len(self.motifs))]
                at = rng.integers(0, max(s - cfg.motif_len, 1))
                toks[i, at : at + cfg.motif_len] = m[: max(s - at, 0)][:cfg.motif_len][: s - at]
        out = {"tokens": toks}
        if cfg.frontend:
            out["prefix"] = rng.standard_normal(
                (b, cfg.frontend_seq, cfg.d_model)).astype(np.float32)
        return out


def host_batch_iterator(cfg: DataConfig, start_step: int = 0):
    ds = SyntheticTokenDataset(cfg)
    step = start_step
    while True:
        yield step, ds.batch(step)
        step += 1


def make_batch_specs(model_cfg, shape_cfg, *, dtype="int32"):
    """ShapeDtypeStructs for a global batch (used by input_specs())."""
    import jax
    import jax.numpy as jnp
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    specs = {}
    if model_cfg.frontend or model_cfg.family == "encdec":
        fs = model_cfg.frontend_seq or s
        specs["prefix"] = jax.ShapeDtypeStruct((b, fs, model_cfg.d_model),
                                               jnp.bfloat16)
        tok_len = s if model_cfg.family == "encdec" else max(s - fs, 1)
        specs["tokens"] = jax.ShapeDtypeStruct((b, tok_len), jnp.int32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return specs
