"""Autotune driver: per-layer encoding search vs the best global config.

  PYTHONPATH=src python -m repro.launch.tune [--small] [--check]
      [--model vgg16] [--max-rel-err 0.03] [--objective sram]
      [--out plan.json]

Runs the §III-C-style per-layer search (:func:`repro.tune.tune_spec`)
on paper-CNN geometry, scores the best *single* global
``EncodeConfig`` over the same candidate table as the baseline, compiles
both, and reports predicted-vs-measured bits/weight, SRAM accesses, and
dense-oracle logit agreement side by side.  ``--check`` asserts the
tuned plan's measured bits/weight and predicted SRAM are no worse than
the global baseline's at equal-or-better top-1 logit agreement — the CI
smoke gate (``--small --check``).  ``--out`` writes the plan JSON so a
later ``codr.compile(spec, plan=TunePlan.load(...))`` skips the search.
"""
from __future__ import annotations

import argparse

import numpy as np

import repro.api as codr
from repro import tune


def run_tune(*, model: str = "vgg16", n_conv: int = 2, n_out: int = 10,
             input_hw: tuple[int, int] = (20, 20), density: float = 0.4,
             max_rel_err: float | None = 0.03, objective: str = "sram",
             target_bits_per_weight: float | None = None,
             max_sram_accesses: float | None = None,
             exact: bool = True, batch: int = 32, seed: int = 0,
             out: str | None = None, verbose: bool = True) -> dict:
    """One tuning run: search → plan → compile → measure, against the
    best-global-config baseline.  Importable so tests, benchmarks, and
    CI drive the same path as the CLI.  ``exact=True`` scores every UCR
    vector (predicted bits/SRAM equal measured); set ``False`` to sample
    on large layers."""
    spec = codr.ModelSpec.from_paper_cnn(
        model, n_conv=n_conv, n_out=n_out, ri=input_hw[0], ci=input_hw[1],
        density=density, rng=np.random.default_rng(seed))
    budget = tune.TuneBudget(
        max_rel_err=max_rel_err, objective=objective,
        target_bits_per_weight=target_bits_per_weight,
        max_sram_accesses=max_sram_accesses)
    grid = tune.TuneGrid(max_vectors=None if exact else 2000)

    plan = tune.tune_spec(spec, input_hw, budget=budget, grid=grid)
    table = tune.layer_candidate_table(spec, input_hw, grid=grid)
    global_cfg, global_pred = tune.best_global_config(
        table, budget=budget, grid=grid)

    tuned = codr.compile(spec, plan=plan)
    baseline = codr.compile(spec, global_cfg)
    x = tune.eval_batch(spec, input_hw, batch=batch, seed=seed)
    q_tuned = tune.cnn_quality(tuned, x)
    q_global = tune.cnn_quality(baseline, x)
    sram_tuned = sum(a.total_sram for _, a in
                     tuned.sram_report(input_hw, per_layer_tiling=True))
    sram_global = sum(a.total_sram for _, a in
                      baseline.sram_report(input_hw, per_layer_tiling=True))

    if verbose:
        print(plan.table())
        print()
        print(tuned.layer_table(input_hw))
        print()
        print(f"global baseline: {global_cfg.metadata()}")
        hdr = (f"{'':<8} {'bits/w':>8} {'pred b/w':>9} {'sram':>12} "
               f"{'pred sram':>12} {'top1':>6} {'rel err':>8}")
        print(hdr)
        print(f"{'tuned':<8} {tuned.bits_per_weight():8.3f} "
              f"{plan.predicted_bits_per_weight():9.3f} "
              f"{sram_tuned:12.3e} {plan.predicted_total_sram():12.3e} "
              f"{q_tuned['top1_match']:6.3f} "
              f"{q_tuned['rel_logit_err']:8.4f}")
        print(f"{'global':<8} {baseline.bits_per_weight():8.3f} "
              f"{global_pred['bits_per_weight']:9.3f} "
              f"{sram_global:12.3e} {global_pred['sram']:12.3e} "
              f"{q_global['top1_match']:6.3f} "
              f"{q_global['rel_logit_err']:8.4f}")
    if out is not None:
        plan.save(out)
        if verbose:
            print(f"plan written to {out}")

    return {
        "plan": plan,
        "global_config": global_cfg,
        "tuned": {"bits_per_weight": tuned.bits_per_weight(),
                  "predicted_bits_per_weight":
                      plan.predicted_bits_per_weight(),
                  "sram_accesses": float(sram_tuned),
                  "predicted_sram": plan.predicted_total_sram(),
                  **q_tuned},
        "global": {"bits_per_weight": baseline.bits_per_weight(),
                   "predicted_bits_per_weight":
                       global_pred["bits_per_weight"],
                   "sram_accesses": float(sram_global),
                   "predicted_sram": global_pred["sram"],
                   **q_global},
    }


def check_result(result: dict) -> None:
    """The CI gate: the tuned plan must be no worse than the best global
    config on measured bits/weight AND predicted SRAM, at
    equal-or-better top-1 logit agreement."""
    t, g = result["tuned"], result["global"]
    if t["bits_per_weight"] > g["bits_per_weight"]:
        raise AssertionError(
            f"tuned bits/weight {t['bits_per_weight']:.4f} worse than "
            f"global {g['bits_per_weight']:.4f}")
    if t["predicted_sram"] > g["predicted_sram"]:
        raise AssertionError(
            f"tuned predicted SRAM {t['predicted_sram']:.0f} worse than "
            f"global {g['predicted_sram']:.0f}")
    if t["top1_match"] < g["top1_match"]:
        raise AssertionError(
            f"tuned top-1 agreement {t['top1_match']:.3f} below global "
            f"{g['top1_match']:.3f}")
    print("CHECK OK: tuned <= global on bits/weight and predicted SRAM "
          f"at equal-or-better agreement "
          f"({t['top1_match']:.3f} vs {g['top1_match']:.3f})")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="vgg16",
                    choices=["alexnet", "vgg16", "googlenet"])
    ap.add_argument("--n-conv", type=int, default=3)
    ap.add_argument("--n-out", type=int, default=10)
    ap.add_argument("--hw", type=int, default=28,
                    help="square input feature-map size")
    ap.add_argument("--density", type=float, default=0.4)
    ap.add_argument("--max-rel-err", type=float, default=0.03)
    ap.add_argument("--objective", default="sram",
                    choices=["sram", "bits", "energy"])
    ap.add_argument("--target-bpw", type=float, default=None,
                    help="model-wide bits/weight target (greedy walk)")
    ap.add_argument("--max-sram", type=float, default=None,
                    help="model-wide predicted-SRAM ceiling")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write plan JSON here")
    ap.add_argument("--small", action="store_true",
                    help="CI smoke geometry (2 conv layers, 20x20 input)")
    ap.add_argument("--check", action="store_true",
                    help="assert tuned <= global at equal-or-better "
                         "agreement (exit 1 otherwise)")
    args = ap.parse_args(argv)
    if args.small:
        args.n_conv, args.hw = 2, 20
    result = run_tune(
        model=args.model, n_conv=args.n_conv, n_out=args.n_out,
        input_hw=(args.hw, args.hw), density=args.density,
        max_rel_err=args.max_rel_err, objective=args.objective,
        target_bits_per_weight=args.target_bpw,
        max_sram_accesses=args.max_sram,
        batch=args.batch, seed=args.seed, out=args.out)
    if args.check:
        check_result(result)


if __name__ == "__main__":
    main()
