"""KV-cache slot pools for continuous batching.

A *pool* is just the pytree returned by a model's ``init_cache(cfg,
n_slots, max_len)`` — the batch axis doubles as the slot axis, so one
pooled ``decode_step`` call advances every active request at once (with
per-row positions, see ``attention.decode_positions``).  The helpers
here move single-request caches in and out of that pool:

* ``diff_axes`` discovers, per leaf, which axis is the batch axis —
  structurally, by comparing the shapes of a batch-1 and a batch-2
  cache from ``jax.eval_shape`` (stacked scan-carry leaves put
  ``n_periods`` first; prologue leaves lead with batch).
* ``write_slot`` block-writes a batch-1 cache (e.g. a prefill result at
  seq length P) into slot ``i`` of the pool.  Shorter-than-pool seq
  axes are written as-is at offset 0: decode attention masks positions
  beyond the slot's own ``pos``, so the stale tail is inert and results
  stay bit-identical to a solo decode.
* ``read_slot`` extracts slot ``i`` back out as a batch-1 cache.

Paged mode (docs/DESIGN.md §2.2) replaces the contiguous per-slot
sequence buffers with :class:`PagedKV` leaves: a shared pool of
fixed-size pages plus a per-slot page table.  Storage is int8 with one
scale per page (requantized in place whenever a new row grows the page
maximum) or bf16 (``kv_dtype="bf16"``), in which case the gathered
cache is bit-identical to the contiguous one.  Physical page 0 is a
reserved *scratch* page: retired and never-admitted slots point every
table entry at it, so the pooled decode step — which advances all
slots, active or not — lands its dead writes somewhere harmless
instead of in a page that may already belong to a new request.

No imports from ``repro.core`` — this is a models-layer utility.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def diff_axes(tree_a, tree_b):
    """Per-leaf axis where ``tree_a`` and ``tree_b`` shapes differ.

    Both trees must share their structure; each leaf pair must differ in
    rank-preserving fashion along exactly one axis (leaves with
    identical shapes are rejected — the batch axis must be
    discoverable).  Returns a pytree of ints with the same structure.
    Feed it ``jax.eval_shape`` results so no arrays are materialized::

        ax = diff_axes(jax.eval_shape(init, 1), jax.eval_shape(init, 2))
    """
    def one(la, lb):
        if la.ndim != lb.ndim:
            raise ValueError(f"rank mismatch {la.shape} vs {lb.shape}")
        diffs = [i for i, (a, b) in enumerate(zip(la.shape, lb.shape))
                 if a != b]
        if len(diffs) != 1:
            raise ValueError(
                f"need exactly one differing axis, got {la.shape} vs "
                f"{lb.shape}")
        return diffs[0]
    return jax.tree.map(one, tree_a, tree_b)


def write_slot(pool, cache, slot, axes):
    """Write batch-1 ``cache`` into ``pool`` at slot index ``slot``.

    ``axes`` is the ``diff_axes`` pytree locating each leaf's slot
    axis.  Leaves whose non-slot dims are shorter than the pool's (a
    seq-P prefill cache into a seq-max pool) land at offset 0, leaving
    the pool's tail untouched — masked out by decode attention."""
    slot = jnp.asarray(slot, jnp.int32)

    def one(pl, cl, ax):
        start = [jnp.int32(0)] * pl.ndim
        start[ax] = slot
        return jax.lax.dynamic_update_slice(
            pl, cl.astype(pl.dtype), tuple(start))
    return jax.tree.map(one, pool, cache, axes)


def read_slot(pool, slot, axes):
    """Extract slot ``slot`` of ``pool`` as a batch-1 cache (full pool
    sequence length — callers mask by position, they don't trim)."""
    slot = jnp.asarray(slot, jnp.int32)

    def one(pl, ax):
        return jax.lax.dynamic_slice_in_dim(pl, slot, 1, axis=ax)
    return jax.tree.map(one, pool, axes)


# ---------------------------------------------------------------------------
# paged KV cache
# ---------------------------------------------------------------------------

SCRATCH_PAGE = 0


@dataclasses.dataclass(frozen=True)
class PagedSpec:
    """Geometry of a paged KV pool (host-side, static).

    ``n_pages`` counts *physical* pages including the reserved scratch
    page 0; the default provisions every slot's worst case so admission
    can never fail on pages alone.
    """

    page_size: int
    max_len: int
    n_slots: int
    kv_dtype: str = "int8"          # "int8" | "bf16"
    n_pages: int | None = None

    def __post_init__(self):
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.kv_dtype not in ("int8", "bf16"):
            raise ValueError(f"kv_dtype must be 'int8' or 'bf16', "
                             f"got {self.kv_dtype!r}")

    @property
    def max_pages(self) -> int:
        """Logical pages per slot (the page-table row length)."""
        return -(-self.max_len // self.page_size)

    @property
    def total_pages(self) -> int:
        n = self.n_pages if self.n_pages is not None \
            else 1 + self.n_slots * self.max_pages
        if n < 1 + self.max_pages:
            raise ValueError(
                f"n_pages={n} cannot hold even one request "
                f"({self.max_pages} pages + scratch)")
        return n

    def pages_for(self, total_len: int) -> int:
        """Pages a request of ``total_len`` tokens must reserve."""
        return min(self.max_pages, -(-total_len // self.page_size))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKV:
    """One paged KV buffer: page data + per-page scales + page tables.

    ``data``  ``(n_pages, page_size, *feat)`` int8 (quantized) or bf16.
    ``scale`` ``(n_pages,)`` f32 — per-page dequant scale (int8 mode).
    ``table`` ``(n_slots, max_pages)`` int32 physical-page ids.

    The three arrays are pytree children, so the standard scan-carry
    stacking (``broadcast_to`` over ``n_periods``) and per-period
    ``dynamic_index_in_dim`` slicing in ``models/lm.py`` apply
    unchanged; ``page_size``/``seq_len``/``quantized`` ride in the
    static aux.
    """

    data: jax.Array
    scale: jax.Array
    table: jax.Array
    page_size: int
    seq_len: int                     # logical max_len — gather crops to it
    quantized: bool

    def tree_flatten(self):
        return ((self.data, self.scale, self.table),
                (self.page_size, self.seq_len, self.quantized))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # -- decode-step write ---------------------------------------------------
    def update(self, new, pos):
        """Write one new token row per slot at position ``pos``.

        ``new`` is ``(B, 1, *feat)`` (``cache_update`` semantics),
        ``pos`` scalar or ``(B,)``; ``B`` must equal the table's slot
        count.  int8 pages requantize in place under a grow-only scale:
        ``new_scale = max(old_scale, amax(row)/127)``, so earlier rows
        of the page are re-rounded only when the running maximum grows.
        """
        b = new.shape[0]
        pos = jnp.asarray(pos, jnp.int32)
        if pos.ndim == 0:
            pos = jnp.full((b,), pos, jnp.int32)
        logical = pos // self.page_size
        off = pos % self.page_size
        phys = self.table[jnp.arange(b), logical]            # (B,)
        row = new[:, 0]                                      # (B, *feat)
        if not self.quantized:
            data = self.data.at[phys, off].set(row.astype(self.data.dtype))
            return dataclasses.replace(self, data=data)
        feat_axes = tuple(range(1, row.ndim))
        bshape = (b,) + (1,) * len(feat_axes)
        rowf = row.astype(jnp.float32)
        amax = jnp.max(jnp.abs(rowf), axis=feat_axes)        # (B,)
        old_s = self.scale[phys]
        new_s = jnp.maximum(old_s, amax / 127.0)
        safe = jnp.where(new_s > 0, new_s, 1.0)
        page = self.data[phys].astype(jnp.float32) \
            * old_s.reshape(bshape)[:, None]                 # (B, ps, *feat)
        page = page.at[jnp.arange(b), off].set(rowf)
        q = jnp.clip(jnp.round(page / safe.reshape(bshape)[:, None]),
                     -127, 127).astype(jnp.int8)
        data = self.data.at[phys].set(q)
        scale = self.scale.at[phys].set(new_s)
        return dataclasses.replace(self, data=data, scale=scale)

    # -- dense view for attention --------------------------------------------
    def gather(self):
        """Dequantized contiguous ``(n_slots, seq_len, *feat)`` view.

        bf16 mode skips the scale multiply entirely — the result holds
        the exact bytes a contiguous bf16 cache would, which is what
        makes ``kv_dtype="bf16"`` paged bit-identical to unpaged."""
        d = self.data[self.table]                # (S, mp, ps, *feat)
        feat = d.shape[3:]
        if self.quantized:
            s = self.scale[self.table]           # (S, mp)
            s = s.reshape(s.shape + (1,) * (1 + len(feat)))
            d = (d.astype(jnp.float32) * s).astype(jnp.bfloat16)
        d = d.reshape(d.shape[0], -1, *feat)
        return d[:, :self.seq_len]

    @property
    def n_slots(self) -> int:
        return self.table.shape[-2]


def paged_kv_init(spec: PagedSpec, feat: tuple, dtype=jnp.bfloat16) -> PagedKV:
    """Fresh all-scratch paged buffer for one KV tensor of ``*feat``."""
    dt = jnp.int8 if spec.kv_dtype == "int8" else dtype
    return PagedKV(
        data=jnp.zeros((spec.total_pages, spec.page_size) + tuple(feat), dt),
        scale=jnp.zeros((spec.total_pages,), jnp.float32),
        table=jnp.zeros((spec.n_slots, spec.max_pages), jnp.int32),
        page_size=spec.page_size,
        seq_len=spec.max_len,
        quantized=spec.kv_dtype == "int8")


def _write_prefill_one(pkv: PagedKV, dense, slot, pages):
    """Write a batch-1 seq-P prefill leaf into ``pages`` of ``pkv``.

    ``pages`` is the slot's full ``(max_pages,)`` table row (tail
    entries scratch).  int8 pages get a fresh per-page scale; the
    scales of reserved-but-unwritten pages reset to 0 so the first
    decode write into them starts from a clean slate regardless of the
    previous tenant's bytes."""
    p_len = dense.shape[1]
    ps = pkv.page_size
    n_pg = -(-p_len // ps)
    feat = dense.shape[2:]
    rows = jnp.pad(dense[0], ((0, n_pg * ps - p_len),) + ((0, 0),) * len(feat))
    rows = rows.reshape(n_pg, ps, *feat)
    tgt = pages[:n_pg]
    if pkv.quantized:
        rf = rows.astype(jnp.float32)
        amax = jnp.max(jnp.abs(rf), axis=tuple(range(1, rf.ndim)))
        s = amax / 127.0
        safe = s.reshape((n_pg,) + (1,) * (1 + len(feat)))
        safe = jnp.where(safe > 0, safe, 1.0)
        q = jnp.clip(jnp.round(rf / safe), -127, 127).astype(jnp.int8)
        data = pkv.data.at[tgt].set(q)
        scale = pkv.scale.at[pages].set(0.0).at[tgt].set(s)
        scale = scale.at[SCRATCH_PAGE].set(0.0)
    else:
        data = pkv.data.at[tgt].set(rows.astype(pkv.data.dtype))
        scale = pkv.scale
    table = pkv.table.at[slot].set(pages)
    return dataclasses.replace(pkv, data=data, scale=scale, table=table)


def write_slot_paged(pool, cache, slot, pages):
    """Paged counterpart of :func:`write_slot`.

    ``pool`` holds :class:`PagedKV` leaves (possibly with an
    ``n_periods`` stacking axis on their children); ``cache`` is the
    matching batch-1 dense prefill cache; ``pages`` is the slot's
    ``(max_pages,)`` physical-page row."""
    slot = jnp.asarray(slot, jnp.int32)
    pages = jnp.asarray(pages, jnp.int32)

    def one(pkv, dense):
        if pkv.table.ndim == 3:      # stacked over periods
            return jax.vmap(_write_prefill_one,
                            in_axes=(0, 0, None, None))(pkv, dense, slot,
                                                        pages)
        return _write_prefill_one(pkv, dense, slot, pages)
    return jax.tree.map(one, pool, cache,
                        is_leaf=lambda x: isinstance(x, PagedKV))


def set_tables(pool, table):
    """Overwrite every leaf's page table with host-side ``table``.

    The batcher owns the table on the host (admission allocates, EOS
    retirement frees by repointing rows at scratch); this pushes the
    authoritative copy into the device pool before each decode step."""
    t = jnp.asarray(table, jnp.int32)

    def one(pkv):
        return dataclasses.replace(
            pkv, table=jnp.broadcast_to(t, pkv.table.shape))
    return jax.tree.map(one, pool, is_leaf=lambda x: isinstance(x, PagedKV))


class PagePool:
    """Host-side free-list allocator over a :class:`PagedSpec`.

    Page 0 (scratch) is never handed out.  ``alloc`` is all-or-nothing
    so a request either reserves its whole worst case at admission or
    stays pending — no mid-stream out-of-pages."""

    def __init__(self, spec: PagedSpec):
        self.spec = spec
        self._free = list(range(spec.total_pages - 1, 0, -1))

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages) -> None:
        for p in pages:
            if p != SCRATCH_PAGE:
                self._free.append(int(p))
