"""Property tests for the customized RLE codec (paper §III-C)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import rle, ucr


def weight_vectors(max_len=512):
    return st.lists(st.integers(-128, 127), min_size=1, max_size=max_len)


@given(weight_vectors())
@settings(max_examples=200, deadline=None)
def test_rle_roundtrip_lossless(vals):
    w = np.array(vals, dtype=np.int8)
    u = ucr.ucr_transform(w)
    enc = rle.encode_vector(u.unique_vals, u.reps, u.indexes, u.vector_len)
    assert np.array_equal(rle.decode_vector(enc), w)


@given(weight_vectors())
@settings(max_examples=100, deadline=None)
def test_size_only_matches_exact_bitstream(vals):
    w = np.array(vals, dtype=np.int8)
    u = ucr.ucr_transform(w)
    enc = rle.encode_vector(u.unique_vals, u.reps, u.indexes, u.vector_len)
    size = rle.encoded_bits_size_only(u.unique_vals, u.reps, u.indexes,
                                      u.vector_len)
    assert size == enc.total_bits


@given(st.lists(st.integers(-128, 127), min_size=2, max_size=64),
       st.integers(1, 8))
@settings(max_examples=100, deadline=None)
def test_param_search_is_optimal(vals, fixed_b):
    """The searched Δ parameter never loses to any fixed bit-length."""
    deltas = np.diff(np.unique(np.array(vals, dtype=np.int64)), prepend=0)
    best = rle.search_delta_param(deltas)
    best_bits = rle.escape_stream_bits(deltas, best, rle.FULL_BITS)
    assert best_bits <= rle.escape_stream_bits(deltas, fixed_b, rle.FULL_BITS)


@given(st.lists(st.integers(1, 300), min_size=1, max_size=64),
       st.integers(1, 8))
@settings(max_examples=100, deadline=None)
def test_rep_overflow_chains_preserve_counts(reps, bits):
    reps = np.asarray(reps)
    entries, dummy = rle.split_rep_overflow(reps, bits)
    # total repetitions preserved
    assert entries.sum() == reps.sum()
    # exactly one non-dummy entry per original unique weight
    assert (~dummy).sum() == len(reps)
    # every entry fits the bit budget (stored as count-1)
    assert (entries >= 1).all() and (entries <= (1 << bits)).all()


def test_escape_encoding_matches_paper_example():
    """Fig. 4: small Δs in low-precision fields, escapes at full width."""
    deltas = np.array([1, 2, 1, 120])     # last one cannot fit in 2 bits
    bits = rle.escape_stream_bits(deltas, 2, 8)
    assert bits == 3 * (2 + 1) + (8 + 1)


def test_index_stream_absolute_fallback():
    """Negative index Δ (new unique-weight group) → absolute mode."""
    idx = np.array([3, 5, 9, 2, 4])       # 9→2 is a negative delta
    deltas, absolute = rle.index_delta_fields(idx)
    assert deltas[3] < 0 and absolute[3] == 2
    s = rle.encode_escape_stream(deltas, 2, 4, absolute=absolute)
    out = rle.decode_escape_stream(s, absolute_mode=True)
    vals, escaped = out[0], out[1].astype(bool)
    rebuilt, prev = [], 0
    for v, e in zip(vals, escaped):
        prev = v if e else prev + v
        rebuilt.append(prev)
    assert rebuilt == list(idx)


# ---------------------------------------------------------------------------
# vectorized bulk decoder == scalar oracle, bit-exact
# ---------------------------------------------------------------------------

@given(st.integers(1, 12), st.integers(1, 6), st.integers(1, 3),
       st.integers(1, 3), st.integers(1, 8), st.integers(1, 4),
       st.floats(0.0, 1.0), st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_decode_layer_matches_scalar_decoder(m, n, rk, ck, t_m, t_n,
                                             density, seed):
    """decode_layer (vectorized) must reproduce decode_vector bit-exactly
    for every vector, across shapes, tilings, and sparsities (which drive
    the searched per-layer params through their whole range)."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(m, n, rk, ck)).astype(np.float32)
    w[rng.random(w.shape) > density] = 0
    code = ucr.encode_conv_layer(w, t_m=t_m, t_n=t_n)
    bulk = rle.decode_layer(code)
    for i, v in enumerate(code.vectors):
        assert np.array_equal(bulk[i, : v.vector_len], rle.decode_vector(v))
        assert not bulk[i, v.vector_len :].any()       # padding stays zero


@given(weight_vectors(max_len=256))
@settings(max_examples=60, deadline=None)
def test_decode_layer_per_vector_params(vals):
    """Bulk decode also handles vectors encoded WITHOUT shared layer
    params (per-vector search → mixed parameter groups)."""
    w = np.array(vals, dtype=np.int8)
    u = ucr.ucr_transform(w)
    encs = [rle.encode_vector(u.unique_vals, u.reps, u.indexes, u.vector_len),
            rle.encode_vector(u.unique_vals, u.reps, u.indexes, u.vector_len,
                              params=(1, 1, 1))]

    class _Code:
        vectors = encs

    got = rle.decode_layer_vectors(_Code)
    for dec in got:
        assert np.array_equal(dec, w)


@pytest.mark.parametrize("density", [0.05, 0.3, 0.9])
@pytest.mark.parametrize("n_unique", [4, 16, 256])
def test_compression_improves_with_sparsity_and_repetition(density, n_unique):
    rng = np.random.default_rng(0)
    w = rng.integers(-128, 128, size=4096)
    w = (w // (256 // n_unique) * (256 // n_unique)).astype(np.int8)
    w[rng.random(4096) > density] = 0
    u = ucr.ucr_transform(w)
    bits = rle.encoded_bits_size_only(u.unique_vals, u.reps, u.indexes,
                                      u.vector_len)
    dense_bits = 8 * 4096
    if density <= 0.3:
        assert bits < dense_bits
