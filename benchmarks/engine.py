"""End-to-end CoDR engine benchmark: encode-once / run-many throughput
plus per-layer SRAM-access estimates from the dataflow model.

  PYTHONPATH=src python benchmarks/engine.py [--small] [--batch B]

Reports the offline bitstream decode (now the vectorized bulk decoder),
the one-time compile, and the steady-state (post-compile) throughput as
separate numbers — the engine's compile-once contract makes the last one
the serving-relevant figure.  CSV lines (the harness format):
``name,us_per_call,derived``; a JSON summary (default
``BENCH_engine.json``) tracks the trajectory PR over PR.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

try:
    from benchmarks.common import Timer, csv_line
except ImportError:                                   # run as a script
    import os
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.common import Timer, csv_line

from repro.core.engine import build_random_model, paper_model_shapes
from repro.core.serving import CodrBatchServer


def build(small: bool):
    """conv → conv → linear model on paper-CNN channel geometry."""
    rng = np.random.default_rng(0)
    if small:
        shapes = paper_model_shapes("vgg16", n_conv=2, ri=20, ci=20)
        hw, n_out = (20, 20), 10
    else:
        shapes = paper_model_shapes("alexnet", n_conv=2, ri=67, ci=67)
        hw, n_out = (67, 67), 100
    # the real bitstream decode path — the vectorized bulk decoder makes
    # it cheap enough to benchmark end-to-end (it used to need the "ucr"
    # shortcut source)
    model = build_random_model(shapes, n_out=n_out, density=0.4, rng=rng,
                               decode_source="bitstream")
    return model, hw


def main(small: bool = False, batch: int = 8, iters: int = 5,
         json_path: str | None = "BENCH_engine.json") -> dict:
    model, hw = build(small)
    rng = np.random.default_rng(1)
    x = rng.normal(size=(batch, *hw, model.layers[0].code.shape[1])
                   ).astype(np.float32)

    with Timer() as t_dec:                     # offline bitstream decode
        for layer in model.layers:             # (bulk decoder, once ever)
            _ = layer.tiles
    with Timer() as t_compile:                 # compile + first dispatch
        np.asarray(model.run(x))

    with Timer() as t_run:                     # steady state (post-compile)
        for _ in range(iters):
            y = model.run(x)
        y.block_until_ready()
    us = t_run.dt / iters * 1e6
    imgs_s = batch * iters / t_run.dt
    print(csv_line("engine_decode", t_dec.dt * 1e6,
                   f"bits={sum(l.code.total_bits for l in model.layers)};"
                   f"decode_s={t_dec.dt:.4f}"))
    print(csv_line("engine_compile", t_compile.dt * 1e6,
                   f"traces={model.trace_count}"))
    print(csv_line("engine_forward", us,
                   f"imgs_per_s={imgs_s:.1f};batch={batch};"
                   f"bits_per_weight={model.bits_per_weight():.2f};"
                   f"steady_state=post_compile"))

    server = CodrBatchServer(model, max_batch=batch)
    samples = [rng.normal(size=(*hw, model.layers[0].code.shape[1])
                          ).astype(np.float32) for _ in range(batch + 3)]
    server.serve(samples)                      # warm the size buckets
    batches_before = server.batches_run
    with Timer() as t_srv:
        outs = server.serve(samples)
    print(csv_line("engine_serve", t_srv.dt / len(outs) * 1e6,
                   f"requests={len(outs)};"
                   f"batches={server.batches_run - batches_before};"
                   f"buckets={len(server.bucket_counts)}"))

    for name, acc in model.sram_report(hw):
        print(csv_line(f"engine_sram_{name}", 0.0,
                       f"total_sram={acc.total_sram:.0f};"
                       f"feature_sram={acc.feature_sram:.0f};"
                       f"weight_rows={acc.weight_sram_rows:.0f}"))

    result = {
        "benchmark": "engine", "small": small, "batch": batch,
        "decode_s": t_dec.dt,
        "compile_s": t_compile.dt,
        "steady_us_per_call": us,
        "imgs_per_s": imgs_s,
        "serve_us_per_request": t_srv.dt / len(outs) * 1e6,
        "bits_per_weight": model.bits_per_weight(),
        "trace_count": model.trace_count,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def cli(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="tiny model (CI smoke run)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--json", default="BENCH_engine.json",
                    help="JSON output path ('' disables)")
    args = ap.parse_args(argv)
    if args.batch < 1 or args.iters < 1:
        ap.error("--batch and --iters must be >= 1")
    print("name,us_per_call,derived")
    main(small=args.small, batch=args.batch, iters=args.iters,
         json_path=args.json or None)


if __name__ == "__main__":
    cli()
