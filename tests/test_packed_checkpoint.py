"""Packed checkpoint artifact: round-trip bit-identity + corruption.

Contract (docs/DESIGN.md §2.2): ``codr.save_packed`` /
``codr.load_packed`` round-trip a ``CompiledParams`` byte-for-byte —
same packed bitstreams, same logits bits, same config/plan/paths — and
every way an artifact can be damaged (missing files, truncation, dtype
drift, version skew) raises ``PackedCheckpointError`` with a message
naming the problem, never a silent wrong-weights boot.
"""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as codr
from repro.configs import get_config, smoke_variant
from repro.models import get_model

N_UNIQUE = 16


def _compiled(arch, key):
    cfg = smoke_variant(get_config(arch))
    api = get_model(cfg)
    params = api.init_params(key, cfg)
    cp = codr.compile_params(params, codr.EncodeConfig(n_unique=N_UNIQUE),
                             backend="codr_matmul")
    return cfg, api, cp


def _batch(cfg, key):
    b = {"tokens": jax.random.randint(key, (2, 6), 0, cfg.vocab_size)}
    if cfg.frontend or cfg.family == "encdec":
        b["prefix"] = jax.random.normal(
            key, (2, cfg.frontend_seq, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "seamless-m4t-medium"])
def test_roundtrip_bit_identical_logits(arch, key, tmp_path):
    cfg, api, cp = _compiled(arch, key)
    batch = _batch(cfg, key)
    ref, _ = api.prefill(cp.params, batch, cfg)

    path = str(tmp_path / "ck.codr")
    assert codr.save_packed(cp, path) == path
    cp2 = codr.load_packed(path)
    got, _ = api.prefill(cp2.params, batch, cfg)
    np.testing.assert_array_equal(np.asarray(ref, np.float32),
                                  np.asarray(got, np.float32))
    assert cp2.config == cp.config
    assert cp2.backend == cp.backend
    assert cp2.packed_paths == cp.packed_paths
    assert cp2.quantized_paths == cp.quantized_paths
    assert cp2.embed_paths == cp.embed_paths
    assert cp2.reports == cp.reports
    assert cp2.hbm_bytes() == cp.hbm_bytes()


def test_roundtrip_preserves_plan(key, tmp_path):
    from repro.tune import TunePlan
    cfg, api, _ = _compiled("qwen2.5-3b", key)
    params = api.init_params(key, cfg)
    plan = TunePlan({}, default=codr.EncodeConfig(n_unique=N_UNIQUE))
    cp = codr.compile_params(params, codr.EncodeConfig(n_unique=N_UNIQUE),
                             plan=plan)
    path = str(tmp_path / "ck.codr")
    codr.save_packed(cp, path)
    cp2 = codr.load_packed(path)
    assert cp2.plan is not None
    assert cp2.plan.to_json() == plan.to_json()


def test_atomic_overwrite(key, tmp_path):
    _, _, cp = _compiled("qwen2.5-3b", key)
    path = str(tmp_path / "ck.codr")
    codr.save_packed(cp, path)
    codr.save_packed(cp, path)                 # overwrite is clean
    assert not os.path.exists(path + ".tmp")   # no stale staging dir
    codr.load_packed(path)


def test_missing_artifact_raises(tmp_path):
    with pytest.raises(codr.PackedCheckpointError, match="manifest"):
        codr.load_packed(str(tmp_path / "nope.codr"))


def test_version_mismatch_raises(key, tmp_path):
    _, _, cp = _compiled("qwen2.5-3b", key)
    path = str(tmp_path / "ck.codr")
    codr.save_packed(cp, path)
    m = json.load(open(os.path.join(path, "manifest.json")))
    m["format_version"] = codr.CODR_FORMAT_VERSION + 1
    json.dump(m, open(os.path.join(path, "manifest.json"), "w"))
    with pytest.raises(codr.PackedCheckpointError, match="format version"):
        codr.load_packed(path)


def test_truncated_array_raises(key, tmp_path):
    _, _, cp = _compiled("qwen2.5-3b", key)
    path = str(tmp_path / "ck.codr")
    codr.save_packed(cp, path)
    apath = os.path.join(path, "arr_0.npy")
    blob = open(apath, "rb").read()
    open(apath, "wb").write(blob[:len(blob) // 2])
    with pytest.raises(codr.PackedCheckpointError):
        codr.load_packed(path)


def test_missing_array_file_raises(key, tmp_path):
    _, _, cp = _compiled("qwen2.5-3b", key)
    path = str(tmp_path / "ck.codr")
    codr.save_packed(cp, path)
    os.remove(os.path.join(path, "arr_1.npy"))
    with pytest.raises(codr.PackedCheckpointError, match="missing array"):
        codr.load_packed(path)


def test_wrong_dtype_raises(key, tmp_path):
    _, _, cp = _compiled("qwen2.5-3b", key)
    path = str(tmp_path / "ck.codr")
    codr.save_packed(cp, path)
    # rewrite arr_0 with a different dtype than the manifest promises
    a = np.load(os.path.join(path, "arr_0.npy"))
    np.save(os.path.join(path, "arr_0.npy"), a.astype(np.float64))
    with pytest.raises(codr.PackedCheckpointError, match="dtype"):
        codr.load_packed(path)


def test_bad_magic_raises(key, tmp_path):
    _, _, cp = _compiled("qwen2.5-3b", key)
    path = str(tmp_path / "ck.codr")
    codr.save_packed(cp, path)
    m = json.load(open(os.path.join(path, "manifest.json")))
    m["magic"] = "not-a-codr-checkpoint"
    json.dump(m, open(os.path.join(path, "manifest.json"), "w"))
    with pytest.raises(codr.PackedCheckpointError, match="magic"):
        codr.load_packed(path)


def test_corrupt_manifest_json_raises(key, tmp_path):
    _, _, cp = _compiled("qwen2.5-3b", key)
    path = str(tmp_path / "ck.codr")
    codr.save_packed(cp, path)
    mpath = os.path.join(path, "manifest.json")
    blob = open(mpath).read()
    open(mpath, "w").write(blob[:len(blob) // 2])
    with pytest.raises(codr.PackedCheckpointError, match="JSON"):
        codr.load_packed(path)


def test_mmap_false_loads_materialized(key, tmp_path):
    cfg, api, cp = _compiled("qwen2.5-3b", key)
    batch = _batch(cfg, key)
    ref, _ = api.prefill(cp.params, batch, cfg)
    path = str(tmp_path / "ck.codr")
    codr.save_packed(cp, path)
    cp2 = codr.load_packed(path, mmap=False)
    got, _ = api.prefill(cp2.params, batch, cfg)
    np.testing.assert_array_equal(np.asarray(ref, np.float32),
                                  np.asarray(got, np.float32))
