"""CoDR dataflow loop-ordering + cost model: the paper's §III-B / Fig. 7
claims in relative form."""
import numpy as np
import pytest

from repro.core import cost_model, dataflow, ucr
from repro.core.baselines import scnn_compress_bits, ucnn_compress_bits
from repro.core.dataflow import (CODR_TILING, SCNN_TILING, UCNN_TILING,
                                 ConvShape)


@pytest.fixture(scope="module")
def layer_stats(rng):
    shape = ConvShape(128, 64, 3, 3, 30, 30)
    w = rng.normal(size=(shape.m, shape.n, shape.rk, shape.ck)).astype(np.float32)
    w[rng.random(w.shape) < 0.6] = 0
    code = ucr.encode_conv_layer(w, t_m=CODR_TILING.t_m, t_n=CODR_TILING.t_n)
    n_unique = sum(len(u.unique_vals) for u in code.ucr)
    n_nonzero = sum(u.n_nonzero for u in code.ucr)
    return shape, code, n_unique, n_nonzero


def test_codr_output_stationary(layer_stats):
    """Paper: CoDR accesses output features exactly once."""
    shape, code, nu, nn = layer_stats
    acc = dataflow.codr_accesses(shape, CODR_TILING, code.total_bits, nu, nn)
    assert acc.output_sram == shape.n_outputs


def test_codr_input_fetch_count(layer_stats):
    """Inputs fetched ceil(M / (T_PU*T_M)) times."""
    shape, code, nu, nn = layer_stats
    acc = dataflow.codr_accesses(shape, CODR_TILING, code.total_bits, nu, nn)
    expected = shape.n_inputs * int(np.ceil(
        shape.m / (CODR_TILING.t_pu * CODR_TILING.t_m)))
    assert acc.input_sram == expected


def test_codr_fewer_feature_accesses_than_baselines(layer_stats):
    shape, code, nu, nn = layer_stats
    codr = dataflow.codr_accesses(shape, CODR_TILING, code.total_bits, nu, nn)
    ucnn = dataflow.ucnn_accesses(shape, UCNN_TILING, code.total_bits, nu, nn)
    scnn = dataflow.scnn_accesses(shape, SCNN_TILING,
                                  scnn_compress_bits(
                                      ucr.quantize_int8(np.zeros((1, 1)))[0]),
                                  nu, nn)
    assert codr.feature_sram < ucnn.feature_sram
    assert codr.feature_sram < scnn.output_sram + scnn.input_sram


def test_codr_trades_weight_streams_for_feature_reuse(layer_stats):
    """The paper's core dataflow trade: more weight traffic, fewer
    feature accesses — profitable because weight access is ~20× cheaper."""
    shape, code, nu, nn = layer_stats
    codr = dataflow.codr_accesses(shape, CODR_TILING, code.total_bits, nu, nn)
    assert codr.weight_bits_streamed > code.total_bits  # re-streamed
    ratio = cost_model.weight_sram_cost_ratio(code.bits_per_weight)
    assert ratio > 5.0


def test_energy_model_relative_ordering(layer_stats):
    shape, code, nu, nn = layer_stats
    q, _ = ucr.quantize_int8(np.random.default_rng(0).normal(
        size=(shape.m, shape.n, shape.rk, shape.ck)).astype(np.float32))
    codr = cost_model.energy(dataflow.codr_accesses(
        shape, CODR_TILING, code.total_bits, nu, nn))
    ucnn = cost_model.energy(dataflow.ucnn_accesses(
        shape, UCNN_TILING, code.total_bits * 1.69, nu, nn))
    scnn = cost_model.energy(dataflow.scnn_accesses(
        shape, SCNN_TILING, scnn_compress_bits(q), nu, shape.n_weights * 0.4))
    assert codr.total_uj < ucnn.total_uj
    assert codr.total_uj < scnn.total_uj
    for e in (codr, ucnn, scnn):
        assert e.total_uj > 0


def test_compression_ordering_codr_ucnn_scnn(rng):
    """Fig. 6: CoDR ≥ UCNN ≥ SCNN compression on NN-like weights
    (Laplacian-concentrated, as real 8-bit CNN weights are — paper
    Fig. 2; flat random weights have no repetition to exploit)."""
    w = rng.laplace(scale=6.0, size=(64, 32, 3, 3))
    w = np.clip(np.round(w), -127, 127).astype(np.float32)
    w[rng.random(w.shape) < 0.4] = 0
    q = w.astype(np.int8)
    code = ucr.encode_conv_layer(w, t_m=4, t_n=4)
    codr_bits = code.total_bits
    ucnn_bits = ucnn_compress_bits(code.ucr)
    scnn_bits = scnn_compress_bits(q)
    assert codr_bits < ucnn_bits < scnn_bits


def test_conv_shape_arithmetic():
    s = ConvShape(8, 4, 3, 3, 10, 10, stride=1)
    assert (s.ro, s.co) == (8, 8)
    assert s.macs == 8 * 8 * 8 * 4 * 9
    s2 = ConvShape(8, 4, 3, 3, 11, 11, stride=2)
    assert (s2.ro, s2.co) == (5, 5)
