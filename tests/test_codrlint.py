"""codrlint: fixture-driven checker tests + the repo-must-be-clean gate.

Each checker has a paired bad/good fixture under ``tests/lint_fixtures``
(a directory the linter's own discovery excludes — fixtures are linted
here by explicit file path).  The gate test at the bottom is tier-1: a
guarded-by violation or an ``np.asarray`` inside a jitted body anywhere
in ``src``/``tools`` fails the suite, not just the CI lint step.
"""
import json
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:           # tests/ is sys.path[0], not repo root
    sys.path.insert(0, str(REPO))

from tools.codrlint import run, registered_checkers  # noqa: E402

FIXTURES = REPO / "tests" / "lint_fixtures"

EXPECTED_CHECKERS = {"jit-purity", "lock-discipline",
                     "capability-consistency", "pytree-registration",
                     "export-surface", "exception-hygiene"}


def lint(*names, only=None):
    """Lint fixture files by explicit path, baseline disabled."""
    paths = tuple(str(FIXTURES / n) for n in names)
    return run(paths, root=REPO, baseline=False, only=only)


def test_all_checkers_registered():
    assert EXPECTED_CHECKERS <= set(registered_checkers())


# -- one bad + one good fixture per checker ------------------------------

@pytest.mark.parametrize("check,bad,good,min_findings", [
    ("jit-purity", "jit_purity_bad.py", "jit_purity_good.py", 7),
    ("lock-discipline", "lock_discipline_bad.py",
     "lock_discipline_good.py", 3),
    ("capability-consistency", "capability_bad.py",
     "capability_good.py", 5),
    ("pytree-registration", "pytree_bad.py", "pytree_good.py", 2),
    ("export-surface", "exports_bad.py", "exports_good.py", 2),
    ("exception-hygiene", "exception_hygiene_bad.py",
     "exception_hygiene_good.py", 3),
])
def test_checker_fires_on_bad_not_on_good(check, bad, good, min_findings):
    rb = lint(bad, only=(check,))
    assert not rb.ok
    assert len(rb.findings) >= min_findings
    assert all(f.check == check for f in rb.findings)
    assert all(f.key and str(f.line) not in f.key.split(":")
               for f in rb.findings), "keys must be line-number free"
    rg = lint(good, only=(check,))
    assert rg.ok, [f.format() for f in rg.findings]


def test_jit_purity_specifics():
    r = lint("jit_purity_bad.py", only=("jit-purity",))
    # decorated fn, coercions, scan body by name, and the lambda form
    # (the owner prefix may itself contain colons — match by suffix)
    for what in ("np.asarray", "print", "float", "item",
                 "time.monotonic", "set:count", "np.square"):
        assert any(f.key.endswith(":" + what) for f in r.findings), what


def test_lock_discipline_inheritance_crosses_classes():
    r = lint("lock_discipline_bad.py", only=("lock-discipline",))
    keys = {f.key for f in r.findings}
    assert "Child.bad_inherited:_queue" in keys  # guard declared in Loop


def test_exports_resolve_against_real_source_tree():
    r = lint("exports_bad.py", only=("export-surface",))
    keys = {f.key for f in r.findings}
    assert "import:repro.core.serving.NoSuchSymbolXYZ" in keys
    assert "__all__:never_defined_name" in keys


# -- suppressions --------------------------------------------------------

def test_suppression_without_rationale_is_itself_a_finding():
    r = lint("suppression_bad.py")
    assert not r.ok
    assert len(r.bad_suppressions) == 1
    assert r.bad_suppressions[0].check == "bad-suppression"
    assert not r.findings            # the original finding was consumed


def test_suppression_with_rationale_silences_same_line_and_above():
    r = lint("suppression_good.py")
    assert r.ok
    assert r.suppressed == 2


# -- baseline mechanism --------------------------------------------------

def test_baseline_grandfathers_and_reports_stale(tmp_path):
    live = lint("exception_hygiene_bad.py")
    assert live.findings
    fps = [f.fingerprint for f in live.findings]
    base = tmp_path / "baseline.json"
    base.write_text(json.dumps(fps + ["exception-hygiene:gone.py:ghost"]))
    r = run((str(FIXTURES / "exception_hygiene_bad.py"),),
            root=REPO, baseline=base)
    assert r.ok
    assert r.baselined == len(fps)
    assert r.stale_baseline == ["exception-hygiene:gone.py:ghost"]


def test_fingerprints_are_line_free_and_stable():
    a = lint("pytree_bad.py")
    b = lint("pytree_bad.py")
    assert [f.fingerprint for f in a.findings] == \
        [f.fingerprint for f in b.findings]
    assert all(str(f.line) not in f.fingerprint.rsplit(":", 1)[-1]
               for f in a.findings)


# -- CLI -----------------------------------------------------------------

def test_cli_exit_codes_and_json_report(tmp_path):
    out = tmp_path / "codrlint.json"
    bad = subprocess.run(
        [sys.executable, "-m", "tools.codrlint", "--no-baseline",
         "--json", str(out),
         str(FIXTURES / "exception_hygiene_bad.py")],
        cwd=REPO, capture_output=True, text=True)
    assert bad.returncode == 1, bad.stdout + bad.stderr
    payload = json.loads(out.read_text())
    assert payload["ok"] is False and len(payload["findings"]) >= 3
    good = subprocess.run(
        [sys.executable, "-m", "tools.codrlint", "--no-baseline",
         str(FIXTURES / "exception_hygiene_good.py")],
        cwd=REPO, capture_output=True, text=True)
    assert good.returncode == 0, good.stdout + good.stderr


def test_cli_rejects_unknown_checker():
    r = subprocess.run(
        [sys.executable, "-m", "tools.codrlint", "--only", "no-such-check"],
        cwd=REPO, capture_output=True, text=True)
    assert r.returncode == 2
    assert "unknown checker" in r.stderr


# -- the tier-1 gate: the live repo must be clean ------------------------

def test_repo_is_codrlint_clean():
    r = run(("src", "tools"), root=REPO)
    msgs = [f.format() for f in r.findings + r.bad_suppressions]
    assert r.ok, "codrlint violations in the repo:\n" + "\n".join(msgs)
    assert not r.stale_baseline, (
        "baseline.json lists fingerprints no longer observed — prune: "
        f"{r.stale_baseline}")


def test_injected_violation_fails_the_gate(tmp_path):
    """Acceptance check from the issue: a fresh np.asarray inside a
    jitted body (or a guarded-by breach) must be caught."""
    src = tmp_path / "injected.py"
    src.write_text(
        "import jax\nimport numpy as np\n\n"
        "@jax.jit\ndef f(x):\n    return np.asarray(x)\n")
    r = run((str(src),), root=tmp_path, baseline=False)
    assert not r.ok
    assert r.findings[0].check == "jit-purity"
