"""Scalar–matrix-multiplication dataflow == dense convolution (the reuse
schedule must change work, never results)."""
import numpy as np
import pytest

from repro.core import smm, ucr


@pytest.mark.parametrize("shape", [
    (4, 3, 3, 3, 8, 8, 1),
    (8, 5, 2, 2, 10, 10, 1),
    (6, 2, 3, 3, 11, 11, 2),
    (4, 4, 1, 1, 6, 6, 1),
])
@pytest.mark.parametrize("density", [0.1, 0.5, 1.0])
def test_conv_smm_equals_dense(shape, density, rng):
    m, n, rk, ck, ri, ci, stride = shape
    w = rng.normal(size=(m, n, rk, ck)).astype(np.float32)
    w[rng.random(w.shape) > density] = 0
    code = ucr.encode_conv_layer(w, t_m=2, t_n=2)
    q, _ = ucr.quantize_int8(w)
    x = rng.integers(-8, 8, size=(n, ri, ci)).astype(np.int8)
    ref = smm.conv2d_dense_ref(x.astype(np.int64), q, stride)
    got = smm.conv2d_smm(x, code, stride)
    assert np.array_equal(ref, got)


@pytest.mark.parametrize("stride", [1, 2])
def test_conv_smm_batched_equals_dense(stride, rng):
    """The batched SMM path (products broadcast over the batch axis — no
    per-sample Python loop) matches the dense oracle per sample."""
    w = rng.normal(size=(6, 2, 3, 3)).astype(np.float32)
    w[rng.random(w.shape) > 0.5] = 0
    code = ucr.encode_conv_layer(w, t_m=2, t_n=2)
    q, _ = ucr.quantize_int8(w)
    x = rng.integers(-8, 8, size=(4, 2, 11, 11)).astype(np.int32)
    got = smm.conv2d_smm_batched(x, code, stride)
    for b in range(4):
        assert np.array_equal(
            got[b], smm.conv2d_dense_ref(x[b].astype(np.int64), q, stride))


def test_linear_smm_equals_matmul(rng):
    w = rng.normal(size=(48, 32)).astype(np.float32)
    w[rng.random(w.shape) < 0.6] = 0
    code = ucr.encode_linear_layer(w, t_m=16, t_n=1)
    q, _ = ucr.quantize_int8(w)
    x = rng.integers(-10, 10, size=32)
    assert np.array_equal(q.astype(np.int64) @ x, smm.linear_smm(x, code))


def test_computation_reuse_reduces_multiplies(rng):
    """The paper's ALU claim: multiplies scale with unique weights."""
    w = rng.normal(size=(16, 8, 3, 3)).astype(np.float32)
    q, _ = ucr.quantize_int8(w)
    q = (q.astype(np.int32) // 32 * 32).astype(np.int8)   # few uniques
    code = ucr.encode_conv_layer(q.astype(np.float32), t_m=4, t_n=4)
    counts = smm.smm_op_counts(code, feature_elems=100)
    assert counts["mults"] < counts["dense_mults"]
    assert counts["unique_ratio"] <= 1.0
    # dense work is density * kernel count when no repetition exploited
    assert counts["accums"] <= counts["dense_mults"]
