"""Pallas TPU kernel: CoDR unique-index compressed matmul.

``y = x @ decode(packed, table) * scale``

TPU adaptation of the CoDR PU (docs/DESIGN.md §2): the compressed weight
stream lives in HBM at ``bits/8`` bytes per weight; each grid step DMAs
one packed block into VMEM, decodes it with vector shifts + a masked
table reduction (the "Weight Decoder"), and feeds the dense tile to the
MXU.  The output tile is **output-stationary** in a VMEM scratch
accumulator across the K loop (the APE), and the activation tile is
reused across the N loop (the shared Input RF) — the paper's loop
ordering with HBM⇄VMEM standing in for SRAM⇄RF.

Weight layout: ``packed[k, n*bits//32]`` uint32 words, ``table[2**bits]``
sorted unique values (bf16/f32), per-tensor ``scale``.

Grid: ``(M//bm, N//bn, K//bk)`` — K innermost so the accumulator stays
resident; N next so the x-block is revisited (input semi-stationary);
M outermost (outputs written exactly once — "fully output stationary").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decode_block(packed_blk: jax.Array, table: jax.Array, bits: int,
                  bn: int) -> jax.Array:
    """uint32 words → dense (bk, bn) weight block (VMEM, vector ops)."""
    per_word = 32 // bits
    shifts = (jnp.arange(per_word, dtype=jnp.uint32) * bits)[None, None, :]
    mask = jnp.uint32((1 << bits) - 1)
    idx = (packed_blk[:, :, None] >> shifts) & mask          # (bk, bn/pw, pw)
    idx = idx.reshape(packed_blk.shape[0], bn).astype(jnp.int32)
    # masked table reduction — 2**bits selects; sorted-unique table makes
    # this the "Weight Decoder" (no gather needed on the TPU vector unit)
    n_entries = table.shape[0]
    out = jnp.zeros(idx.shape, dtype=jnp.float32)

    def body(u, acc):
        return acc + jnp.where(idx == u, table[u].astype(jnp.float32), 0.0)

    return jax.lax.fori_loop(0, n_entries, body, out)


def _codr_matmul_kernel(x_ref, packed_ref, table_ref, scale_ref, o_ref,
                        acc_ref, *, bits: int, bn: int, n_k: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_blk = _decode_block(packed_ref[...], table_ref[...], bits, bn)
    x_blk = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x_blk, w_blk,
                            preferred_element_type=jnp.float32)

    @pl.when(k_step == n_k - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * scale_ref[0]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bits", "n", "bm", "bn", "bk", "interpret"))
def codr_matmul_pallas(x: jax.Array, packed: jax.Array, table: jax.Array,
                       scale: jax.Array, *, bits: int, n: int,
                       bm: int = 128, bn: int = 128, bk: int = 128,
                       interpret: bool = False) -> jax.Array:
    m, k = x.shape
    per_word = 32 // bits
    assert packed.shape == (k, n // per_word), (packed.shape, (k, n // per_word))
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))

    kernel = functools.partial(_codr_matmul_kernel, bits=bits, bn=bn,
                               n_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),      # x: reused over j
            pl.BlockSpec((bk, bn // per_word), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((table.shape[0],), lambda i, j, kk: (0,)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, packed, table, scale.reshape(1))
