"""codrlint fixture: guarded attributes accessed per the convention."""
import threading


class Loop:
    def __init__(self):
        self._cv = threading.Condition()
        self._queue = []            # guarded-by: _cv
        self.count = 0              # guarded-by: _cv

    def ok_locked_block(self):
        with self._cv:
            self._queue.append(1)
            self.count += 1

    def _drain_locked(self):
        # *_locked suffix: caller holds the lock by convention
        n = len(self._queue)
        self._queue.clear()
        return n

    def unrelated(self):
        return threading.active_count()


class Child(Loop):
    def ok_inherited(self):
        with self._cv:
            return list(self._queue)
