"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
