"""Paper Fig. 6 — weight compression across three CNNs, swept over
density (D) and unique-weight count (U).  Reports bits/weight for CoDR's
customized RLE vs UCNN (fixed 5-bit RLE + transition bits) and SCNN
(8-bit weights + 4-bit zero run lengths), and the headline ratios
(paper: CoDR 1.69× vs UCNN, 2.80× vs SCNN on the original profiles).

Also runs the **tuning lane** (``repro.tune``): a quality-vs-bits/weight
Pareto curve over global U budgets plus the per-layer tuned plan and the
best single global config on paper-CNN geometry, written to
``BENCH_tune.json`` (git-SHA-stamped) so the tuned-vs-global gap is
tracked PR over PR.  ``small=True`` (CI: ``--only compression
--small``) keeps the Fig. 6 sweep to one model and shrinks the tuned
spec to the smoke geometry."""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import BASE_DENSITY, Timer, bench_meta, csv_line, \
    make_weights, sampled_layer_vectors
from repro.configs.paper_cnns import PAPER_CNNS
from repro.core import rle
from repro.core.baselines.scnn import scnn_compress_bits
from repro.core.baselines.ucnn import ucnn_vector_bits
from repro.core.dataflow import CODR_TILING

# the paper's sweep: middle group = original profile; right groups lower
# density; left groups fewer unique weights
SWEEPS = [
    ("U16", 1.0, 16), ("U64", 1.0, 64),
    ("orig", 1.0, 256),
    ("D0.6", 0.6, 256), ("D0.4", 0.4, 256), ("D0.2", 0.2, 256),
]


def model_bits(model: str, density: float, n_unique: int, rng) -> dict:
    codr = ucnn = scnn = total_w = 0.0
    for shape in PAPER_CNNS[model]:
        q = make_weights((shape.m, shape.n, shape.rk, shape.ck),
                         density=density * BASE_DENSITY[model],
                         n_unique=n_unique, rng=rng)
        vecs, scale = sampled_layer_vectors(q, CODR_TILING.t_m,
                                            CODR_TILING.t_n)
        codr += scale * rle.layer_bits_size_only(
            vecs, CODR_TILING.t_m * shape.rk * shape.ck)
        ucnn += scale * sum(ucnn_vector_bits(u) for u in vecs)
        scnn += scnn_compress_bits(q)
        total_w += shape.n_weights
    return {"codr_bpw": codr / total_w, "ucnn_bpw": ucnn / total_w,
            "scnn_bpw": scnn / total_w,
            "vs_ucnn": ucnn / codr, "vs_scnn": scnn / codr}


def tune_section(print_fn=print, small: bool = False,
                 json_path: str = "BENCH_tune.json") -> list[str]:
    """Quality-vs-bits/weight Pareto curve + tuned-vs-global comparison,
    written to ``BENCH_tune.json``."""
    from repro.launch.tune import run_tune
    from repro.tune import pareto_curve

    import repro.api as codr

    hw = (20, 20) if small else (28, 28)
    n_conv = 2 if small else 3
    spec = codr.ModelSpec.from_paper_cnn(
        "vgg16", n_conv=n_conv, n_out=10, ri=hw[0], ci=hw[1],
        density=0.4, rng=np.random.default_rng(0))

    with Timer() as t:
        result = run_tune(model="vgg16", n_conv=n_conv, input_hw=hw,
                          density=0.4, max_rel_err=0.03, verbose=False)
    plan = result["plan"]
    points = pareto_curve(spec, hw, n_uniques=(8, 16, 32, 64, 256),
                          plans={"tuned": plan},
                          batch=8 if small else 32)

    lines = []
    for p in points:
        lines.append(csv_line(
            f"tune_pareto/vgg16/{p['tag']}", 0.0,
            f"bpw={p['bits_per_weight']:.2f}"
            f";sram={p['sram_accesses']:.3e}"
            f";top1={p['top1_match']:.3f}"
            f";rel_err={p['rel_logit_err']:.4f}"))
        print_fn(lines[-1])
    tn, gl = result["tuned"], result["global"]
    lines.append(csv_line(
        "tune_pareto/vgg16/tuned_vs_global", t.dt * 1e6,
        f"tuned_bpw={tn['bits_per_weight']:.3f}"
        f";global_bpw={gl['bits_per_weight']:.3f}"
        f";tuned_sram={tn['sram_accesses']:.3e}"
        f";global_sram={gl['sram_accesses']:.3e}"
        f";tuned_top1={tn['top1_match']:.3f}"
        f";global_top1={gl['top1_match']:.3f}"))
    print_fn(lines[-1])

    with open(json_path, "w") as f:
        json.dump({
            "meta": bench_meta(small=small, input_hw=list(hw),
                               n_conv=n_conv,
                               budget=plan.budget.as_dict()),
            "pareto": points,
            "tuned": tn,
            "global": {**gl,
                       "config": result["global_config"].metadata()},
            "plan": plan.to_json(),
        }, f, indent=2)
    print_fn(csv_line(f"tune_pareto/json:{json_path}", 0.0,
                      f"points={len(points)}"))
    return lines


def main(print_fn=print, small: bool = False) -> list[str]:
    rng = np.random.default_rng(0)
    lines = []
    ratios_u, ratios_s = [], []
    models = ["vgg16"] if small else list(PAPER_CNNS)
    sweeps = SWEEPS[:3] if small else SWEEPS
    for model in models:
        for tag, density, n_unique in sweeps:
            with Timer() as t:
                r = model_bits(model, density, n_unique, rng)
            name = f"fig6_compression/{model}/{tag}"
            derived = (f"codr={r['codr_bpw']:.2f}bpw"
                       f";ucnn={r['ucnn_bpw']:.2f}"
                       f";scnn={r['scnn_bpw']:.2f}"
                       f";x_ucnn={r['vs_ucnn']:.2f}"
                       f";x_scnn={r['vs_scnn']:.2f}")
            lines.append(csv_line(name, t.dt * 1e6, derived))
            print_fn(lines[-1])
            ratios_u.append(r["vs_ucnn"])
            ratios_s.append(r["vs_scnn"])
    lines.append(csv_line(
        "fig6_compression/MEAN", 0.0,
        f"x_ucnn={np.mean(ratios_u):.2f}(paper:1.69)"
        f";x_scnn={np.mean(ratios_s):.2f}(paper:2.80)"))
    print_fn(lines[-1])
    lines += tune_section(print_fn, small=small)
    return lines


if __name__ == "__main__":
    main()
