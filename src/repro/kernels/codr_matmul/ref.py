"""Pure-jnp oracle for the CoDR compressed matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_ref(packed: jax.Array, table: jax.Array, *, bits: int,
               n: int) -> jax.Array:
    per_word = 32 // bits
    shifts = jnp.arange(per_word, dtype=jnp.uint32) * bits
    mask = jnp.uint32((1 << bits) - 1)
    idx = (packed[:, :, None] >> shifts[None, None, :]) & mask
    idx = idx.reshape(packed.shape[0], n).astype(jnp.int32)
    return jnp.take(table, idx, axis=0).astype(jnp.float32)


def codr_matmul_ref(x: jax.Array, packed: jax.Array, table: jax.Array,
                    scale: jax.Array, *, bits: int, n: int) -> jax.Array:
    dense = decode_ref(packed, table, bits=bits, n=n)
    y = jnp.dot(x.astype(jnp.float32), dense) * scale
    return y.astype(x.dtype)
