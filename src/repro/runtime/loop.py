"""Fault-tolerant training loop.

Composes: data pipeline (step-indexed, restart-exact) → jitted train
step (loss + grad + AdamW, optional bf16 gradient compression before the
cross-pod all-reduce) → checkpoint manager (async, atomic) → straggler
monitor → elastic re-mesh on simulated failure.  This is the runtime a
launcher (`repro.launch.train`) drives.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, restore_latest
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.runtime.straggler import StragglerMonitor


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    peak_lr: float = 3e-4
    warmup_steps: int = 10
    grad_compression: str | None = None   # None | "bf16"
    fail_at_step: int | None = None       # simulated host failure (tests)


def make_train_step(train_loss_fn: Callable, opt_cfg: AdamWConfig,
                    loop_cfg: TrainLoopConfig):
    """Build the jittable (params, opt_state, batch) → ... step."""

    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(train_loss_fn)(params, batch)
        if loop_cfg.grad_compression == "bf16":
            # compress gradients before the (cross-pod) all-reduce; XLA
            # fuses the cast into the reduce-scatter/all-gather pair.
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        lr = cosine_schedule(opt_state["step"], peak_lr=loop_cfg.peak_lr,
                             warmup_steps=loop_cfg.warmup_steps,
                             total_steps=loop_cfg.total_steps)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg, lr=lr)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step_fn


class TrainLoop:
    def __init__(self, *, train_loss_fn, params, batch_iter,
                 opt_cfg: AdamWConfig | None = None,
                 loop_cfg: TrainLoopConfig | None = None,
                 jit_kwargs: dict | None = None):
        self.loop_cfg = loop_cfg or TrainLoopConfig()
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.params = params
        self.opt_state = adamw_init(params, self.opt_cfg)
        self.batch_iter = batch_iter
        self.ckpt = CheckpointManager(self.loop_cfg.ckpt_dir)
        self.monitor = StragglerMonitor(n_hosts=jax.process_count())
        step_fn = make_train_step(train_loss_fn, self.opt_cfg, self.loop_cfg)
        self.step_fn = jax.jit(step_fn, **(jit_kwargs or {}))
        self.start_step = 0
        self.history: list[dict] = []

    # -- fault tolerance ----------------------------------------------------
    def try_restore(self) -> int:
        state = {"params": self.params, "opt": self.opt_state}
        restored, extra, step = restore_latest(self.ckpt, state)
        if restored is not None:
            self.params = restored["params"]
            self.opt_state = restored["opt"]
            self.start_step = step + 1
        return self.start_step

    def _save(self, step: int) -> None:
        self.ckpt.save(step, {"params": self.params, "opt": self.opt_state},
                       extra={"data_cursor": step + 1}, async_=True)

    # -- main loop ------------------------------------------------------------
    def run(self, *, max_steps: int | None = None) -> list[dict]:
        cfg = self.loop_cfg
        end = min(cfg.total_steps,
                  self.start_step + (max_steps or cfg.total_steps))
        for step, batch in self.batch_iter:
            if step < self.start_step:
                continue
            if step >= end:
                break
            if cfg.fail_at_step is not None and step == cfg.fail_at_step:
                # the simulated failure kills the *process*, not I/O issued
                # steps ago: join the async writer so the last checkpoint
                # commit isn't racily lost with the in-memory state.
                self.ckpt.wait()
                raise RuntimeError(f"simulated host failure at step {step}")
            t0 = time.monotonic()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.monotonic() - t0
            self.monitor.observe(np.array([dt] * max(jax.process_count(), 1)))
            metrics["step_time_s"] = dt
            metrics["step"] = step
            self.history.append(metrics)
            if step % cfg.checkpoint_every == 0 and step > 0:
                self._save(step)
        self.ckpt.wait()
        return self.history
