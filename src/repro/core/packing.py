"""Bit-level packing utilities for the CoDR run-length encoder.

The CoDR RLE streams are true variable-width bitstreams (paper Fig. 4):
each field is ``flag_bit + payload`` where the payload is either the
low-precision width ``b`` or the full-precision width.  We implement an
exact bit-accurate packer/unpacker so compression ratios are measured in
real bits, not estimates.

Packing is fully vectorized (numpy).  Unpacking of variable-width streams
is inherently sequential (the width of field ``k+1`` depends on the flag
bit of field ``k``), so the decoder walks the bitstream with an integer
cursor; this is only used in tests and the (small) kernel demos — the
benchmarks use the vectorized size-only path in :mod:`repro.core.rle`.
"""
from __future__ import annotations

import numpy as np

__all__ = ["pack_varbits", "unpack_bits", "BitReader"]


def pack_varbits(values: np.ndarray, widths: np.ndarray) -> tuple[np.ndarray, int]:
    """Pack ``values[i]`` into ``widths[i]`` bits each, LSB-first per field.

    Returns ``(packed_uint8, total_bits)``.  Values must be non-negative and
    fit in their widths (masked to width — caller is responsible for
    two's-complement pre-encoding of negatives).
    """
    values = np.asarray(values, dtype=np.uint64)
    widths = np.asarray(widths, dtype=np.int64)
    if values.shape != widths.shape:
        raise ValueError(f"shape mismatch {values.shape} vs {widths.shape}")
    total_bits = int(widths.sum())
    if total_bits == 0:
        return np.zeros(0, dtype=np.uint8), 0
    # index of the source value for every output bit
    field_idx = np.repeat(np.arange(len(values)), widths)
    # bit position within each field (0 = LSB)
    offsets = np.cumsum(widths) - widths
    bitpos = np.arange(total_bits, dtype=np.int64) - np.repeat(offsets, widths)
    bits = ((values[field_idx] >> bitpos.astype(np.uint64)) & 1).astype(np.uint8)
    packed = np.packbits(bits, bitorder="little")
    return packed, total_bits


def unpack_bits(packed: np.ndarray, total_bits: int) -> np.ndarray:
    """Inverse of the bit-expansion in :func:`pack_varbits` — returns the raw
    0/1 bit array of length ``total_bits``."""
    bits = np.unpackbits(np.asarray(packed, dtype=np.uint8), bitorder="little")
    return bits[:total_bits]


class BitReader:
    """Sequential cursor over a packed bitstream (LSB-first fields)."""

    def __init__(self, packed: np.ndarray, total_bits: int):
        self._bits = unpack_bits(packed, total_bits)
        self.pos = 0

    @property
    def remaining(self) -> int:
        return len(self._bits) - self.pos

    def read(self, width: int) -> int:
        if width == 0:
            return 0
        if self.pos + width > len(self._bits):
            raise EOFError("bitstream exhausted")
        chunk = self._bits[self.pos : self.pos + width]
        self.pos += width
        # LSB-first
        return int((chunk.astype(np.uint64) << np.arange(width, dtype=np.uint64)).sum())
