"""Data pipeline: determinism, sharding, restart-exactness."""
import numpy as np

from repro.data import DataConfig, SyntheticTokenDataset, host_batch_iterator


def test_batches_deterministic_per_step():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=4)
    ds1, ds2 = SyntheticTokenDataset(cfg), SyntheticTokenDataset(cfg)
    for step in (0, 5, 17):
        np.testing.assert_array_equal(ds1.batch(step)["tokens"],
                                      ds2.batch(step)["tokens"])


def test_batches_differ_across_steps_and_shards():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=4)
    ds = SyntheticTokenDataset(cfg)
    assert not np.array_equal(ds.batch(0)["tokens"], ds.batch(1)["tokens"])
    c2 = DataConfig(vocab_size=1000, seq_len=64, global_batch=8,
                    n_shards=2, shard_id=1)
    assert not np.array_equal(ds.batch(0)["tokens"],
                              SyntheticTokenDataset(c2).batch(0)["tokens"])


def test_shard_batch_split():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, n_shards=4)
    ds = SyntheticTokenDataset(cfg)
    assert ds.batch(0)["tokens"].shape == (2, 16)


def test_iterator_resume_matches():
    """Restarting from a cursor reproduces the same stream (the property
    checkpoint/restore relies on)."""
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
    it1 = host_batch_iterator(cfg)
    seq1 = [next(it1) for _ in range(6)]
    it2 = host_batch_iterator(cfg, start_step=3)
    for (s1, b1), (s2, b2) in zip(seq1[3:], it2):
        assert s1 == s2
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_motifs_make_data_learnable():
    """Repeated motifs → bigram statistics far from uniform."""
    cfg = DataConfig(vocab_size=64, seq_len=256, global_batch=8,
                     motif_prob=0.9)
    toks = SyntheticTokenDataset(cfg).batch(0)["tokens"]
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs[(a, b)] = pairs.get((a, b), 0) + 1
    top = max(pairs.values()) / sum(pairs.values())
    assert top > 2.0 / 64 ** 2 * 10   # heavily repeated pairs exist


def test_frontend_prefix_shapes():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2,
                     frontend="vision", frontend_seq=8, d_model=32)
    b = SyntheticTokenDataset(cfg).batch(0)
    assert b["prefix"].shape == (2, 8, 32)
