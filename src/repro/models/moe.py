"""Dense MLP and Mixture-of-Experts with expert parallelism.

MoE dispatch is sort-based + ``lax.ragged_dot`` (active-expert FLOPs
only — no one-hot dispatch einsum, keeping the roofline's useful-FLOPs
ratio honest).  Under a mesh, experts are sharded over the ``model`` axis
via ``shard_map``: tokens (already sharded over ``data``) are processed
against the *local* expert slice and partial outputs are ``psum``-combined
over ``model`` — one all-reduce per MoE layer, the same collective class
as TP, with no data-dependent all-to-all sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
try:                                   # jax >= 0.6 exports it at top level
    from jax import shard_map
except ImportError:                    # jax 0.4.x
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.common import (PackedLinear, act_fn, dense_init,
                                 dense_weight, linear)
from repro.sharding import current_ctx

# router logits + expert stacks consume raw weight arrays (jnp.dot with
# explicit f32 casts, lax.ragged_dot, shard_map operands) rather than a
# single matmul a backend could intercept — packed leaves are decoded
# once per forward here (decode-on-dispatch, docs/DESIGN.md §2)
_PACKABLE_KEYS = ("router", "w_experts_gate", "w_experts_in",
                  "w_experts_out")


def _dense_moe_params(p):
    if not any(isinstance(p.get(k), PackedLinear) for k in _PACKABLE_KEYS):
        return p
    return {k: dense_weight(v) if k in _PACKABLE_KEYS else v
            for k, v in p.items()}


# ---------------------------------------------------------------------------
# dense MLP (SwiGLU-style gate/up/down or plain act(up)·down)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, *, gated: bool = True) -> dict:
    ks = jax.random.split(key, 3)
    p = {"up_proj": dense_init(ks[0], d_model, d_ff),
         "down_proj": dense_init(ks[2], d_ff, d_model)}
    if gated:
        p["gate_proj"] = dense_init(ks[1], d_model, d_ff)
    return p


def mlp_forward(p, x, act: str = "silu"):
    up = linear(x, p["up_proj"])
    if "gate_proj" in p:
        up = act_fn(act)(linear(x, p["gate_proj"])) * up
    else:
        up = act_fn(act)(up)
    return linear(up, p["down_proj"])


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_init(key, cfg) -> dict:
    ks = jax.random.split(key, 5)
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    p = {
        "router": dense_init(ks[0], d, e, scale=0.02),
        "w_experts_gate": jax.vmap(lambda k: dense_init(k, d, f))(
            jax.random.split(ks[1], e)),
        "w_experts_in": jax.vmap(lambda k: dense_init(k, d, f))(
            jax.random.split(ks[2], e)),
        "w_experts_out": jax.vmap(lambda k: dense_init(k, f, d))(
            jax.random.split(ks[3], e)),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def _expert_compute(xs: jax.Array, group_sizes: jax.Array, wg, wi, wo,
                    act: str) -> jax.Array:
    """Grouped SwiGLU over sorted tokens: xs (T, d), experts (E, d, f)."""
    gate = jax.lax.ragged_dot(xs, wg.astype(xs.dtype), group_sizes)
    up = jax.lax.ragged_dot(xs, wi.astype(xs.dtype), group_sizes)
    h = act_fn(act)(gate) * up
    return jax.lax.ragged_dot(h, wo.astype(xs.dtype), group_sizes)


def _moe_local(x2d: jax.Array, p, cfg, n_local: int, expert_offset
               ) -> jax.Array:
    """Token-choice top-k against ``n_local`` experts starting at
    ``expert_offset`` (traced).  x2d (T, d) → (T, d) partial output."""
    t, d = x2d.shape
    k = cfg.moe_top_k
    logits = jnp.dot(x2d.astype(jnp.float32), p["router"].astype(jnp.float32))
    gates, idx = jax.lax.top_k(logits, k)                  # (T, k)
    gates = jax.nn.softmax(gates, axis=-1)

    flat_idx = idx.reshape(-1)                             # (T*k,)
    flat_gate = gates.reshape(-1)
    local_id = flat_idx - expert_offset
    is_local = (local_id >= 0) & (local_id < n_local)
    sort_key = jnp.where(is_local, local_id, n_local)      # remotes last
    order = jnp.argsort(sort_key)
    token_of = order // k                                  # source token
    xs = jnp.take(x2d, token_of, axis=0)                   # (T*k, d)
    group_sizes = jnp.bincount(jnp.where(is_local, local_id, n_local),
                               length=n_local + 1)[:n_local]
    ys = _expert_compute(xs, group_sizes, p["w_experts_gate"],
                         p["w_experts_in"], p["w_experts_out"], cfg.act)
    # zero contributions from remote/padding rows
    in_range = jnp.arange(t * k) < group_sizes.sum()
    ys = jnp.where(in_range[:, None], ys, 0.0)
    ys = ys * jnp.take(flat_gate, order).astype(ys.dtype)[:, None]
    out = jnp.zeros((t, d), ys.dtype).at[token_of].add(ys)
    return out


def _moe_2d(p, x, cfg, ctx):
    """Decode-time MoE with 2-D expert sharding (§Perf optimization).

    Experts shard over ``model`` (E/m each) and every expert's FFN
    hidden dim shards over ``data`` (TP-within-expert), so each chip
    holds E·3·d·f/(m·d_axis) weight bytes and reads ONLY those from HBM
    — zero per-step weight collectives.  The (tiny) decode token batch
    is all-gathered over ``data``; every shard computes its expert/f
    slice for all of its pod's tokens; one psum over (data, model)
    combines both the cross-expert and the f-partial sums (both are
    additive); each data shard keeps its own token rows."""
    b, s, d = x.shape
    mesh = ctx.mesh
    msize, dsize = ctx.axis_size("model"), ctx.axis_size("data")
    e = cfg.n_experts
    n_local = e // msize
    bspec = ctx.batch_spec
    rows = b * s

    def body(x2d, router, wg, wi, wo):
        xg = jax.lax.all_gather(x2d, "data", axis=0, tiled=True)
        offset = jax.lax.axis_index("model") * n_local
        pl_ = {"router": router, "w_experts_gate": wg,
               "w_experts_in": wi, "w_experts_out": wo}
        part = _moe_local(xg, pl_, cfg, n_local, offset)
        full = jax.lax.psum(part, ("data", "model"))
        t_loc = x2d.shape[0]
        start = jax.lax.axis_index("data") * t_loc
        return jax.lax.dynamic_slice(full, (start, 0), (t_loc, d))

    out2d = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None), P(None, None),
                  P("model", None, "data"),      # gate (E, d, f{data})
                  P("model", None, "data"),      # up
                  P("model", "data", None)),     # down (E, f{data}, d)
        out_specs=P(bspec, None),
        check_vma=False,
    )(x.reshape(rows, d), p["router"], p["w_experts_gate"],
      p["w_experts_in"], p["w_experts_out"])
    return out2d.reshape(b, s, d).astype(x.dtype)


def moe_forward(p, x, cfg, mode: str = "train"):
    """x (B, S, d) → (B, S, d).  EP over 'model' when a mesh is active;
    2-D expert sharding for decode when ``cfg.moe_decode_2d``."""
    p = _dense_moe_params(p)
    b, s, d = x.shape
    ctx = current_ctx()
    e = cfg.n_experts

    def run_local(x2d):
        return _moe_local(x2d, p, cfg, e, 0)

    if (cfg.moe_decode_2d and mode == "decode" and ctx is not None
            and ctx.axis_size("model") > 1 and ctx.axis_size("data") > 1
            and e % ctx.axis_size("model") == 0
            and cfg.moe_d_ff % ctx.axis_size("data") == 0):
        out = _moe_2d(p, x, cfg, ctx)
        if "shared" in p:
            out = out + mlp_forward(p["shared"], x, cfg.act)
        return out

    if ctx is None or ctx.axis_size("model") == 1 or e % ctx.axis_size("model"):
        out = run_local(x.reshape(-1, d)).reshape(b, s, d).astype(x.dtype)
    else:
        mesh = ctx.mesh
        msize = ctx.axis_size("model")
        n_local = e // msize
        batch = ctx.batch_spec
        # token rows must divide the batch axes; otherwise replicate
        # (single-sequence decode: B·S == 1)
        if batch is not None:
            baxes = batch if isinstance(batch, tuple) else (batch,)
            total = 1
            for a in baxes:
                total *= ctx.axis_size(a)
            if (b * s) % total:
                batch = None

        def sharded(x2d, router, wg, wi, wo):
            my = jax.lax.axis_index("model")
            pl_ = {"router": router, "w_experts_gate": wg,
                   "w_experts_in": wi, "w_experts_out": wo}
            part = _moe_local(x2d, pl_, cfg, n_local, my * n_local)
            return jax.lax.psum(part, "model")

        specs_w = (P(None, None), P("model", None, None),
                   P("model", None, None), P("model", None, None))
        out2d = shard_map(
            sharded, mesh=mesh,
            in_specs=(P(batch, None),) + specs_w,
            out_specs=P(batch, None),
            check_vma=False,
        )(x.reshape(-1, d), p["router"], p["w_experts_gate"],
          p["w_experts_in"], p["w_experts_out"])
        out = out2d.reshape(b, s, d).astype(x.dtype)

    if "shared" in p:
        out = out + mlp_forward(p["shared"], x, cfg.act)
    return out
