"""Baseline accelerators the paper compares against (SCNN [1], UCNN [5]).

The paper's evaluation is relative — we implement both baselines'
compression schemes and dataflows so every CoDR claim has an in-repo
counterpart."""
from repro.core.baselines.scnn import scnn_compress_bits
from repro.core.baselines.ucnn import ucnn_compress_bits

__all__ = ["scnn_compress_bits", "ucnn_compress_bits"]
