"""The ``sharded`` tile-parallel backend: mesh helpers, bit-for-bit
parity vs ``tiled`` on whatever mesh the host exposes, and a forced
2-device host-platform mesh in a subprocess.

CI runs this file twice: once inside the tier-1 suite (1 device →
1-element-mesh fallback) and once under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (real
partitioning on fake devices).  The subprocess test forces 2 devices
regardless, so the multi-device path is exercised even in a plain
single-device run.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import repro.api as codr
from repro.sharding import rules


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _sparse(rng, shape, density=0.5, scale=0.5):
    w = rng.normal(size=shape).astype(np.float32) * scale
    w[rng.random(shape) > density] = 0
    return w


def _conv_linear_spec(rng, m0=10, m1=6, n_out=5, hw=9):
    """conv → conv → linear; m0=10 with t_m=4 → ragged last tile."""
    w0 = _sparse(rng, (m0, 3, 3, 3))
    w1 = _sparse(rng, (m1, m0, 3, 3))
    feat = m1 * (hw - 4) ** 2
    wl = _sparse(rng, (n_out, feat))
    b0 = rng.normal(size=m0).astype(np.float32)
    return codr.ModelSpec([
        codr.LayerSpec.conv(w0, b0, activation="relu", name="c0"),
        codr.LayerSpec.conv(w1, activation="relu", name="c1"),
        codr.LayerSpec.dense(wl, name="fc"),
    ])


# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------

def test_pad_to_multiple():
    assert rules.pad_to_multiple(0, 4) == 4     # floor: at least one block
    assert rules.pad_to_multiple(1, 4) == 4
    assert rules.pad_to_multiple(4, 4) == 4
    assert rules.pad_to_multiple(5, 4) == 8
    assert rules.pad_to_multiple(7, 1) == 7


def test_tile_mesh_axis_and_size():
    mesh = rules.tile_mesh()
    assert mesh.axis_names == (rules.ENGINE_TILE_AXIS,)
    assert mesh.shape[rules.ENGINE_TILE_AXIS] == len(jax.devices())
    sub = rules.tile_mesh(jax.devices()[:1])
    assert sub.shape[rules.ENGINE_TILE_AXIS] == 1


def test_shard_leading_pads_and_commits(rng):
    mesh = rules.tile_mesh()
    d = mesh.shape[rules.ENGINE_TILE_AXIS]
    x = rng.normal(size=(2 * d + 1, 3)).astype(np.float32)
    y = rules.shard_leading(x, mesh)
    assert y.shape[0] == rules.pad_to_multiple(x.shape[0], d)
    got = np.asarray(y)
    np.testing.assert_array_equal(got[: x.shape[0]], x)
    assert (got[x.shape[0]:] == 0).all()        # zero pad rows
    assert y.sharding.mesh.shape[rules.ENGINE_TILE_AXIS] == d


# ---------------------------------------------------------------------------
# parity: sharded vs tiled, bit for bit
# ---------------------------------------------------------------------------

def test_sharded_registered_with_caps():
    assert "sharded" in codr.available_backends()
    be = codr.get_backend("sharded")
    assert be.caps.supports_stride(3)           # any stride
    assert {"conv", "linear"} <= set(be.caps.native_kinds)


def test_sharded_matches_tiled_bit_for_bit(rng):
    compiled = codr.compile(_conv_linear_spec(rng),
                            codr.EncodeConfig(n_unique=16),
                            backend="sharded")
    x = rng.normal(size=(3, 9, 9, 3)).astype(np.float32)
    y_sh = np.asarray(compiled.run(x))
    y_ti = np.asarray(compiled.run(x, backend="tiled"))
    np.testing.assert_array_equal(y_sh, y_ti)
    # repeat requests reuse the cached sharded chain and stay identical
    np.testing.assert_array_equal(np.asarray(compiled.run(x)), y_ti)


@pytest.mark.parametrize("stride", [1, 2])
def test_sharded_single_layer_steps_match_layer_forward(stride, rng):
    w = _sparse(rng, (10, 3, 3, 3))             # ragged: 10 rows, t_m=4
    spec = codr.ModelSpec([codr.LayerSpec.conv(
        w, stride=stride, activation="relu", name="c0")])
    compiled = codr.compile(spec, codr.EncodeConfig())
    layer = compiled.model.layers[0]
    be = codr.get_backend("sharded")
    x = rng.normal(size=(2, 11, 11, 3)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(be.conv(layer, x)),
                                  np.asarray(layer(x)))


def test_sharded_linear_only_model(rng):
    wl = _sparse(rng, (7, 33))                  # ragged vs any device pad
    spec = codr.ModelSpec([codr.LayerSpec.dense(wl, name="fc")])
    compiled = codr.compile(spec, codr.EncodeConfig(), backend="sharded")
    x = rng.normal(size=(4, 33)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(compiled.run(x)),
                                  np.asarray(compiled.run(x,
                                                          backend="tiled")))


def test_sharded_explicit_mesh_and_custom_name(rng):
    """A ShardedBackend pinned to a device subset registers under its
    own name and dispatches like any other backend."""
    from repro.core.backends import ShardedBackend
    mesh = rules.tile_mesh(jax.devices()[:1])
    be = codr.register(ShardedBackend(mesh, name="sharded_one"),
                       overwrite=True)
    assert be.n_devices == 1
    compiled = codr.compile(_conv_linear_spec(rng), codr.EncodeConfig(),
                            backend="sharded_one")
    x = rng.normal(size=(2, 9, 9, 3)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(compiled.run(x)),
                                  np.asarray(compiled.run(x,
                                                          backend="tiled")))


def test_register_your_own_backend_example(rng):
    """The worked example from the ``repro.core.backends`` module
    docstring, executed: custom caps gate compile, ``finish`` reproduces
    the epilogue bit-for-bit."""

    class DenseDemoBackend(codr.Backend):
        name = "dense_demo_test"
        caps = codr.BackendCaps(max_stride=1,
                                description="toy dense executor")

        def conv(self, layer, x):
            t = layer.tiles_device
            w = t.reshape(-1, *t.shape[2:])[: layer.code.shape[0]]
            y = jax.lax.conv_general_dilated(
                x, w, window_strides=(1, 1), padding="VALID",
                dimension_numbers=("NHWC", "OIHW", "NHWC"))
            return self.finish(layer, y * layer.code.scale)

    codr.register(DenseDemoBackend(), overwrite=True)
    w = _sparse(rng, (8, 3, 3, 3))
    b = rng.normal(size=8).astype(np.float32)
    spec = codr.ModelSpec([codr.LayerSpec.conv(w, b, activation="relu",
                                               name="c0")])
    compiled = codr.compile(spec, codr.EncodeConfig(),
                            backend="dense_demo_test")
    x = rng.normal(size=(2, 9, 9, 3)).astype(np.float32)
    # eager op-by-op vs the tiled backend's jit-fused chain: same math,
    # different fusion → last-bit rounding may differ
    np.testing.assert_allclose(np.asarray(compiled.run(x)),
                               np.asarray(compiled.run(x, backend="tiled")),
                               rtol=1e-4, atol=1e-5)
    # the declared stride ceiling is enforced at compile time
    spec2 = codr.ModelSpec([codr.LayerSpec.conv(w, stride=2, name="c0")])
    with pytest.raises(ValueError, match="stride"):
        codr.compile(spec2, backend="dense_demo_test")


# ---------------------------------------------------------------------------
# forced multi-device host mesh (subprocess — XLA_FLAGS must be set
# before jax initializes, so it cannot run in this process)
# ---------------------------------------------------------------------------

_FORCED_SCRIPT = """
import numpy as np, jax
import repro.api as codr
assert len(jax.devices()) == 2, jax.devices()
rng = np.random.default_rng(0)
w0 = rng.normal(size=(10, 3, 3, 3)).astype(np.float32)
w0[rng.random(w0.shape) > 0.5] = 0
wl = rng.normal(size=(5, 10 * 7 * 7)).astype(np.float32)
spec = codr.ModelSpec([
    codr.LayerSpec.conv(w0, rng.normal(size=10).astype(np.float32),
                        activation="relu", name="c0"),
    codr.LayerSpec.dense(wl, name="fc"),
])
compiled = codr.compile(spec, codr.EncodeConfig(n_unique=16),
                        backend="sharded")
x = rng.normal(size=(3, 9, 9, 3)).astype(np.float32)
y_sh = np.asarray(compiled.run(x))
y_ti = np.asarray(compiled.run(x, backend="tiled"))
assert np.array_equal(y_sh, y_ti), abs(y_sh - y_ti).max()
print("FORCED_MESH_PARITY_OK")
"""


def test_sharded_parity_on_forced_two_device_mesh():
    env = dict(os.environ)
    # drop any inherited device-count forcing (the outer suite may run
    # under one) — the last occurrence wins inside XLA
    inherited = [f for f in env.get("XLA_FLAGS", "").split()
                 if "xla_force_host_platform_device_count" not in f]
    env["XLA_FLAGS"] = " ".join(
        inherited + ["--xla_force_host_platform_device_count=2"])
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    old = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src + (os.pathsep + old if old else "")
    res = subprocess.run([sys.executable, "-c", _FORCED_SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         env=env)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "FORCED_MESH_PARITY_OK" in res.stdout
