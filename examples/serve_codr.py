"""Serving with CoDR-compressed weights (the paper's technique as a
first-class serving feature): batched prefill + greedy decode, before and
after offline UCR+RLE compression, with measured compression ratios and
the TPU-target HBM traffic model.

    PYTHONPATH=src python examples/serve_codr.py --arch qwen2.5-3b
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if "--codr" not in sys.argv:
        sys.argv.append("--codr")
    main()
