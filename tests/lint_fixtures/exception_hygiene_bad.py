"""codrlint fixture: silent swallows of broad exception classes."""


def swallow():
    try:
        risky()                     # noqa: F821
    except Exception:
        pass                        # silent swallow


def bare():
    try:
        risky()                     # noqa: F821
    except:                         # noqa: E722 — bare except
        return None


def tuple_swallow():
    try:
        risky()                     # noqa: F821
    except (ValueError, BaseException):
        return -1                   # swallow via tuple member
