"""codrlint fixture: guarded attributes touched without the lock."""
import threading


class Loop:
    def __init__(self):
        self._cv = threading.Condition()
        self._queue = []            # guarded-by: _cv
        self.count = 0              # guarded-by: _cv

    def bad_read(self):
        return len(self._queue)     # no lock held

    def bad_partial(self):
        with self._cv:
            self._queue.append(1)   # fine here
        self.count += 1             # lock already released


class Child(Loop):
    """Inherits the guarded set from Loop (cross-class resolution)."""

    def bad_inherited(self):
        self._queue.clear()         # guard inherited from Loop
