"""Per-arch smoke tests: a REDUCED config of every assigned architecture
runs one forward/train step on CPU — output shapes + no NaNs.  The full
configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.models import get_model

B, S = 2, 32


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.family == "encdec" or cfg.frontend:
        batch["prefix"] = jax.random.normal(key, (B, cfg.frontend_seq,
                                                  cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch, key):
    cfg = smoke_variant(get_config(arch))
    api = get_model(cfg)
    params = api.init_params(key, cfg)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: api.train_loss(p, batch, cfg))(params)
    assert np.isfinite(float(loss)), arch
    # rough sanity: ~uniform prediction at init
    assert float(loss) < np.log(cfg.vocab_size) * 2
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.isfinite(np.asarray(g)).all() for g in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_serve_step(arch, key):
    cfg = smoke_variant(get_config(arch))
    api = get_model(cfg)
    params = api.init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, cache = api.prefill(params, batch, cfg)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    cache = api.init_cache(cfg, B, S)
    tok = batch["tokens"][:, 0]
    logits, cache = api.decode_step(params, cache, tok, jnp.int32(0), cfg)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen3-32b", "deepseek-v2-236b",
                                  "jamba-v0.1-52b", "xlstm-350m"])
def test_decode_matches_prefill_f32(arch, key):
    """Incremental decode must reproduce the parallel forward exactly
    (f32; bf16 differs only by rounding — verified manually)."""
    import repro.models.common as common
    import repro.models.lm as lm_mod
    old = common.DEFAULT_DTYPE
    common.DEFAULT_DTYPE = jnp.float32
    lm_mod.DEFAULT_DTYPE = jnp.float32
    try:
        cfg = smoke_variant(get_config(arch))
        cfg = dataclasses.replace(cfg, remat=False)
        api = get_model(cfg)
        params = api.init_params(key, cfg)
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        lg_ref, _ = api.prefill(params, {"tokens": tokens}, cfg)
        cache = api.init_cache(cfg, B, S, dtype=jnp.float32)
        lg = None
        for t in range(S):
            lg, cache = api.decode_step(params, cache, tokens[:, t],
                                        jnp.int32(t), cfg)
        rel = (float(jnp.abs(lg - lg_ref[:, 0]).max())
               / max(float(jnp.abs(lg_ref).max()), 1e-6))
        assert rel < 1e-4, (arch, rel)
    finally:
        common.DEFAULT_DTYPE = old
        lm_mod.DEFAULT_DTYPE = old


def test_moe_routing_is_topk(key):
    """Every token's MoE output uses exactly top-k experts: perturbing a
    non-selected expert's weights must not change the output."""
    from repro.models import moe as moe_mod
    cfg = smoke_variant(get_config("granite-moe-1b-a400m"))
    p = moe_mod.moe_init(key, cfg)
    x = jax.random.normal(key, (1, 8, cfg.d_model), jnp.float32)
    out1 = moe_mod.moe_forward(p, x, cfg)
    logits = jnp.dot(x.reshape(-1, cfg.d_model),
                     p["router"].astype(jnp.float32))
    _, used = jax.lax.top_k(logits, cfg.moe_top_k)
    unused = [e for e in range(cfg.n_experts)
              if e not in np.unique(np.asarray(used))]
    if unused:
        p2 = dict(p)
        p2["w_experts_in"] = p["w_experts_in"].at[unused[0]].set(123.0)
        out2 = moe_mod.moe_forward(p2, x, cfg)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


def test_flash_attention_matches_naive(key):
    from repro.models.attention import flash_attention
    b, s, h, d = 2, 64, 4, 16
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, 2, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 2, d))
    out = flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    # naive reference
    qg = q.reshape(b, s, 2, 2, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / np.sqrt(d)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    ref = jnp.einsum("bhgqk,bkhd->bqhgd", jax.nn.softmax(scores, -1), v)
    ref = ref.reshape(b, s, h, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_mamba_chunked_scan_matches_sequential(key):
    from repro.models.ssm import _ssm_scan_chunked
    b, s, d, n = 2, 32, 4, 3
    a = jax.random.uniform(key, (b, s, d, n), minval=0.5, maxval=0.99)
    bb = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d, n))
    h0 = jnp.zeros((b, d, n))
    hs = _ssm_scan_chunked(a, bb, h0, chunk=8)
    # sequential reference
    h = h0
    outs = []
    for t in range(s):
        h = a[:, t] * h + bb[:, t]
        outs.append(h)
    ref = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
