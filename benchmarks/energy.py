"""Paper Fig. 8 — energy consumption analysis across the three designs
(DRAM / SRAM / RF / ALU / crossbar breakdown; paper headline: CoDR
3.76× vs UCNN, 6.84× vs SCNN at equal 2.85 mm²)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import BASE_DENSITY, Timer, csv_line, \
    make_weights, sampled_layer_vectors
from repro.configs.paper_cnns import PAPER_CNNS
from repro.core import cost_model, dataflow, rle
from repro.core.baselines.scnn import scnn_compress_bits
from repro.core.baselines.ucnn import ucnn_vector_bits
from repro.core.dataflow import CODR_TILING, SCNN_TILING, UCNN_TILING

SWEEPS = [("U16", 1.0, 16), ("orig", 1.0, 256), ("D0.4", 0.4, 256)]


def model_energy(model: str, density: float, n_unique: int, rng) -> dict:
    briefs = {}
    for name in ("CoDR", "UCNN", "SCNN"):
        briefs[name] = dict(dram=0.0, sram=0.0, rf=0.0, alu=0.0, xbar=0.0,
                            total=0.0)
    for shape in PAPER_CNNS[model]:
        q = make_weights((shape.m, shape.n, shape.rk, shape.ck),
                         density=density * BASE_DENSITY[model],
                         n_unique=n_unique, rng=rng)
        vecs, scale = sampled_layer_vectors(q, CODR_TILING.t_m,
                                            CODR_TILING.t_n)
        codr_bits = scale * rle.layer_bits_size_only(
            vecs, CODR_TILING.t_m * shape.rk * shape.ck)
        ucnn_bits = scale * sum(ucnn_vector_bits(u) for u in vecs)
        nu = scale * sum(len(u.unique_vals) for u in vecs)
        nn = scale * sum(u.n_nonzero for u in vecs)
        accs = {
            "CoDR": dataflow.codr_accesses(shape, CODR_TILING, codr_bits,
                                           nu, nn),
            "UCNN": dataflow.ucnn_accesses(shape, UCNN_TILING, ucnn_bits,
                                           nu, nn),
            "SCNN": dataflow.scnn_accesses(shape, SCNN_TILING,
                                           float(scnn_compress_bits(q)),
                                           nu, nn),
        }
        for name, acc in accs.items():
            e = cost_model.energy(acc)
            b = briefs[name]
            b["dram"] += e.dram_uj
            b["sram"] += e.sram_uj
            b["rf"] += e.rf_uj
            b["alu"] += e.alu_uj
            b["xbar"] += e.crossbar_uj
            b["total"] += e.total_uj
    return briefs


def main(print_fn=print) -> list[str]:
    rng = np.random.default_rng(2)
    lines = []
    for model in PAPER_CNNS:
        for tag, density, n_unique in SWEEPS:
            with Timer() as t:
                b = model_energy(model, density, n_unique, rng)
            x_ucnn = b["UCNN"]["total"] / b["CoDR"]["total"]
            x_scnn = b["SCNN"]["total"] / b["CoDR"]["total"]
            alu_frac = b["CoDR"]["alu"] / b["CoDR"]["total"]
            name = f"fig8_energy/{model}/{tag}"
            derived = (f"x_ucnn={x_ucnn:.2f}(paper:3.76)"
                       f";x_scnn={x_scnn:.2f}(paper:6.84)"
                       f";codr_total_uj={b['CoDR']['total']:.0f}"
                       f";codr_alu_frac={alu_frac:.2f}(paper:0.42)")
            lines.append(csv_line(name, t.dt * 1e6, derived))
            print_fn(lines[-1])
    return lines


if __name__ == "__main__":
    main()
