"""repro.tune: per-layer autotuner, plan-aware compile, eval harness.

Deterministic tests (no hypothesis dependency — the property-based
variants live in ``tests/test_tune_props.py``; the three properties get
fixed-seed twins here so tier-1 exercises the same invariants without
the optional dependency).
"""
import dataclasses
import itertools

import numpy as np
import pytest

import repro.api as codr
from repro import tune
from repro.core import cost_model, dataflow, rle, ucr
from repro.core.dataflow import CODR_TILING, ConvShape
from repro.core.serving import codr_report

HW = (20, 20)


@pytest.fixture(scope="module")
def spec():
    return codr.ModelSpec.from_paper_cnn(
        "vgg16", n_conv=2, n_out=10, ri=HW[0], ci=HW[1], density=0.4,
        rng=np.random.default_rng(0))


@pytest.fixture(scope="module")
def grid():
    # exact scoring: predicted bits/SRAM must equal measured
    return tune.TuneGrid(max_vectors=None)


@pytest.fixture(scope="module")
def budget():
    return tune.TuneBudget(max_rel_err=0.03)


@pytest.fixture(scope="module")
def plan(spec, grid, budget):
    return tune.tune_spec(spec, HW, budget=budget, grid=grid)


@pytest.fixture(scope="module")
def table(spec, grid):
    return tune.layer_candidate_table(spec, HW, grid=grid)


@pytest.fixture(scope="module")
def global_best(table, budget, grid):
    return tune.best_global_config(table, budget=budget, grid=grid)


@pytest.fixture(scope="module")
def compiled_pair(spec, plan, global_best):
    gcfg, _ = global_best
    return codr.compile(spec, plan=plan), codr.compile(spec, gcfg)


# ---------------------------------------------------------------------------
# the acceptance criterion: tuned plan strictly beats the best global
# config on predicted SRAM and measured bits/weight at equal agreement
# ---------------------------------------------------------------------------

def test_tuned_plan_strictly_dominates_best_global(spec, plan, global_best,
                                                   compiled_pair):
    gcfg, gpred = global_best
    tuned, baseline = compiled_pair
    assert plan.predicted_total_sram() < gpred["sram"]
    assert tuned.bits_per_weight() < baseline.bits_per_weight()
    x = tune.eval_batch(spec, HW, batch=32, seed=0)
    q_tuned = tune.cnn_quality(tuned, x)
    q_global = tune.cnn_quality(baseline, x)
    assert q_tuned["top1_match"] >= q_global["top1_match"]


def test_predicted_equals_measured_under_exact_grid(plan, compiled_pair):
    """Unsampled scoring: the plan's predicted bits and SRAM are the
    measured numbers, not estimates."""
    tuned, _ = compiled_pair
    assert plan.predicted_bits_per_weight() == \
        pytest.approx(tuned.bits_per_weight(), rel=1e-12)
    measured = sum(a.total_sram for _, a in
                   tuned.sram_report(HW, per_layer_tiling=True))
    assert plan.predicted_total_sram() == pytest.approx(measured, rel=1e-12)


def test_best_global_totals_match_candidate_table(table, budget, grid,
                                                  global_best):
    """Regression: the global scorer's totals are the per-layer sums for
    its chosen config (it once summed one layer three times)."""
    gcfg, gpred = global_best
    expect_sram = expect_bits = 0.0
    for cands in table.values():
        tm = gcfg.t_m if cands[0].kind == "conv" else gcfg.t_m_linear
        match = [c for c in cands if c.n_unique == gcfg.n_unique
                 and c.t_m == tm and c.rle_params == gcfg.rle_params]
        assert len(match) == 1
        expect_sram += match[0].sram
        expect_bits += match[0].bits
    assert gpred["sram"] == pytest.approx(expect_sram)
    assert gpred["bits"] == pytest.approx(expect_bits)


def test_per_layer_optimum_never_worse_than_any_global(plan, global_best):
    """The plan relaxes the global search's single-config constraint, so
    its predicted total can never exceed the best global's."""
    _, gpred = global_best
    assert plan.predicted_total_sram() <= gpred["sram"]
    assert plan.predicted_total_bits() <= gpred["bits"]


# ---------------------------------------------------------------------------
# plan-aware compile: the degenerate plan IS the global-config path
# ---------------------------------------------------------------------------

def test_empty_plan_bit_identical_to_global_compile(spec):
    cfg = codr.EncodeConfig(n_unique=32)
    a = codr.compile(spec, cfg)
    b = codr.compile(spec, cfg, plan=tune.TunePlan())
    assert a.total_bits() == b.total_bits()
    x = tune.eval_batch(spec, HW, batch=4, seed=1)
    np.testing.assert_array_equal(np.asarray(a.run(x)),
                                  np.asarray(b.run(x)))


def test_one_entry_plan_matches_explicit_config(spec):
    """A plan naming every layer with one shared config == passing that
    config globally."""
    cfg = codr.EncodeConfig(n_unique=32, t_m=8)
    as_dict = {ls.name: cfg for ls in spec.layers}
    a = codr.compile(spec, cfg)
    b = codr.compile(spec, plan=as_dict)      # plain-dict plan duck type
    assert a.total_bits() == b.total_bits()
    x = tune.eval_batch(spec, HW, batch=4, seed=1)
    np.testing.assert_array_equal(np.asarray(a.run(x)),
                                  np.asarray(b.run(x)))


def test_plan_entry_type_error(spec):
    with pytest.raises(TypeError, match="must be an EncodeConfig"):
        codr.compile(spec, plan={spec.layers[0].name: 32})


def test_layer_table_shows_plan_and_effective_tiles(compiled_pair, plan):
    tuned, _ = compiled_pair
    out = tuned.layer_table(HW)
    for name in plan.layers:
        assert name in out
    fc = next(line for line in out.splitlines()
              if line.startswith("fc"))
    # t_m_linear clamps to the 10 output features: the table must show
    # the EFFECTIVE tile, not the requested one
    assert fc.split()[3] == "10"
    assert "pred b/w" in out and "pred sram" in out and "total" in out


def test_layer_table_without_plan_or_hw(spec):
    out = codr.compile(spec, codr.EncodeConfig(n_unique=16)).layer_table()
    assert "-" in out                      # no plan, no sram: dash columns


# ---------------------------------------------------------------------------
# effective-tile stats (the t_m_linear silent-clamp fix)
# ---------------------------------------------------------------------------

def test_linear_stats_record_effective_tile(spec):
    cfg = codr.EncodeConfig(n_unique=16, t_m_linear=512)
    compiled = codr.compile(spec, cfg)
    by_name = {st.name: st for st in compiled.stats()}
    assert by_name["fc"].t_m == 10          # clamped to out_features
    assert by_name["conv0"].t_m == cfg.t_m
    assert by_name["fc"].n_unique_budget == 16


# ---------------------------------------------------------------------------
# plan artifact: serialization + cache
# ---------------------------------------------------------------------------

def test_plan_json_roundtrip(plan, tmp_path):
    p = tmp_path / "plan.json"
    plan.save(str(p))
    loaded = tune.TunePlan.load(str(p))
    assert loaded.to_json() == plan.to_json()
    for name, lp in plan.layers.items():
        assert loaded.config_for(name) == lp.config
    assert loaded.budget == plan.budget


def test_fingerprint_cache_hits_on_retune(spec, grid, budget):
    tune.clear_cache()
    p1 = tune.tune_spec(spec, HW, budget=budget, grid=grid)
    assert tune.cache_stats() == {"hits": 0, "misses": len(spec.layers)}
    assert not any(lp.from_cache for lp in p1.layers.values())
    p2 = tune.tune_spec(spec, HW, budget=budget, grid=grid)
    assert tune.cache_stats()["hits"] == len(spec.layers)
    assert all(lp.from_cache for lp in p2.layers.values())
    assert p1.to_json()["layers"].keys() == p2.to_json()["layers"].keys()
    assert p2.meta["cache_hits"] == len(spec.layers)


def test_fingerprint_sensitive_to_weights_and_geometry(rng):
    w = rng.normal(size=(8, 4, 3, 3)).astype(np.float32)
    base = tune.layer_fingerprint(w, "conv")
    assert tune.layer_fingerprint(w, "conv") == base        # deterministic
    assert tune.layer_fingerprint(w, "linear") != base
    assert tune.layer_fingerprint(w, "conv", stride=2) != base
    assert tune.layer_fingerprint(w * 2.0, "conv") != base


# ---------------------------------------------------------------------------
# budgets
# ---------------------------------------------------------------------------

def test_bits_target_walks_below_unconstrained(spec, grid, table):
    free = tune.tune_spec(spec, HW, grid=grid,
                          budget=tune.TuneBudget(max_rel_err=0.03))
    target = free.predicted_bits_per_weight() * 0.9
    squeezed = tune.tune_spec(
        spec, HW, grid=grid,
        budget=tune.TuneBudget(max_rel_err=None,
                               target_bits_per_weight=target,
                               objective="bits"))
    assert squeezed.predicted_bits_per_weight() <= target
    assert squeezed.meta["meets_budget"]


def test_unreachable_sram_target_reported(spec, grid):
    plan = tune.tune_spec(
        spec, HW, grid=grid,
        budget=tune.TuneBudget(max_rel_err=None, max_sram_accesses=1.0))
    assert not plan.meta["meets_budget"]


def test_budget_validation():
    with pytest.raises(ValueError, match="objective"):
        tune.TuneBudget(objective="latency")
    with pytest.raises(ValueError, match="max_rel_err"):
        tune.TuneBudget(max_rel_err=-0.1)
    with pytest.raises(ValueError, match="target_bits_per_weight"):
        tune.TuneBudget(target_bits_per_weight=0)


# ---------------------------------------------------------------------------
# EncodeConfig validation (the satellite: clear messages, no silent junk)
# ---------------------------------------------------------------------------

def test_encode_config_tile_validation():
    with pytest.raises(ValueError, match="t_m must be >= 1"):
        codr.EncodeConfig(t_m=0)
    with pytest.raises(ValueError, match="t_n must be an integer"):
        codr.EncodeConfig(t_n=2.5)
    with pytest.raises(ValueError, match="t_m_linear must be an integer"):
        codr.EncodeConfig(t_m_linear=True)
    with pytest.raises(ValueError, match="n_unique must be in"):
        codr.EncodeConfig(n_unique=2)


def test_encode_config_rle_params_validation():
    with pytest.raises(ValueError, match=r"\(delta, rep, index\) triple"):
        codr.EncodeConfig(rle_params=(3, 3))
    with pytest.raises(ValueError, match="rep bit-length"):
        codr.EncodeConfig(rle_params=(3, 0, 3))
    with pytest.raises(ValueError, match="index bit-length"):
        codr.EncodeConfig(rle_params=(3, 3, 17))
    cfg = codr.EncodeConfig(rle_params=(np.int64(3), 4, 5))
    assert cfg.rle_params == (3, 4, 5)
    assert all(isinstance(b, int) for b in cfg.rle_params)


# ---------------------------------------------------------------------------
# eval harness
# ---------------------------------------------------------------------------

def test_pareto_curve_quality_improves_with_u(spec, plan):
    pts = tune.pareto_curve(spec, HW, n_uniques=(8, 256),
                            plans={"tuned": plan}, batch=8)
    by_tag = {p["tag"]: p for p in pts}
    assert set(by_tag) == {"U8", "U256", "tuned"}
    assert by_tag["U8"]["bits_per_weight"] < by_tag["U256"]["bits_per_weight"]
    assert by_tag["U8"]["rel_logit_err"] > by_tag["U256"]["rel_logit_err"]
    for p in pts:
        assert {"top1_match", "sram_accesses", "config"} <= set(p)


def test_run_tune_check_passes():
    from repro.launch.tune import check_result, run_tune
    result = run_tune(verbose=False)       # CI smoke defaults
    check_result(result)                   # raises on regression


# ---------------------------------------------------------------------------
# transformer lane: per-leaf plans through compile_params
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_setup(key):
    from repro.configs import get_config, smoke_variant
    from repro.models import get_model
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    api = get_model(cfg)
    return cfg, api, api.init_params(key, cfg)


def test_compile_params_empty_plan_bit_identical(lm_setup, key):
    import jax
    cfg, api, params = lm_setup
    ecfg = codr.EncodeConfig(n_unique=16)
    a = codr.compile_params(params, ecfg, accounting=False)
    b = codr.compile_params(params, ecfg, accounting=False,
                            plan=tune.TunePlan())
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    la, _ = api.prefill(a.params, {"tokens": tokens}, cfg)
    lb, _ = api.prefill(b.params, {"tokens": tokens}, cfg)
    np.testing.assert_array_equal(np.asarray(la, np.float32),
                                  np.asarray(lb, np.float32))
    assert a.bits_per_weight() == b.bits_per_weight()


def test_tune_params_per_leaf_plan_shrinks_hbm(lm_setup):
    _, _, params = lm_setup
    plan = tune.tune_params(params,
                            budget=tune.TuneBudget(max_rel_err=0.2),
                            n_uniques=(4, 8, 16, 32))
    assert plan.layers                      # found packable projections
    assert all(lp.kind == "linear" for lp in plan.layers.values())
    us = {lp.config.n_unique for lp in plan.layers.values()}
    max_u = max(us)
    tuned = codr.compile_params(params, plan=plan,
                                config=codr.EncodeConfig(n_unique=max_u))
    flat = codr.compile_params(params,
                               codr.EncodeConfig(n_unique=max_u))
    assert tuned.hbm_bytes() <= flat.hbm_bytes()
    if len(us) > 1:                         # heterogeneous U picked
        assert tuned.hbm_bytes() < flat.hbm_bytes()
    report = codr_report(tuned.reports, per_tensor=True)
    assert "tensor" in report
    assert any(p in report for p in tuned.packed_paths)


def test_transformer_quality_smoke():
    q = tune.transformer_quality("qwen2.5-3b", batch=1, prompt_len=4)
    assert q["n_packed"] > 0
    assert 0.0 <= q["argmax_agreement"] <= 1.0
    assert q["bits_per_weight"] < 16.0


# ---------------------------------------------------------------------------
# deterministic twins of the tests/test_tune_props.py properties
# ---------------------------------------------------------------------------

def test_codr_accesses_monotone_in_tile_counts_det():
    shape = ConvShape(64, 16, 3, 3, 20, 20)
    bits, nu, nn = 5e4, 400.0, 3000.0
    prev = None
    for t_m in (1, 2, 4, 8, 16):
        acc = dataflow.codr_accesses(shape, dataflow.codr_tiling(t_m),
                                     bits, nu, nn)
        if prev is not None:               # larger t_m -> fewer m-groups
            assert acc.input_sram <= prev.input_sram
            assert acc.output_sram == prev.output_sram
        prev = acc
    # smaller spatial tiles -> more weight re-streams, never fewer
    small = dataclasses.replace(CODR_TILING, t_ro=4, t_co=4)
    a_big = dataflow.codr_accesses(shape, CODR_TILING, bits, nu, nn)
    a_small = dataflow.codr_accesses(shape, small, bits, nu, nn)
    assert a_small.weight_sram_rows >= a_big.weight_sram_rows


def test_energy_total_is_sum_of_components_det():
    shape = ConvShape(32, 8, 3, 3, 12, 12)
    acc = dataflow.codr_accesses(shape, CODR_TILING, 1e4, 100.0, 500.0)
    e = cost_model.energy(acc)
    assert e.total_uj == pytest.approx(
        e.dram_uj + e.sram_uj + e.rf_uj + e.alu_uj + e.crossbar_uj)


def test_rle_search_never_beats_exhaustive_det(rng):
    q = (rng.integers(-8, 8, size=(8, 3, 3, 3)) * 2).astype(np.int8)
    vecs = ucr.layer_ucr_vectors(q, t_m=4, t_n=2)
    vector_len = 4 * 9
    searched = rle.layer_bits_size_only(vecs, vector_len)
    oracle = min(
        rle.layer_bits_size_only(vecs, vector_len, params=p)
        for p in itertools.product(rle.PARAM_SEARCH_SPACE, repeat=3))
    assert oracle <= searched
    # and the search is near-optimal: within one escape header per stream
    assert searched <= oracle + 3 * rle.FULL_BITS
