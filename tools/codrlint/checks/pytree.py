"""pytree-registration: leaf classes that cross jit must be registered.

A dataclass whose instances ride inside jitted computations
(``PackedWeight`` packs inside ``prefill``/``decode_step`` graphs,
``PagedKV`` pools inside the pooled decode step) silently degrades to
an opaque leaf — or hard-errors — the first time it crosses a
``jax.jit`` boundary unless it is pytree-registered.  PR 5 and PR 9
established the convention; this checker enforces it:

**Required** classes are (a) the known jit-crossing leaves
(:data:`REQUIRED_NAMES`), and (b) any ``@dataclass`` whose fields
include a ``jax.Array`` / ``jnp.ndarray`` annotation or a field typed
as another required class (transitively — ``PackedLinear`` is required
because its ``weight`` field is a ``PackedWeight``).

**Registered** means, anywhere in the linted tree: a
``jax.tree_util.register_pytree_node(Cls, ...)`` /
``register_dataclass(Cls, ...)`` call, or the
``@jax.tree_util.register_pytree_node_class`` decorator, or defining
``tree_flatten`` + ``tree_unflatten`` behind that decorator.

Host-side containers deliberately kept OUT of jit (e.g. ``PagePool``,
whose free-list must never be traced) are exempt by not having array
fields; a new jit-crossing class with array fields must either register
or carry a ``# codrlint: disable=pytree-registration`` with rationale.
"""
from __future__ import annotations

import ast

from tools.codrlint.core import (Checker, Finding, Project, dotted_name,
                                 register_checker)

REQUIRED_NAMES = {"PackedWeight", "PackedLinear", "PackedEmbedding",
                  "PagedKV"}
ARRAY_ANNOTATIONS = {"jax.Array", "jnp.ndarray", "jax.numpy.ndarray",
                     "Array"}
REGISTER_CALLS = {"register_pytree_node", "register_dataclass",
                  "register_pytree_node_class",
                  "register_pytree_with_keys_class"}


def _is_dataclass(cls: ast.ClassDef) -> bool:
    for d in cls.decorator_list:
        target = d.func if isinstance(d, ast.Call) else d
        if dotted_name(target).split(".")[-1] == "dataclass":
            return True
    return False


def _field_types(cls: ast.ClassDef) -> list[str]:
    out = []
    for item in cls.body:
        if isinstance(item, ast.AnnAssign) and item.annotation is not None:
            ann = item.annotation
            if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
                out.append(ann.value)
            else:
                name = dotted_name(ann)
                if name:
                    out.append(name)
    return out


class PytreeChecker(Checker):
    name = "pytree-registration"
    description = ("jit-crossing leaf dataclasses (PackedWeight/-Linear/"
                   "-Embedding, PagedKV, and any dataclass with jax.Array "
                   "fields) are pytree-registered")

    def finalize(self, project: Project):
        registered: set[str] = set()
        classes: dict[str, tuple] = {}          # name → (mod, cls)
        for mod in project.modules:
            if mod.tree is None:
                continue
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.ClassDef):
                    classes.setdefault(node.name, (mod, node))
                    for d in node.decorator_list:
                        target = d.func if isinstance(d, ast.Call) else d
                        if (dotted_name(target).split(".")[-1]
                                in REGISTER_CALLS):
                            registered.add(node.name)
                elif isinstance(node, ast.Call):
                    fn = dotted_name(node.func).split(".")[-1]
                    if fn in REGISTER_CALLS and node.args:
                        first = node.args[0]
                        if isinstance(first, ast.Name):
                            registered.add(first.id)

        # required set: names + array-fielded dataclasses, to fixpoint
        required: set[str] = {n for n in REQUIRED_NAMES if n in classes}
        for name, (mod, cls) in classes.items():
            if _is_dataclass(cls) and any(
                    t in ARRAY_ANNOTATIONS for t in _field_types(cls)):
                required.add(name)
        changed = True
        while changed:
            changed = False
            for name, (mod, cls) in classes.items():
                if name in required or not _is_dataclass(cls):
                    continue
                if any(t.split(".")[-1] in required
                       for t in _field_types(cls)):
                    required.add(name)
                    changed = True

        findings = []
        for name in sorted(required):
            if name in registered:
                continue
            mod, cls = classes[name]
            findings.append(Finding(
                "pytree-registration", mod.rel, cls.lineno,
                f"{name}", f"class {name} carries jax arrays across jit "
                f"boundaries but is not pytree-registered — add "
                f"jax.tree_util.register_pytree_node({name}, ...) (or "
                f"the register_pytree_node_class decorator)"))
        return findings


register_checker(PytreeChecker())
