"""seamless-m4t-medium [audio] — enc-dec, multimodal; the audio
frontend is a stub (input_specs() feeds precomputed frame embeddings).
[arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206,
    n_encoder_layers=12, frontend="audio", frontend_seq=1024,
    act="relu", norm_type="layernorm",
)
