"""CoDR weight compression as a serving feature.

``codr_compress_params`` runs the paper's offline pipeline over every
projection matrix in a params pytree: int8 quantization → unique-weight
budget U (the paper's Fig. 6 U-sweep knob) → UCR (sort/densify/unify/Δ)
→ customized RLE parameter search.  It returns

  * params with the quantization *applied* (so served logits reflect the
    compressed weights — what you'd get decoding the real bitstream), and
  * a per-tensor report of real encoded bits (CoDR) vs UCNN / SCNN / int8
    / the fixed-width kernel pack.

The decode-fused execution lives in ``repro.kernels.codr_matmul`` (run
on TPU; interpret-mode on CPU) — the XLA serving graphs model compressed
weights as int8 + scale (docs/DESIGN.md §2 explains the split).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent import futures

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rle, ucr
from repro.core.baselines import scnn_compress_bits, ucnn_compress_bits
from repro.core.codr_linear import choose_bits
from repro.core.ucr import restrict_unique  # noqa: F401  (canonical home)

MIN_COMPRESS_SIZE = 1024           # skip tiny leaves (norms, biases)


@dataclasses.dataclass
class TensorReport:
    """Per-tensor compression accounting.  ``codr/ucnn/scnn_bits`` are
    the variable-width storage formats; ``pack_bits`` is the size of the
    **fixed-width unique-index pack** the decode-fused kernel executes
    from — i.e. the weight HBM traffic of the serving path, which is why
    it rides in the report instead of being recomputed downstream."""

    path: str
    n_weights: int
    codr_bits: int
    ucnn_bits: int
    scnn_bits: int
    density: float
    n_unique_mean: float
    pack_bits: int = 0

    @property
    def codr_bits_per_weight(self) -> float:
        return self.codr_bits / self.n_weights

    @property
    def pack_bits_per_weight(self) -> float:
        return self.pack_bits / self.n_weights


def compress_tensor(w: np.ndarray, *, n_unique: int = 256, t_m: int = 256
                    ) -> tuple[np.ndarray, dict]:
    """Offline CoDR pipeline for one (d_in, d_out) matrix.  Returns the
    dequantized-after-restriction tensor + size accounting."""
    q, scale = ucr.quantize_int8(w)
    q = restrict_unique(q, n_unique)
    # UCR per output-column-tile vector (linear layer = 1×1-kernel conv)
    ucrs = []
    m, n = q.shape[1], q.shape[0]       # weights stored (d_in, d_out)
    qt = q.T                            # (M=d_out, N=d_in)
    for m0 in range(0, m, t_m):
        tile = qt[m0 : m0 + t_m]
        for nn in range(n):
            ucrs.append(ucr.ucr_transform(tile[:, nn]))
    codr_bits = rle.layer_bits_size_only(ucrs, min(t_m, m))
    report = {
        "codr_bits": codr_bits,
        "ucnn_bits": ucnn_compress_bits(ucrs),
        "scnn_bits": scnn_compress_bits(q),
        "density": float((q != 0).mean()),
        "n_unique_mean": float(np.mean([len(u.unique_vals) for u in ucrs])),
        "pack_bits": int(q.size) * choose_bits(
            max(int(len(np.unique(q))), 2)),
    }
    deq = ucr.dequantize_int8(q, scale)
    return deq.astype(np.float32), report


def account_tensor(mat: np.ndarray, *, n_unique: int,
                   sample_rows: int | None) -> dict:
    """Sampled RLE/baseline accounting for one ``(rows, d_out)`` matrix:
    encode the leading ``sample_rows`` rows, scale the bit counts back up
    by the sampled fraction.  Shared by ``codr_compress_params`` and
    ``api.compile_params`` so the sampling policy lives in one place."""
    rows = mat.shape[0]
    if sample_rows and rows > sample_rows:
        sub, scale_f = mat[:sample_rows], rows / sample_rows
    else:
        sub, scale_f = mat, 1.0
    _, rep = compress_tensor(sub, n_unique=n_unique)
    out = {k: int(rep[k] * scale_f)
           for k in ("codr_bits", "ucnn_bits", "scnn_bits", "pack_bits")}
    out["density"] = rep["density"]
    out["n_unique_mean"] = rep["n_unique_mean"]
    return out


def codr_compress_params(params, *, n_unique: int = 16,
                         sample_rows: int | None = 4096,
                         sample_cols: int | None = None):
    """Compress every large 2-D+ leaf; returns (new_params, report).

    ``sample_rows`` bounds the RLE accounting work per tensor: each leaf
    is reshaped to ``(rows, d_out)`` and only the leading ``sample_rows``
    **rows** are RLE-encoded, with the bit counts scaled back up by the
    sampled fraction (a regression test pins sampled-vs-full agreement).
    The *quantization* is always applied to the full tensor.

    ``sample_cols`` is the deprecated name of the same parameter — it
    always sampled rows of the reshaped matrix, never columns.
    """
    if sample_cols is not None:
        import warnings
        warnings.warn("codr_compress_params(sample_cols=...) is "
                      "deprecated — it always sampled leading ROWS of "
                      "the reshaped (rows, d_out) matrix; use "
                      "sample_rows", DeprecationWarning, stacklevel=2)
        sample_rows = sample_cols
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    new_leaves, reports = [], []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = np.asarray(leaf)
        if arr.ndim < 2 or arr.size < MIN_COMPRESS_SIZE:
            new_leaves.append(leaf)
            continue
        mat = arr.reshape(-1, arr.shape[-1])
        acc = account_tensor(mat, n_unique=n_unique,
                             sample_rows=sample_rows)
        full_deq, _ = _quantize_only(mat, n_unique)
        new_leaves.append(jnp.asarray(full_deq.reshape(arr.shape),
                                      dtype=leaf.dtype))
        reports.append(TensorReport(path=pstr, n_weights=arr.size, **acc))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), reports


def _quantize_only(mat: np.ndarray, n_unique: int):
    q, scale = ucr.quantize_int8(mat)
    q = restrict_unique(q, n_unique)
    return ucr.dequantize_int8(q, scale), q


def codr_report(reports: list[TensorReport], *,
                per_tensor: bool = False) -> str:
    """Aggregate compression report; ``per_tensor=True`` appends one row
    per tensor (path, mean unique count, measured CoDR and pack
    bits/weight) so per-leaf tune plans are inspectable at a glance."""
    tot_w = sum(r.n_weights for r in reports)
    tot_codr = sum(r.codr_bits for r in reports)
    tot_ucnn = sum(r.ucnn_bits for r in reports)
    tot_scnn = sum(r.scnn_bits for r in reports)
    tot_pack = sum(r.pack_bits for r in reports)
    lines = [
        f"CoDR weight compression over {len(reports)} tensors "
        f"({tot_w/1e6:.1f}M weights):",
        f"  CoDR : {tot_codr/tot_w:.2f} bits/weight "
        f"({16*tot_w/max(tot_codr,1):.1f}x vs bf16)",
        f"  UCNN : {tot_ucnn/tot_w:.2f} bits/weight "
        f"(CoDR {tot_ucnn/max(tot_codr,1):.2f}x better)",
        f"  SCNN : {tot_scnn/tot_w:.2f} bits/weight "
        f"(CoDR {tot_scnn/max(tot_codr,1):.2f}x better)",
    ]
    if tot_pack:
        lines.append(
            f"  pack : {tot_pack/tot_w:.2f} bits/weight fixed-width "
            f"unique-index pack (serving HBM traffic, "
            f"{16*tot_w/max(tot_pack,1):.1f}x vs bf16)")
    if per_tensor:
        lines.append(f"  {'tensor':<40} {'weights':>9} {'uniq':>6} "
                     f"{'codr b/w':>9} {'pack b/w':>9}")
        for r in reports:
            pack = (f"{r.pack_bits_per_weight:9.2f}" if r.pack_bits
                    else f"{'-':>9}")
            lines.append(f"  {r.path:<40} {r.n_weights:>9} "
                         f"{r.n_unique_mean:6.1f} "
                         f"{r.codr_bits_per_weight:9.2f} {pack}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# async worker chassis (shared by CodrBatchServer and ContinuousBatcher)
# ---------------------------------------------------------------------------

class AsyncWorkerLoop:
    """Condition-variable worker-thread chassis: lazy daemon start,
    stop/drain/restart, and the can't-stop-from-the-worker guard.

    Subclasses provide the actual work:

    * :meth:`_loop` — the worker body.  It must re-check
      ``self._stopping`` under ``self._cv`` and return once stopping
      *and* (when draining) the pending work is gone.
    * :meth:`_cancel_pending_locked` — called under ``self._cv`` by
      ``stop_async(drain=False)`` to drop queued work (cancel futures,
      fail handles, ...).

    All shared state transitions happen under ``self._cv``; subclasses
    must take the same lock for their own queue state so one lock
    orders everything (the PR-6 sync-path race lived exactly in code
    that skipped it).

    **Supervision** (``docs/DESIGN.md`` §3.5): the worker thread runs
    :meth:`_loop` under :meth:`_run_worker`, which catches *any* escape
    — including ``BaseException`` crashes — and, when a
    ``RestartPolicy`` is configured via :meth:`configure_resilience`,
    backs off and re-enters the loop **on the same thread** so every
    pending request survives the crash.  Past the restart budget (or
    with no policy) the crash fails every live future/handle through
    the :meth:`_fail_live_locked` hook, guaranteeing ``result()`` never
    hangs on a dead loop.  ``configure_resilience`` also installs the
    optional fault injector (:meth:`_fire` is the zero-overhead-when-
    disabled site hook), retry policy, and serving supervisor consumed
    by subclasses.
    """

    _thread_name = "async-worker"

    def __init__(self) -> None:
        self._cv = threading.Condition()
        self._worker: threading.Thread | None = None   # guarded-by: _cv
        self._stopping = False                         # guarded-by: _cv
        # -- resilience (all optional; None ⇒ exact pre-resilience path)
        self._injector = None           # runtime.resilience.FaultInjector
        self._retry_policy = None       # runtime.resilience.RetryPolicy
        self._restart_policy = None     # runtime.resilience.RestartPolicy
        self._supervisor = None         # runtime.resilience.ServingSupervisor
        self.worker_crashes = 0                        # guarded-by: _cv
        self.worker_restarts = 0                       # guarded-by: _cv

    # -- subclass hooks -----------------------------------------------------
    def _loop(self) -> None:
        raise NotImplementedError

    def _cancel_pending_locked(self) -> None:
        raise NotImplementedError

    def _fail_live_locked(self, exc: BaseException) -> None:
        """Under ``self._cv``: deliver ``exc`` to every live future /
        handle (pending *and* in-flight) so no caller hangs after the
        worker died for good.  Subclasses with queues must override."""

    # -- resilience ---------------------------------------------------------
    def configure_resilience(self, *, injector=None, retry_policy=None,
                             restart_policy=None, supervisor=None):
        """Install resilience hooks (all optional, from
        ``repro.runtime.resilience``): a :class:`FaultInjector` firing
        at this loop's sites, a :class:`RetryPolicy` for transient
        dispatch failures (exhaustion ⇒ quarantine), a
        :class:`RestartPolicy` for worker crashes, and a
        :class:`ServingSupervisor` for latency-watch + mesh degradation.
        With none installed every code path is byte-identical to the
        unwired loop.  Returns ``self`` for chaining."""
        with self._cv:
            self._injector = injector
            self._retry_policy = retry_policy
            self._restart_policy = restart_policy
            self._supervisor = supervisor
        return self

    def _fire(self, site: str) -> None:
        """Fault-injection site hook: one attribute load + ``None``
        check when disabled — the cost a production dispatch pays."""
        inj = self._injector
        if inj is not None:
            inj.fire(site)

    def _run_worker(self) -> None:
        """Thread target: supervise :meth:`_loop`.  A normal return
        ends the thread; any escape (worker crash — ``Exception`` or
        injected ``BaseException``) consumes one restart from the
        ``RestartPolicy`` budget and re-enters the loop after backoff,
        pending work intact.  Budget exhausted ⇒ fail all live work
        with ``WorkerCrashed`` (chaining the cause) and clear
        ``self._worker`` so a later submit can lazily start fresh."""
        while True:
            try:
                self._loop()
                return
            except BaseException as e:  # noqa: BLE001 — supervision net
                with self._cv:
                    self.worker_crashes += 1
                    pol = self._restart_policy
                    if (pol is not None and not self._stopping
                            and self.worker_restarts < pol.max_restarts):
                        n = self.worker_restarts
                        self.worker_restarts += 1
                    else:
                        from repro.runtime.resilience import WorkerCrashed
                        err = WorkerCrashed(
                            f"{self._thread_name} worker died: {e!r}"
                            + ("" if pol is None else
                               f" (restart budget {pol.max_restarts} "
                               "exhausted)"))
                        err.__cause__ = e
                        # clear the thread slot BEFORE failing waiters:
                        # a woken submitter may immediately resubmit and
                        # must be able to lazily start a fresh worker
                        self._worker = None
                        self._fail_live_locked(err)
                        self._cv.notify_all()
                        return
                time.sleep(pol.delay(n))

    # -- lifecycle ----------------------------------------------------------
    def start_async(self):
        """Start the worker explicitly (idempotent)."""
        with self._cv:
            if self._stopping:
                raise RuntimeError(f"{type(self).__name__} is stopping")
            if self._worker is None or not self._worker.is_alive():
                self._start_locked()
        return self

    def _start_locked(self) -> None:
        self._worker = threading.Thread(target=self._run_worker,
                                        name=self._thread_name,
                                        daemon=True)
        self._worker.start()

    def stop_async(self, *, drain: bool = True) -> None:
        """Stop the worker.  ``drain=True`` (default) lets it finish the
        pending work first; ``drain=False`` cancels pending work.
        Idempotent; the loop can be restarted with :meth:`start_async`
        afterwards.  Must not be called from the worker itself (e.g.
        inside a ``Future`` done-callback, which runs on the worker
        thread) — that raises ``RuntimeError`` without corrupting state.
        """
        with self._cv:
            worker = self._worker
            if worker is threading.current_thread():
                raise RuntimeError(
                    f"stop_async called from the {self._thread_name} "
                    "worker itself (done callbacks run on the worker "
                    "thread) — stop from another thread")
            self._stopping = True
            if not drain:
                self._cancel_pending_locked()
            self._cv.notify_all()
        try:
            if worker is not None:
                worker.join()
        finally:
            with self._cv:
                self._worker = None
                self._stopping = False

    def __enter__(self):
        return self.start_async()

    def __exit__(self, *exc) -> None:
        self.stop_async(drain=True)


# ---------------------------------------------------------------------------
# batched request path over a CoDR engine model
# ---------------------------------------------------------------------------

class FlushDispatchError(RuntimeError):
    """A :meth:`CodrBatchServer.flush` chunk dispatch failed.

    Attributes:
        partial: submission-order output list for the flushed queue —
            rows computed by chunks that succeeded before the failure,
            ``None`` elsewhere.
        failed: queue positions (within the flushed queue) of the
            requests in the chunk whose dispatch raised.  These are
            consumed, not requeued.
        requeued: how many undispatched requests were restored to the
            server queue (they will be served by the next ``flush``).
    """

    def __init__(self, msg: str, *, partial, failed, requeued):
        super().__init__(msg)
        self.partial = partial
        self.failed = failed
        self.requeued = requeued


def _res():
    """Lazy handle on ``repro.runtime.resilience`` — imported only when
    a resilience feature (deadline, shedding, retry, injection) is
    actually exercised, so the plain serving path never pays the
    ``repro.runtime`` import."""
    from repro.runtime import resilience
    return resilience


@dataclasses.dataclass
class _AsyncReq:
    """One queued async request: the sample, its future, and the
    absolute monotonic deadline (``None`` ⇒ no deadline)."""

    sample: np.ndarray
    future: futures.Future
    deadline: float | None = None


class CodrBatchServer(AsyncWorkerLoop):
    """Batched inference over a CoDR executable (a
    :class:`repro.core.engine.CodrModel` or a
    :class:`repro.core.api.CompiledModel` — anything with ``.run``).

    Single-sample requests are queued and executed together in fixed-size
    batches, so every forward pass reuses the one jitted tile-dispatch
    computation per layer — the serving-side complement of the engine's
    encode-once/run-many contract.

    Dispatch is **size-bucketed**: requests are grouped by sample shape,
    and ragged tail batches are padded up to the next power-of-two bucket
    (≤ ``max_batch``) rather than to arbitrary sizes.  A mixed-size
    request stream therefore compiles at most ``len(shapes) ×
    log2(max_batch)+1`` forward variants instead of one per distinct
    ragged size — the compile cache stops thrashing while padding waste
    stays bounded at <2x.

    Two request paths share that dispatch core (``docs/DESIGN.md`` §3):

    * **Synchronous** — :meth:`submit` + :meth:`flush` (or
      :meth:`serve`): the caller owns batching cadence; a dispatch
      failure raises out of ``flush``.
    * **Asynchronous** — :meth:`submit_async` returns a
      :class:`concurrent.futures.Future` immediately; a background flush
      loop dispatches when either ``max_batch`` requests are pending
      (load trigger) or the oldest pending request has waited
      ``flush_deadline_s`` (latency trigger).  Consecutive batches are
      **double-buffered**: batch *i+1*'s host→device transfer is issued
      while batch *i* computes, so the device never idles on the PCIe
      copy.  A dispatch failure propagates into exactly the futures of
      the failed batch; other batches are unaffected.

    The loop starts lazily on first ``submit_async`` (or explicitly via
    :meth:`start_async`) and is joined by :meth:`stop_async` /
    ``with server: ...``.
    """

    _thread_name = "codr-batch-server"

    def __init__(self, model, *, max_batch: int = 8,
                 flush_deadline_s: float = 0.01,
                 max_pending: int | None = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if flush_deadline_s <= 0:
            raise ValueError("flush_deadline_s must be > 0")
        if max_pending is not None and max_pending < 1:
            raise ValueError("max_pending must be >= 1 (or None)")
        super().__init__()                  # _cv / _worker / _stopping
        self.model = model
        self.max_batch = max_batch
        self.flush_deadline_s = flush_deadline_s
        self.max_pending = max_pending      # bounded admission (None=∞)
        self._queue: list[tuple[np.ndarray, float | None]] = []  # guarded-by: _cv
        self._next_id = 0                   # guarded-by: _cv
        self.batches_run = 0                # guarded-by: _cv
        self.requests_served = 0            # guarded-by: _cv
        self.bucket_counts: dict[int, int] = {}   # guarded-by: _cv
        # -- resilience accounting (docs/DESIGN.md §3.5) ----------------
        self.requests_shed = 0              # guarded-by: _cv
        self.requests_expired = 0           # guarded-by: _cv
        self.requests_quarantined = 0       # guarded-by: _cv
        self.quarantined: list[dict] = []   # guarded-by: _cv
        # -- async state ------------------------------------------------
        self._async_queue: list[_AsyncReq] = []   # guarded-by: _cv
        self._oldest_t: float | None = None       # guarded-by: _cv

    def _bucket(self, n_real: int) -> int:
        b = 1
        while b < n_real:
            b *= 2
        return min(b, self.max_batch)

    def _chunks(self, samples: list[np.ndarray]):
        """Shared batching core: group positions by sample shape, split
        into ≤ ``max_batch`` chunks, pad each to its power-of-two bucket.
        Yields ``(positions, batch, n_real, bucket)`` with ``batch`` a
        stacked host array of ``bucket`` rows."""
        by_shape: dict[tuple, list[int]] = {}
        for pos, x in enumerate(samples):
            by_shape.setdefault(x.shape, []).append(pos)
        for positions in by_shape.values():
            for i in range(0, len(positions), self.max_batch):
                chunk_pos = positions[i : i + self.max_batch]
                chunk = [samples[p] for p in chunk_pos]
                n_real = len(chunk)
                bucket = self._bucket(n_real)
                if n_real < bucket:          # pad → bucketed batch shape
                    chunk = chunk + [chunk[-1]] * (bucket - n_real)
                yield chunk_pos, np.stack(chunk), n_real, bucket

    def _count(self, n_real: int, bucket: int) -> None:
        # locked: the sync flush (caller thread) and the async flush
        # loop (worker thread) both account onto these counters
        with self._cv:
            self.batches_run += 1
            self.requests_served += n_real
            self.bucket_counts[bucket] = \
                self.bucket_counts.get(bucket, 0) + 1

    def _admit_deadline(self, deadline_s: float | None) -> float | None:
        if deadline_s is None:
            return None
        if deadline_s <= 0:
            raise ValueError("deadline_s must be > 0 (or None)")
        return time.monotonic() + deadline_s

    def _shed_locked(self, pending: int) -> None:
        """Under ``self._cv``: reject admission when the bounded queue
        is full (``RejectedError`` with a retry-after hint — one flush
        deadline is when capacity frees up at the latest)."""
        if self.max_pending is not None and pending >= self.max_pending:
            self.requests_shed += 1
            raise _res().RejectedError(
                f"admission queue full ({pending}/{self.max_pending} "
                f"pending); retry in ~{self.flush_deadline_s:.3f}s",
                retry_after_s=self.flush_deadline_s)

    # -- synchronous path ---------------------------------------------------
    def submit(self, x: np.ndarray, *, deadline_s: float | None = None
               ) -> int:
        """Queue one sample (no batch dim).  Returns its request id.

        Ids come from a dedicated monotonic counter, NOT from
        ``requests_served`` (which advances in *chunk* order during
        :meth:`flush` — deriving ids from it let ids collide with
        already-issued ones whenever a flush died mid-way).  An id is
        issued exactly once, forever.

        ``deadline_s`` bounds how long the request may wait in the
        queue: if the next :meth:`flush` starts after the deadline, the
        request is dropped (its output row is ``None``, counted in
        ``requests_expired``) instead of burning a dispatch slot on an
        answer nobody is waiting for.  With ``max_pending`` set, a full
        queue rejects admission with ``RejectedError`` instead of
        growing without bound.

        Thread-safe: queue append and id issue happen under the same
        lock the async worker and :meth:`flush` take, so concurrent
        submitters can neither collide on an id nor corrupt the queue.
        """
        sample = np.asarray(x, dtype=np.float32)
        deadline = self._admit_deadline(deadline_s)
        with self._cv:
            self._shed_locked(len(self._queue))
            self._queue.append((sample, deadline))
            rid = self._next_id
            self._next_id += 1
        return rid

    def flush(self) -> list[np.ndarray]:
        """Run all queued requests; returns outputs in submission order.

        If a chunk's dispatch raises, the failure is re-raised as
        :class:`FlushDispatchError` carrying the already-computed
        partial results, and every *undispatched* request is restored
        to the queue head (submission order preserved) so the next
        ``flush`` serves them — nothing is silently dropped.  The
        failed chunk itself is NOT requeued: a poison request would
        otherwise kill every subsequent flush forever.

        With a :class:`~repro.runtime.resilience.RetryPolicy`
        configured, *transient* chunk failures retry with backoff
        first; only retry-budget exhaustion (the chunk is then recorded
        in ``self.quarantined``) or a non-transient error reaches the
        ``FlushDispatchError`` path.  Requests whose ``deadline_s``
        already passed are dropped up front (``None`` output row,
        ``requests_expired``).
        """
        with self._cv:
            queue, self._queue = self._queue, []
        outs: list[np.ndarray | None] = [None] * len(queue)
        live_pos = list(range(len(queue)))
        if any(d is not None for _, d in queue):
            now = time.monotonic()
            live_pos = [p for p in live_pos
                        if queue[p][1] is None or now < queue[p][1]]
            if len(live_pos) < len(queue):
                with self._cv:
                    self.requests_expired += len(queue) - len(live_pos)
        chunks = list(self._chunks([queue[p][0] for p in live_pos]))
        for ci, (chunk_pos, batch, n_real, bucket) in enumerate(chunks):
            try:
                y = self._guarded_dispatch(batch)
            except Exception as e:          # noqa: BLE001 — rewrapped
                qpos = [live_pos[p] for p in chunk_pos]
                self._note_quarantine(e, n_real)
                tail = sorted(live_pos[p] for c in chunks[ci + 1:]
                              for p in c[0])
                with self._cv:
                    self._queue[:0] = [queue[p] for p in tail]
                raise FlushDispatchError(
                    f"dispatch failed on a chunk of {n_real} request(s) "
                    f"(bucket {bucket}); {len(tail)} undispatched "
                    f"request(s) restored to the queue",
                    partial=outs, failed=qpos,
                    requeued=len(tail)) from e
            for p, row in zip(chunk_pos, y[:n_real]):
                outs[live_pos[p]] = row
            self._count(n_real, bucket)
        return outs

    def _model_run(self, batch):
        """One model dispatch, routed through the supervisor's current
        lane when one is installed (degradation changes the backend,
        bit-for-bit never the outputs — DESIGN §3.3/§3.5)."""
        sup = self._supervisor
        if sup is not None:
            return self.model.run(batch, backend=sup.backend)
        return self.model.run(batch)

    def _guarded_dispatch(self, batch: np.ndarray) -> np.ndarray:
        """Dispatch one host chunk under the resilience ladder: fire the
        injection site, run on the current lane, block to host.  With a
        retry policy, transient failures re-execute with backoff (the
        jitted dispatch is side-effect free on failure); with a
        supervisor, device loss degrades the lane and retries there.
        Unconfigured, this is exactly ``np.asarray(model.run(...))``."""

        def _attempt():
            self._fire("server.dispatch")
            return np.asarray(self._model_run(jnp.asarray(batch)))

        pol, sup = self._retry_policy, self._supervisor
        if pol is None and sup is None:
            return _attempt()
        t0 = time.monotonic()
        y = _res().retry_call(_attempt, policy=pol, supervisor=sup)
        if sup is not None:
            sup.record_latency(time.monotonic() - t0)
        return y

    def _note_quarantine(self, exc: BaseException, n_real: int) -> None:
        """Record a consumed-not-requeued chunk.  Only exhaustion of a
        configured retry budget counts as quarantine; a plain dispatch
        error without a policy keeps PR-6 semantics untouched."""
        if not isinstance(exc, _res().QuarantinedError):
            return
        with self._cv:
            self.requests_quarantined += n_real
            self.quarantined.append({
                "n_requests": n_real, "attempts": exc.attempts,
                "error": repr(exc.__cause__ or exc),
                "t": time.monotonic()})
            del self.quarantined[:-64]      # bounded log

    def serve(self, samples) -> list[np.ndarray]:
        """Convenience: submit + flush a list of single samples."""
        for s in samples:
            self.submit(s)
        return self.flush()

    # -- asynchronous path --------------------------------------------------
    @property
    def async_pending(self) -> int:
        """Requests submitted via :meth:`submit_async` not yet dispatched."""
        with self._cv:
            return len(self._async_queue)

    def submit_async(self, x: np.ndarray, *,
                     deadline_s: float | None = None) -> futures.Future:
        """Queue one sample (no batch dim) on the background flush loop.

        Returns immediately with a :class:`concurrent.futures.Future`
        that resolves to this sample's output row (host ``np.ndarray``)
        once its batch is dispatched — by the ``max_batch`` load trigger
        or the ``flush_deadline_s`` latency trigger, whichever fires
        first.  If the batch dispatch raises, the exception lands on the
        future (``.result()`` re-raises it).  Starts the flush loop if it
        is not running.  Raises ``RuntimeError`` after :meth:`stop_async`
        began (a future that could never resolve must not be issued).

        ``deadline_s`` bounds queue wait: a request still undispatched
        when its deadline passes resolves to
        :class:`~repro.runtime.resilience.DeadlineExceeded` instead of
        occupying a batch slot.  With ``max_pending`` set, a full
        admission queue sheds the request with ``RejectedError``
        (``retry_after_s`` hint) rather than queueing unboundedly.
        """
        fut: futures.Future = futures.Future()
        sample = np.asarray(x, dtype=np.float32)
        deadline = self._admit_deadline(deadline_s)
        with self._cv:
            if self._stopping:
                raise RuntimeError("server is stopping; submit_async "
                                   "rejected (future would never resolve)")
            self._shed_locked(len(self._async_queue))
            if self._worker is None or not self._worker.is_alive():
                self._start_locked()
            self._async_queue.append(_AsyncReq(sample, fut, deadline))
            if self._oldest_t is None:
                self._oldest_t = time.monotonic()
            self._cv.notify_all()
        return fut

    def _cancel_pending_locked(self) -> None:
        for req in self._async_queue:
            req.future.cancel()
        self._async_queue.clear()
        self._oldest_t = None

    def _fail_live_locked(self, exc: BaseException) -> None:
        # crash past the restart budget: every undispatched future gets
        # the WorkerCrashed (already-cancelled ones stay cancelled)
        for req in self._async_queue:
            if req.future.set_running_or_notify_cancel():
                req.future.set_exception(exc)
        self._async_queue.clear()
        self._oldest_t = None

    def _loop(self) -> None:
        """Background worker: wait for a trigger, take the whole queue,
        dispatch it bucketed with double-buffered staging."""
        while True:
            # injection site "server.worker": fires BEFORE the queue is
            # taken, so a crash here leaves every pending request queued
            # for the restarted loop (or for _fail_live_locked)
            self._fire("server.worker")
            with self._cv:
                while not self._stopping:
                    if len(self._async_queue) >= self.max_batch:
                        break                      # load trigger
                    if self._oldest_t is not None:
                        wait = (self._oldest_t + self.flush_deadline_s
                                - time.monotonic())
                        if wait <= 0:
                            break                  # latency trigger
                        self._cv.wait(wait)
                    else:
                        self._cv.wait()
                taken = self._async_queue
                self._async_queue = []
                self._oldest_t = None
                stopping = self._stopping
            if taken:
                self._dispatch_async(taken)
            if stopping:
                return

    def _dispatch_async(self, taken) -> None:
        """Run one drained queue: stage batch i+1's host→device transfer
        while batch i computes (double buffering), resolve each batch's
        futures as its results arrive, and propagate a failed dispatch
        into exactly that batch's futures.  With resilience configured
        the chunks route through :meth:`_guarded_dispatch` (retry /
        quarantine / supervisor lane) instead of the overlapped fast
        path — the unconfigured path is exactly the pre-resilience
        code."""
        # drop requests cancelled while queued BEFORE batching — they
        # must neither burn compute nor inflate requests_served (this
        # also moves every surviving future to RUNNING, so a cancel
        # arriving after this point is a no-op).  Deadline-expired
        # requests resolve to DeadlineExceeded here, for the same
        # reason: never burn a batch slot on an abandoned request.
        live = []
        now = time.monotonic()
        expired = 0
        for req in taken:
            if not req.future.set_running_or_notify_cancel():
                continue
            if req.deadline is not None and now >= req.deadline:
                expired += 1
                req.future.set_exception(_res().DeadlineExceeded(
                    "deadline expired before dispatch"))
                continue
            live.append(req)
        if expired:
            with self._cv:
                self.requests_expired += expired
        if not live:
            return
        samples = [r.sample for r in live]
        futs = [r.future for r in live]
        chunks = list(self._chunks(samples))
        if (self._retry_policy is not None or self._supervisor is not None
                or self._injector is not None):
            self._dispatch_chunks_resilient(chunks, futs)
            return
        staged: list = [None] * len(chunks)
        if chunks:                      # stage the first transfer
            staged[0] = _try_device_put(chunks[0][1])
        for i, (chunk_pos, batch, n_real, bucket) in enumerate(chunks):
            try:
                y_dev = self.model.run(jnp.asarray(staged[i]))
            except Exception as e:      # noqa: BLE001 — lands on futures
                y_dev, err = None, e
            else:
                err = None
            if i + 1 < len(chunks):     # overlaps with batch i's compute
                staged[i + 1] = _try_device_put(chunks[i + 1][1])
            if err is None:
                try:
                    y = np.asarray(y_dev)   # block on batch i only
                except Exception as e:  # noqa: BLE001
                    err = e
            staged[i] = None            # release batch i's device buffer
            if err is None:
                # account BEFORE resolving: a caller waking up on
                # Future.result() must already see this batch counted
                self._count(n_real, bucket)
            for j, p in enumerate(chunk_pos):
                if err is not None:
                    futs[p].set_exception(err)
                else:
                    futs[p].set_result(y[j])

    def _dispatch_chunks_resilient(self, chunks, futs) -> None:
        """Async dispatch under the resilience ladder: each chunk runs
        through :meth:`_guarded_dispatch` (fire site → current lane →
        block), retries transients, quarantines on budget exhaustion
        (the chunk's futures get the ``QuarantinedError``; later chunks
        are unaffected), and feeds per-chunk latency to the supervisor.
        No double-buffer overlap here — a retried chunk must own its
        dispatch end-to-end."""
        for chunk_pos, batch, n_real, bucket in chunks:
            try:
                y = self._guarded_dispatch(batch)
            except Exception as e:      # noqa: BLE001 — lands on futures
                self._note_quarantine(e, n_real)
                for p in chunk_pos:
                    futs[p].set_exception(e)
                continue
            self._count(n_real, bucket)
            for j, p in enumerate(chunk_pos):
                futs[p].set_result(y[j])


def _try_device_put(batch: np.ndarray):
    """Start the async host→device transfer for a staged batch.  On a
    backend without ``device_put`` semantics this degrades to the host
    array (the dispatch then transfers synchronously, still correct)."""
    try:
        return jax.device_put(jnp.asarray(batch))
    # codrlint: disable=exception-hygiene — deliberate fallback: any device_put failure degrades to the host array; dispatch stays correct, just synchronous
    except Exception:                   # pragma: no cover — defensive
        return batch


def codr_serving_stats(cfg, *, n_unique: int = 16, seed: int = 0,
                       reports: list[TensorReport] | None = None) -> dict:
    """Per-decode-token weight HBM traffic under each format (GB).

    When ``reports`` (the :class:`TensorReport` list from a real
    ``codr_compress_params`` / ``api.compile_params`` run) is given,
    bits/weight is **measured** from the model's own tensors.  Without
    it the number is extrapolated from one synthetic 512×512 Gaussian
    matrix — ``stats["source"]`` says which you got, and printers must
    label the synthetic path as an estimate.
    """
    n_active = cfg.active_param_count()
    if reports:
        tot_w = sum(r.n_weights for r in reports)
        bits_pw = sum(r.codr_bits for r in reports) / tot_w
        pack_pw = sum(r.pack_bits for r in reports) / tot_w
        source = "measured"
    else:
        rng = np.random.default_rng(seed)
        w = rng.normal(size=(512, 512)).astype(np.float32) * 0.02
        _, rep = compress_tensor(w, n_unique=n_unique)
        bits_pw = rep["codr_bits"] / w.size
        pack_pw = rep["pack_bits"] / w.size
        source = "synthetic-estimate"
    return {
        "bf16_gb": n_active * 2 / 1e9,
        "int8_gb": n_active * 1 / 1e9,
        "codr_gb": n_active * bits_pw / 8 / 1e9,
        "codr_bits_per_weight": bits_pw,
        "pack_bits_per_weight": pack_pw,
        "source": source,
    }
