"""Pure-jnp oracle for the SMM convolution kernel: a dense int-exact
convolution of the *decoded* weights (UCR/RLE decode must be lossless, so
the kernel's reuse-exploiting schedule has to reproduce plain conv)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.ucr import LayerCode, ucr_reconstruct


def decode_dense_weights(code: LayerCode, n_in: int) -> np.ndarray:
    """Rebuild the dense int8 weight tensor (M, N, RK, CK) from UCR vectors."""
    m = code.shape[0]
    rk, ck = (code.shape[2], code.shape[3]) if len(code.shape) == 4 else (1, 1)
    m_tiles = -(-m // code.t_m)
    w = np.zeros((m_tiles * code.t_m, n_in, rk, ck), dtype=np.int8)
    for vi, u in enumerate(code.ucr):
        mt, nn = vi // n_in, vi % n_in
        vec = ucr_reconstruct(u).reshape(-1, rk, ck)   # (t_m, rk, ck)
        w[mt * code.t_m : mt * code.t_m + vec.shape[0], nn] = vec
    return w[:m]


def smm_conv_ref(x: np.ndarray, code: LayerCode,
                 stride: int = 1) -> jnp.ndarray:
    """Dense conv oracle via jax.lax.conv (float32, exact for int8 ranges)."""
    import jax.lax as lax
    n_in = x.shape[0]
    w = decode_dense_weights(code, n_in).astype(np.float32)
    xf = jnp.asarray(x, jnp.float32)[None]                  # (1, N, RI, CI)
    wf = jnp.asarray(w)                                     # (M, N, RK, CK)
    out = lax.conv_general_dilated(
        xf, wf, window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return out[0]
