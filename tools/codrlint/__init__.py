"""codrlint — repo-specific static invariant checker (docs/DESIGN.md §7).

An AST-based, plugin-style analysis suite pinning the conventions the
codebase otherwise holds only in prose:

==========================  =============================================
check                       invariant
==========================  =============================================
``jit-purity``              no host sync (np.*, .item(), float()/int(),
                            print, attribute mutation) inside functions
                            traced by jit/scan/shard_map/pallas_call
``lock-discipline``         ``# guarded-by: <lock>`` attributes only
                            touched under ``with self.<lock>:`` or in
                            ``*_locked`` methods
``capability-consistency``  Backend subclasses implement what their
                            BackendCaps/KERNEL_CAPS flags claim
``pytree-registration``     jit-crossing leaf dataclasses are
                            pytree-registered
``export-surface``          ``__all__`` names bound; first-party
                            re-exports resolve
``exception-hygiene``       broad catches re-raise, deliver, or log —
                            never silently swallow
==========================  =============================================

Run ``python -m tools.codrlint [--json] [paths]`` (default: ``src
tools``).  Inline suppressions require a rationale; grandfathered
findings live in ``tools/codrlint/baseline.json``.
"""
from tools.codrlint.core import (DEFAULT_PATHS, Checker,  # noqa: F401
                                 Finding, ModuleInfo, Project, Report,
                                 register_checker, registered_checkers, run)

__all__ = ["Checker", "Finding", "ModuleInfo", "Project", "Report",
           "DEFAULT_PATHS", "register_checker", "registered_checkers",
           "run"]
