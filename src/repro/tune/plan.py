"""The tune-plan artifact: per-layer encode configs + predicted costs.

A :class:`TunePlan` is what the per-layer search (:mod:`repro.tune.autotune`)
emits and what ``codr.compile(spec, plan=...)`` /
``codr.compile_params(params, plan=...)`` consume: a mapping from layer
name (or pytree leaf path) to the :class:`~repro.core.api.EncodeConfig`
that layer should encode under, carrying the tuner's predicted cost
numbers alongside so the compiled model's measured stats can be checked
against them (``CompiledModel.layer_table``).

Plans serialize to JSON (``save``/``load``) and cache by a **weight-stats
fingerprint**: layer geometry + quantized-value statistics (density,
unique-level count, magnitude histogram).  Two layers with the same
fingerprint have identical candidate cost tables, so re-tuning a model
with repeated layer shapes — or re-running the tuner across sessions —
hits the cache instead of re-scoring (docs/DESIGN.md §2.1).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json

import numpy as np

from repro.core.api import EncodeConfig
from repro.core.ucr import quantize_int8

__all__ = ["TuneBudget", "LayerPlan", "TunePlan", "layer_fingerprint"]


@dataclasses.dataclass(frozen=True)
class TuneBudget:
    """What the search optimizes and what it must not exceed.

    ``max_rel_err``       per-layer quality gate: candidates whose
                          relative weight-quantization error exceeds
                          this are infeasible (``None`` = any error).
    ``target_bits_per_weight``  model-wide storage target: after the
                          per-layer optimum, the search greedily trades
                          quality headroom for bits until the total
                          measured-size prediction meets the target (or
                          no feasible move remains).
    ``max_sram_accesses`` model-wide predicted-SRAM ceiling, same greedy
                          semantics as the bits target.
    ``objective``         what each layer minimizes once feasible:
                          ``"sram"`` (default — the paper's §IV metric),
                          ``"bits"`` (Fig. 6 metric), or ``"energy"``
                          (§V).  Ties break on bits, then n_unique.
    """

    max_rel_err: float | None = 0.05
    target_bits_per_weight: float | None = None
    max_sram_accesses: float | None = None
    objective: str = "sram"

    def __post_init__(self):
        if self.objective not in ("sram", "bits", "energy"):
            raise ValueError(f"objective must be 'sram', 'bits' or "
                             f"'energy', got {self.objective!r}")
        for field in ("max_rel_err", "target_bits_per_weight",
                      "max_sram_accesses"):
            v = getattr(self, field)
            if v is not None and v <= 0:
                raise ValueError(f"{field} must be positive or None, "
                                 f"got {v}")

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def layer_fingerprint(w: np.ndarray, kind: str, stride: int = 1) -> str:
    """Geometry + weight-stats cache key for one layer.

    Hashes the shape/kind/stride plus statistics of the *quantized*
    tensor — int8 magnitude histogram, density, unique-level count —
    which are exactly the quantities every candidate score is a function
    of.  Float payloads with the same int8 image share a key on purpose.
    """
    w = np.asarray(w, dtype=np.float32)
    q, scale = quantize_int8(w)
    hist = np.bincount(((q.astype(np.int16) + 128) // 8).ravel(),
                       minlength=32)
    h = hashlib.sha256()
    h.update(repr((kind, tuple(w.shape), int(stride),
                   tuple(int(c) for c in hist),
                   int(len(np.unique(q))),
                   float(np.asarray(scale)))).encode())
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One layer's chosen config + the tuner's predicted costs for it."""

    name: str
    kind: str                        # "conv" | "linear"
    config: EncodeConfig
    n_weights: int
    predicted_bits: float            # exact when unsampled
    predicted_sram: float            # total SRAM accesses, CoDR dataflow
    predicted_energy_uj: float
    rel_err: float                   # relative weight quantization error
    fingerprint: str
    from_cache: bool = False

    @property
    def predicted_bits_per_weight(self) -> float:
        return self.predicted_bits / max(self.n_weights, 1)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["config"] = self.config.metadata()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "LayerPlan":
        cfg = dict(d["config"])
        if cfg.get("rle_params") is not None:
            cfg["rle_params"] = tuple(cfg["rle_params"])
        d = dict(d, config=EncodeConfig(**cfg))
        d.pop("predicted_bits_per_weight", None)
        return cls(**d)


class TunePlan:
    """Per-layer encode configs, consumable by ``codr.compile(plan=...)``.

    ``config_for(name, default)`` is the whole runtime contract — any
    layer the plan does not name encodes under the caller's default, so
    the empty plan is exactly the global-config path.
    """

    def __init__(self, layers: dict[str, LayerPlan] | None = None, *,
                 default: EncodeConfig | None = None,
                 budget: TuneBudget | None = None,
                 meta: dict | None = None):
        self.layers: dict[str, LayerPlan] = dict(layers or {})
        self.default = EncodeConfig() if default is None else default
        self.budget = TuneBudget() if budget is None else budget
        self.meta = dict(meta or {})

    # -- the compile-side contract ------------------------------------------
    def config_for(self, name: str,
                   default: EncodeConfig | None = None) -> EncodeConfig:
        lp = self.layers.get(name)
        if lp is not None:
            return lp.config
        return self.default if default is None else default

    def __len__(self) -> int:
        return len(self.layers)

    def __contains__(self, name: str) -> bool:
        return name in self.layers

    # -- predicted totals ----------------------------------------------------
    def predicted_total_sram(self) -> float:
        return sum(lp.predicted_sram for lp in self.layers.values())

    def predicted_total_bits(self) -> float:
        return sum(lp.predicted_bits for lp in self.layers.values())

    def predicted_bits_per_weight(self) -> float:
        n = sum(lp.n_weights for lp in self.layers.values())
        return self.predicted_total_bits() / max(n, 1)

    def max_rel_err(self) -> float:
        return max((lp.rel_err for lp in self.layers.values()), default=0.0)

    def table(self) -> str:
        hdr = (f"{'layer':<16} {'kind':<7} {'U':>4} {'t_m':>5} "
               f"{'pred b/w':>9} {'pred sram':>12} {'pred uJ':>10} "
               f"{'rel err':>8} {'cached':>7}")
        lines = [hdr, "-" * len(hdr)]
        for lp in self.layers.values():
            t_m = lp.config.t_m if lp.kind == "conv" else lp.config.t_m_linear
            lines.append(
                f"{lp.name:<16} {lp.kind:<7} {lp.config.n_unique:>4} "
                f"{t_m:>5} {lp.predicted_bits_per_weight:9.2f} "
                f"{lp.predicted_sram:12.3e} {lp.predicted_energy_uj:10.4f} "
                f"{lp.rel_err:8.4f} {str(lp.from_cache):>7}")
        lines.append(f"{'total':<16} {'':<7} {'':>4} {'':>5} "
                     f"{self.predicted_bits_per_weight():9.2f} "
                     f"{self.predicted_total_sram():12.3e}")
        return "\n".join(lines)

    # -- serialization -------------------------------------------------------
    def to_json(self) -> dict:
        return {
            "version": 1,
            "default": self.default.metadata(),
            "budget": self.budget.as_dict(),
            "meta": self.meta,
            "layers": {name: lp.as_dict()
                       for name, lp in self.layers.items()},
        }

    @classmethod
    def from_json(cls, d: dict) -> "TunePlan":
        default = dict(d["default"])
        if default.get("rle_params") is not None:
            default["rle_params"] = tuple(default["rle_params"])
        return cls(
            {name: LayerPlan.from_dict(lp)
             for name, lp in d["layers"].items()},
            default=EncodeConfig(**default),
            budget=TuneBudget(**d["budget"]),
            meta=d.get("meta", {}))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)

    @classmethod
    def load(cls, path: str) -> "TunePlan":
        with open(path) as f:
            return cls.from_json(json.load(f))

    def __repr__(self) -> str:
        return (f"TunePlan({len(self.layers)} layers, "
                f"{self.predicted_bits_per_weight():.2f} pred bits/weight, "
                f"objective={self.budget.objective!r})")
