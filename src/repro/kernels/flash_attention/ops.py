"""jit'd wrapper: (B, S, H, D) GQA layout → fused flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 512,
                           bk: int = 512,
                           interpret: bool | None = None) -> jax.Array:
    """q (B,S,Hq,D), k/v (B,S,Hkv,D) → (B,S,Hq,Dv); GQA by repeating kv
    heads at the wrapper level (the kernel sees flat (B·H, S, D))."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, hq, d = q.shape
    _, sk, hkv, dv = v.shape
    g = hq // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * hq, sk, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * hq, sk, dv)
    out = flash_attention_pallas(qf, kf, vf, causal=causal, bq=bq, bk=bk,
                                 interpret=interpret)
    return out.reshape(b, hq, s, dv).transpose(0, 2, 1, 3)
