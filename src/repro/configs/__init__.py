"""Architecture registry: ``get_config(arch_id)`` + reduced smoke
variants + applicable shape sets per arch."""
from __future__ import annotations

import dataclasses

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.command_r_plus_104b import CONFIG as _command_r
from repro.configs.deepseek_v2_236b import CONFIG as _deepseek
from repro.configs.granite_moe_1b import CONFIG as _granite
from repro.configs.internvl2_26b import CONFIG as _internvl
from repro.configs.jamba_v01_52b import CONFIG as _jamba
from repro.configs.qwen1_5_4b import CONFIG as _qwen15
from repro.configs.qwen2_5_3b import CONFIG as _qwen25
from repro.configs.qwen3_32b import CONFIG as _qwen3
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.xlstm_350m import CONFIG as _xlstm

REGISTRY: dict[str, ModelConfig] = {
    c.name: c for c in [
        _qwen25, _qwen15, _command_r, _qwen3, _seamless,
        _deepseek, _granite, _internvl, _xlstm, _jamba,
    ]
}

ARCH_IDS = list(REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    return REGISTRY[arch]


def applicable_shapes(cfg: ModelConfig) -> dict[str, str]:
    """shape_name → 'run' | reason-to-skip (recorded in the roofline
    table; see docs/DESIGN.md §4)."""
    out = {}
    for name, shp in SHAPES.items():
        if name == "long_500k" and not cfg.sub_quadratic:
            out[name] = "SKIP: 512k dense-attention decode is the quadratic regime this shape excludes"
        else:
            out[name] = "run"
    return out


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: few layers, narrow, tiny vocab/experts
    — used by the per-arch CPU smoke tests (full configs are exercised
    only via the dry-run)."""
    period = len(cfg.block_pattern)
    n_layers = period + cfg.n_dense_layers
    d_model = 64
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    changes = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=d_model // n_heads if cfg.head_dim == cfg.d_model // cfg.n_heads else 32,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        attn_q_chunk=16, attn_kv_chunk=16, mamba_chunk=16,
        remat=False,
    )
    if cfg.use_mla:
        changes.update(q_lora_rank=32, kv_lora_rank=16, nope_head_dim=16,
                       rope_head_dim=8, v_head_dim=16, head_dim=16)
    if cfg.n_experts:
        changes.update(n_experts=8, moe_top_k=min(cfg.moe_top_k, 4),
                       moe_d_ff=32)
    if cfg.n_encoder_layers:
        changes.update(n_encoder_layers=2)
    if cfg.frontend:
        changes.update(frontend_seq=16)
    return dataclasses.replace(cfg, **changes)


__all__ = ["REGISTRY", "ARCH_IDS", "SHAPES", "ModelConfig", "ShapeConfig",
           "get_config", "applicable_shapes", "smoke_variant"]
