"""Bit-packing primitives and the vectorized bulk decoder — deterministic
tests (no hypothesis dependency; the property-based variants live in
``tests/test_rle.py``)."""
import numpy as np
import pytest

from repro.core import rle, ucr
from repro.core.packing import (BitReader, escape_field_offsets,
                                escape_field_offsets_batch, gather_bitfields,
                                pack_varbits, unpack_bits)


def test_bitreader_read_many_matches_sequential_reads():
    rng = np.random.default_rng(3)
    widths = rng.integers(0, 14, size=200)
    vals = rng.integers(0, 2**13, size=200).astype(np.uint64) \
        & ((np.uint64(1) << widths.astype(np.uint64)) - np.uint64(1))
    packed, nbits = pack_varbits(vals, widths)
    bulk = BitReader(packed, nbits).read_many(widths)
    seq = BitReader(packed, nbits)
    assert [int(v) for v in bulk] == [seq.read(int(w)) for w in widths]


def test_bitreader_overrun_raises_clear_error():
    packed, nbits = pack_varbits(np.array([5], dtype=np.uint64),
                                 np.array([3]))
    r = BitReader(packed, nbits)
    with pytest.raises(EOFError, match="overruns the 3-bit payload"):
        r.read(4)
    r2 = BitReader(packed, nbits)
    with pytest.raises(EOFError, match="bulk read"):
        r2.read_many([2, 2])
    assert r2.pos == 0                     # failed bulk read moves nothing
    assert r2.read_many([2, 1]).tolist() == [1, 1]   # 5 = 0b101 LSB-first


def test_gather_bitfields_overrun_and_zero_width():
    bits = unpack_bits(*pack_varbits(np.array([3], np.uint64),
                                     np.array([2])))
    assert gather_bitfields(bits, np.array([0]), np.array([2]))[0] == 3
    assert gather_bitfields(bits, np.array([0]), np.array([0]))[0] == 0
    with pytest.raises(EOFError):
        gather_bitfields(bits, np.array([1]), np.array([2]))


@pytest.mark.parametrize("seed", range(6))
def test_field_offset_resolvers_agree(seed):
    """The O(log n) pointer-doubling resolver and the lockstep batch
    resolver find identical field starts on real escape streams."""
    rng = np.random.default_rng(seed)
    w = rng.integers(-128, 128, size=200).astype(np.int8)
    w[rng.random(200) > rng.uniform(0.05, 1.0)] = 0
    u = ucr.ucr_transform(w)
    enc = rle.encode_vector(u.unique_vals, u.reps, u.indexes, u.vector_len)
    for s in (enc.deltas, enc.indexes):
        if s.count == 0:
            continue
        bits = unpack_bits(s.packed, s.nbits)
        doubling = escape_field_offsets(bits, s.count, s.param + 1,
                                        s.mode_bits + 1)
        lockstep = escape_field_offsets_batch(
            bits, np.array([0]), np.array([s.count]), s.param + 1,
            s.mode_bits + 1)
        assert np.array_equal(doubling, lockstep)


def test_decode_layer_rejects_truncated_streams():
    """A truncated payload must raise EOFError, not bleed into the next
    stream's bits (the scalar BitReader guarantee, kept by the bulk
    path)."""
    rng = np.random.default_rng(2)
    w = rng.normal(size=(8, 4, 3, 3)).astype(np.float32)
    w[rng.random(w.shape) > 0.5] = 0
    code = ucr.encode_conv_layer(w, t_m=4, t_n=2)
    import dataclasses
    victim = code.vectors[1]
    code.vectors[1] = dataclasses.replace(
        victim, deltas=dataclasses.replace(
            victim.deltas, nbits=victim.deltas.nbits - 1))
    with pytest.raises(EOFError, match="corrupt stream 1"):
        rle.decode_layer(code)
    code.vectors[1] = dataclasses.replace(
        victim, reps=dataclasses.replace(
            victim.reps, nbits=victim.reps.nbits - 1))
    with pytest.raises(EOFError, match="corrupt rep stream 1"):
        rle.decode_layer(code)


@pytest.mark.parametrize("shape,density,t_m,t_n", [
    ((8, 4, 3, 3), 0.3, 4, 2),
    ((5, 3, 2, 2), 0.05, 4, 2),
    ((16, 2, 1, 1), 1.0, 4, 2),
    ((10, 3, 3, 3), 0.6, 4, 4),
    ((24, 16, 1, 1), 0.5, 8, 1),
    ((4, 2, 3, 3), 0.0, 4, 2),          # all-zero layer
])
def test_decode_layer_matches_scalar_decoder(shape, density, t_m, t_n):
    rng = np.random.default_rng(0)
    w = rng.normal(size=shape).astype(np.float32) * 0.5
    w[rng.random(shape) > density] = 0
    code = ucr.encode_conv_layer(w, t_m=t_m, t_n=t_n)
    bulk = rle.decode_layer(code)
    for i, v in enumerate(code.vectors):
        assert np.array_equal(bulk[i, : v.vector_len], rle.decode_vector(v))
        assert not bulk[i, v.vector_len:].any()


def test_decode_layer_mixed_per_vector_params():
    """Bulk decode handles vectors encoded WITHOUT shared layer params
    (per-vector search → mixed parameter groups in one layer)."""
    rng = np.random.default_rng(1)
    w = rng.integers(-128, 128, size=60).astype(np.int8)
    w[rng.random(60) > 0.5] = 0
    u = ucr.ucr_transform(w)
    encs = [rle.encode_vector(u.unique_vals, u.reps, u.indexes, u.vector_len),
            rle.encode_vector(u.unique_vals, u.reps, u.indexes, u.vector_len,
                              params=(1, 1, 1)),
            rle.encode_vector(u.unique_vals, u.reps, u.indexes, u.vector_len,
                              params=(8, 8, 8))]

    class _Code:
        vectors = encs

    for dec in rle.decode_layer_vectors(_Code):
        assert np.array_equal(dec, w)
