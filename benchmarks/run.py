"""Benchmark harness — one module per paper table/figure plus the
roofline report.  Prints ``name,us_per_call,derived`` CSV lines.

  python -m benchmarks.run [--only fig6|compression|fig7|fig8|kernels|
                                   roofline|engine|decode]
                           [--small]

``compression`` is ``fig6`` plus the tuning-lane Pareto section (the
quality-vs-bits/weight curve and tuned-vs-global comparison written to
``BENCH_tune.json``).  ``--small`` runs the size-aware suites (engine —
the spec→compile→serve API path — decode, and compression) in their CI
smoke configuration; the CI workflow uses it so every PR appends a
comparable, SHA-stamped point to the ``BENCH_*.json`` perf
trajectories.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks import compression, decode, energy, engine, kernels, \
    roofline, sram_access

SUITES = {
    "fig6": compression.main,
    "compression": compression.main,   # fig6 + tuning-lane Pareto curve
    "fig7": sram_access.main,
    "fig8": energy.main,
    "kernels": kernels.main,
    "roofline": roofline.main,
    "engine": engine.main,
    "decode": decode.main,
}
SMALL_AWARE = {"engine", "decode", "fig6", "compression"}  # small= kwarg


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=list(SUITES), default=None)
    ap.add_argument("--small", action="store_true",
                    help="CI smoke sizes for the suites that support it "
                         f"({', '.join(sorted(SMALL_AWARE))})")
    args = ap.parse_args(argv)
    if args.only:
        suites = {args.only: SUITES[args.only]}
    else:                       # run each suite once despite name aliases
        seen: set = set()
        suites = {n: f for n, f in SUITES.items()
                  if not (f in seen or seen.add(f))}
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        try:
            if args.small and name in SMALL_AWARE:
                fn(small=True)
            else:
                fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0.00,ERROR:{type(e).__name__}:{e}",
                  file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
