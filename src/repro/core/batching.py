"""Continuous batching: a production decode loop over packed weights.

``ContinuousBatcher`` runs a fixed pool of KV-cache slots (one pooled
cache whose batch axis is the slot axis) and drives every *active* slot
forward with a single jitted ``decode_step`` per iteration:

* **join-on-prefill** — a new request is prefilled on its own (batch-1,
  its exact prompt length) and its cache block-written into a free slot
  (:func:`repro.models.cache.write_slot`); the pooled decode batch never
  stalls behind a long prompt, and in-flight requests never recompile.
* **leave-on-EOS** — a slot retires the moment its request samples
  ``eos_id`` or hits ``max_new_tokens``, freeing the slot for the next
  admission while the rest of the pool keeps decoding.
* **streaming** — :meth:`submit` returns a :class:`GenerationHandle`
  immediately; iterating it yields tokens as they are produced, and
  ``handle.result()`` blocks for the full sequence.

Per-request results are **bit-identical** to a solo decode of the same
prompt on the same params (:meth:`ContinuousBatcher.generate_reference`
is that oracle, sharing the batcher's compiled functions): decode
attention masks every cache position beyond a slot's own ``pos``, so a
neighbour slot's content — or the stale tail a previous tenant left —
contributes exactly 0.0, and XLA's per-row computation does not mix
rows.  The slot state machine and streaming contract are documented in
``docs/DESIGN.md`` §3.4.

The async chassis (condition-variable worker, lazy start, stop/drain/
restart, exception isolation) is :class:`repro.core.serving
.AsyncWorkerLoop`, shared with ``CodrBatchServer``.
"""
from __future__ import annotations

import dataclasses
import queue as queue_mod
import time
from concurrent import futures

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.serving import AsyncWorkerLoop

_DONE = object()                    # stream sentinel: generation finished


class GenerationHandle:
    """Streaming handle for one request.

    * iterate it (``for tok in handle``) to stream tokens as the pool
      produces them — the iterator ends at EOS/max-tokens and re-raises
      a generation failure;
    * ``handle.result(timeout)`` blocks for the full token list;
    * ``handle.finish_reason`` is ``"eos"``, ``"length"``,
      ``"cancelled"`` or ``"error"`` once finished.

    Tokens are plain Python ints.  When the batcher was built with
    ``record_logits=True``, ``handle.logits`` holds one float32 vocab
    row per emitted token (the bit-identity witness).
    """

    def __init__(self, rid: int, prompt_len: int, max_new_tokens: int):
        self.rid = rid
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.finish_reason: str | None = None
        self.future: futures.Future = futures.Future()
        self.logits: list[np.ndarray] = []
        self._tokens: list[int] = []
        self._stream: queue_mod.SimpleQueue = queue_mod.SimpleQueue()

    # -- worker side --------------------------------------------------------
    def _emit(self, tok: int, logits_row: np.ndarray | None = None) -> None:
        self._tokens.append(tok)
        if logits_row is not None:
            self.logits.append(logits_row)
        self._stream.put(tok)

    def _finish(self, reason: str) -> None:
        self.finish_reason = reason
        self.future.set_result(list(self._tokens))
        self._stream.put(_DONE)

    def _fail(self, exc: BaseException, reason: str = "error") -> None:
        self.finish_reason = reason
        self.future.set_exception(exc)
        self._stream.put(exc)

    # -- caller side --------------------------------------------------------
    def __iter__(self):
        while True:
            item = self._stream.get()
            if item is _DONE:
                return
            if isinstance(item, BaseException):
                raise item
            yield item

    def result(self, timeout: float | None = None) -> list[int]:
        """Block until generation finishes; returns all emitted tokens."""
        return self.future.result(timeout)

    @property
    def tokens(self) -> list[int]:
        """Tokens emitted so far (snapshot; may still be growing)."""
        return list(self._tokens)

    def done(self) -> bool:
        return self.future.done()


@dataclasses.dataclass
class _Slot:
    """One occupied pool slot (ACTIVE state of the slot machine)."""
    handle: GenerationHandle
    eos_id: int | None
    last_tok: int                   # token fed to the next decode step
    pos: int                        # cache position that step writes
    n_gen: int                      # tokens emitted so far


@dataclasses.dataclass
class _Pending:
    """A submitted request waiting for a free slot (QUEUED state)."""
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: int | None
    handle: GenerationHandle


class ContinuousBatcher(AsyncWorkerLoop):
    """Slot-pooled continuous-batching decode loop over an LM.

    ``params`` may be a raw params pytree or an
    :class:`repro.core.api.CompiledParams` (packed weights; its
    ``.params`` pytree is served through the backend registry exactly as
    in ``launch/serve.py --codr``).  Decoder-only families only — the
    encoder-decoder cache (per-request encoder output) has no pooled
    form here.

    The worker admits up to ``prefill_per_step`` queued requests per
    iteration (each prefilled at its own prompt length, outside the
    decode batch), then advances every active slot with ONE pooled
    ``decode_step`` whose per-slot positions ride in a ``(n_slots,)``
    vector.  ``join_deadline_s > 0`` lets a partially-filled pool wait
    that long after an admission for co-riders before decoding resumes
    (a latency/throughput knob mirroring ``CodrBatchServer``'s
    ``flush_deadline_s``).

    A failed *prefill* fails only its own request's handle; a failed
    pooled *decode step* fails the handles of exactly the slots that
    were active in it.  The worker survives both and keeps serving.
    """

    _thread_name = "codr-continuous-batcher"

    def __init__(self, params, cfg, *, n_slots: int = 4, max_len: int = 128,
                 eos_id: int | None = None, prefill_per_step: int = 1,
                 join_deadline_s: float = 0.0, record_logits: bool = False):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if max_len < 2:
            raise ValueError("max_len must be >= 2")
        if cfg.family == "encdec" or cfg.frontend:
            raise NotImplementedError(
                "ContinuousBatcher supports decoder-only LM configs "
                f"(got family={cfg.family!r}, frontend={cfg.frontend!r})")
        super().__init__()
        from repro.models import get_model          # lazy: core → models
        from repro.models import cache as cache_mod
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.prefill_per_step = max(1, prefill_per_step)
        self.join_deadline_s = join_deadline_s
        self.record_logits = record_logits
        # CompiledParams duck-typing: serve from its packed pytree
        self._params = getattr(params, "params", params)
        self._api = get_model(cfg)
        # slot axis per cache leaf, discovered structurally (stacked
        # scan-carry leaves lead with n_periods, prologue leaves with
        # batch) — no arrays materialized
        self._axes = cache_mod.diff_axes(
            jax.eval_shape(lambda: self._api.init_cache(cfg, 1, max_len)),
            jax.eval_shape(lambda: self._api.init_cache(cfg, 2, max_len)))
        self._prefill_fn = jax.jit(
            lambda p, t: self._api.prefill(p, {"tokens": t}, cfg))
        self._step_fn = jax.jit(
            lambda p, pool, tok, pos: self._api.decode_step(
                p, pool, tok, pos, cfg))
        self._write_fn = jax.jit(
            lambda pool, c, slot: cache_mod.write_slot(
                pool, c, slot, self._axes))
        self._pool = self._api.init_cache(cfg, n_slots, max_len)
        self._slots: list[_Slot | None] = [None] * n_slots
        self._pending: list[_Pending] = []
        self._next_id = 0
        self._abort_active = False
        self._last_admit_t: float | None = None
        # stats (written by the worker under _cv)
        self.steps_run = 0
        self.prefills_run = 0
        self.requests_finished = 0
        self.peak_active = 0

    # -- submission ---------------------------------------------------------
    def submit(self, prompt, *, max_new_tokens: int = 16,
               eos_id: int | None = None) -> GenerationHandle:
        """Queue one prompt (1-D int token array).  Returns immediately
        with a :class:`GenerationHandle`; the worker starts lazily.
        ``eos_id`` overrides the batcher default for this request."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if prompt.size + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt_len {prompt.size} + max_new_tokens "
                f"{max_new_tokens} exceeds pool max_len {self.max_len}")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        with self._cv:
            if self._stopping:
                raise RuntimeError(
                    "batcher is stopping; submit rejected (handle would "
                    "never resolve)")
            handle = GenerationHandle(self._next_id, int(prompt.size),
                                      max_new_tokens)
            self._next_id += 1
            self._pending.append(_Pending(
                prompt, max_new_tokens,
                self.eos_id if eos_id is None else eos_id, handle))
            if self._worker is None or not self._worker.is_alive():
                self._start_locked()
            self._cv.notify_all()
        return handle

    @property
    def active(self) -> int:
        with self._cv:
            return sum(s is not None for s in self._slots)

    @property
    def pending(self) -> int:
        with self._cv:
            return len(self._pending)

    # -- AsyncWorkerLoop hooks ----------------------------------------------
    def _cancel_pending_locked(self) -> None:
        self._abort_active = True
        for p in self._pending:
            p.handle._fail(futures.CancelledError(), reason="cancelled")
        self._pending.clear()

    def _loop(self) -> None:
        with self._cv:
            self._abort_active = False
        while True:
            with self._cv:
                while not self._stopping:
                    has_free = any(s is None for s in self._slots)
                    n_active = sum(s is not None for s in self._slots)
                    if self._pending and has_free:
                        break                       # admission work
                    if n_active:
                        # join deadline: a partially-filled pool lingers
                        # briefly after an admission so co-riders can
                        # join the decode batch
                        if (self.join_deadline_s > 0 and has_free
                                and self._last_admit_t is not None):
                            wait = (self._last_admit_t
                                    + self.join_deadline_s
                                    - time.monotonic())
                            if wait > 0:
                                self._cv.wait(wait)
                                continue
                        break                       # decode work
                    self._cv.wait()
                if self._stopping:
                    if self._abort_active:
                        for i, s in enumerate(self._slots):
                            if s is not None:
                                s.handle._fail(futures.CancelledError(),
                                               reason="cancelled")
                                self._slots[i] = None
                        return
                    if (not self._pending
                            and not any(s is not None for s in self._slots)):
                        return                      # drained
                admits: list[tuple[int, _Pending]] = []
                for _ in range(self.prefill_per_step):
                    free = [i for i, s in enumerate(self._slots)
                            if s is None]
                    if not free or not self._pending:
                        break
                    req = self._pending.pop(0)
                    # reserve the slot under the lock; prefill happens
                    # outside it
                    self._slots[free[0]] = _Slot(
                        req.handle, req.eos_id, last_tok=-1,
                        pos=-1, n_gen=0)
                    admits.append((free[0], req))
            for slot_idx, req in admits:
                self._admit(slot_idx, req)
            self._decode_active()

    # -- worker internals ---------------------------------------------------
    def _admit(self, slot_idx: int, req: _Pending) -> None:
        """Prefill one request and install it in its reserved slot.  A
        prefill failure releases the slot and fails only this handle."""
        try:
            logits, cache = self._prefill_fn(
                self._params, jnp.asarray(req.prompt[None, :]))
            self._pool = self._write_fn(self._pool, cache,
                                        jnp.int32(slot_idx))
            row = np.asarray(logits, np.float32).reshape(-1)
        except Exception as e:      # noqa: BLE001 — lands on the handle
            with self._cv:
                self._slots[slot_idx] = None
            req.handle._fail(e)
            return
        tok = int(np.argmax(row))
        with self._cv:
            slot = self._slots[slot_idx]
            slot.last_tok = tok
            slot.pos = int(req.prompt.size)
            slot.n_gen = 1
            self.prefills_run += 1
            self._last_admit_t = time.monotonic()
            n_active = sum(s is not None for s in self._slots)
            self.peak_active = max(self.peak_active, n_active)
        req.handle._emit(tok, row if self.record_logits else None)
        self._maybe_retire(slot_idx, tok)

    def _decode_active(self) -> None:
        with self._cv:
            active = [(i, s) for i, s in enumerate(self._slots)
                      if s is not None]
        if not active:
            return
        toks = np.zeros((self.n_slots,), np.int32)
        poss = np.zeros((self.n_slots,), np.int32)
        for i, s in active:
            toks[i] = s.last_tok
            poss[i] = s.pos
        try:
            logits, self._pool = self._step_fn(
                self._params, self._pool, jnp.asarray(toks),
                jnp.asarray(poss))
            rows = np.asarray(logits, np.float32)
        except Exception as e:      # noqa: BLE001 — exactly this batch
            with self._cv:
                for i, s in active:
                    self._slots[i] = None
                    self.requests_finished += 1
                for _, s in active:
                    s.handle._fail(e)
            return
        with self._cv:
            self.steps_run += 1
        for i, s in active:
            tok = int(np.argmax(rows[i]))
            s.pos += 1
            s.n_gen += 1
            s.last_tok = tok
            s.handle._emit(tok,
                           rows[i].copy() if self.record_logits else None)
            self._maybe_retire(i, tok)

    def _maybe_retire(self, slot_idx: int, tok: int) -> None:
        with self._cv:
            s = self._slots[slot_idx]
            if s is None:
                return
            reason = None
            if s.eos_id is not None and tok == s.eos_id:
                reason = "eos"
            elif s.n_gen >= s.handle.max_new_tokens:
                reason = "length"
            if reason is None:
                return
            self._slots[slot_idx] = None        # slot → FREE
            self.requests_finished += 1
            self._cv.notify_all()
        s.handle._finish(reason)

    # -- solo oracle --------------------------------------------------------
    def generate_reference(self, prompt, *, max_new_tokens: int = 16,
                           eos_id: int | None = None,
                           record_logits: bool = False):
        """Solo decode of ``prompt``: a fresh ``n_slots`` pool with only
        slot 0 active, driven by the SAME compiled prefill/decode
        functions the batcher uses.  This is the bit-identity oracle —
        any pooled run of the same request must emit exactly these
        tokens (and, with ``record_logits``, these logits bits).
        Returns ``(tokens, logits_rows)``."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size + max_new_tokens > self.max_len:
            raise ValueError("prompt + max_new_tokens exceeds max_len")
        eos = self.eos_id if eos_id is None else eos_id
        pool = self._api.init_cache(self.cfg, self.n_slots, self.max_len)
        logits, cache = self._prefill_fn(self._params,
                                         jnp.asarray(prompt[None, :]))
        pool = self._write_fn(pool, cache, jnp.int32(0))
        row = np.asarray(logits, np.float32).reshape(-1)
        toks: list[int] = []
        rows: list[np.ndarray] = []
        tok, pos = int(np.argmax(row)), int(prompt.size)
        toks.append(tok)
        if record_logits:
            rows.append(row)
        while len(toks) < max_new_tokens and tok != eos:
            tvec = np.zeros((self.n_slots,), np.int32)
            pvec = np.zeros((self.n_slots,), np.int32)
            tvec[0], pvec[0] = tok, pos
            logits, pool = self._step_fn(self._params, pool,
                                         jnp.asarray(tvec),
                                         jnp.asarray(pvec))
            r = np.asarray(logits, np.float32)[0]
            tok, pos = int(np.argmax(r)), pos + 1
            toks.append(tok)
            if record_logits:
                rows.append(r.copy())
        return toks, rows
