"""Docs stay wired to the code: every doc cross-reference in the tree
resolves (tools/check_docs.py — the CI link-check step runs the same
script), and the two architecture documents exist with the sections the
module docstrings cite."""
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_doc_cross_references_resolve():
    res = subprocess.run([sys.executable, str(ROOT / "tools" /
                                              "check_docs.py")],
                         capture_output=True, text=True, timeout=120)
    assert res.returncode == 0, res.stdout + res.stderr


def test_design_doc_has_cited_sections():
    text = (ROOT / "docs" / "DESIGN.md").read_text()
    # the sections module docstrings point into (serving §2/§3, configs
    # §4, sharding/checkpoint §5, benchmarks §6)
    for sec in ("## §1", "## §2", "## §3", "## §4", "## §5", "## §6"):
        assert sec in text, f"docs/DESIGN.md lost section {sec!r}"


def test_paper_map_exists_and_linked_from_readme():
    assert (ROOT / "docs" / "PAPER_MAP.md").exists()
    readme = (ROOT / "README.md").read_text()
    assert "docs/PAPER_MAP.md" in readme
    assert "docs/DESIGN.md" in readme
