"""Distributed-runtime substrate: straggler detection, elastic re-mesh
planning, and the fault-tolerant training loop (checkpoint → crash →
resume, loss continues to improve)."""
import numpy as np
import pytest

import jax

from repro.runtime import (ElasticMeshManager, HostSet, StragglerMonitor,
                           TrainLoop, TrainLoopConfig)
from repro.runtime.elastic import feasible_grid
from repro.runtime.straggler import StragglerConfig


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------

def test_straggler_flags_slow_host():
    mon = StragglerMonitor(8, StragglerConfig(patience=3))
    for _ in range(10):
        t = np.ones(8)
        t[3] = 2.5
        res = mon.observe(t)
    assert res["actions"].get(3) == "rebalance"


def test_straggler_recommends_eviction_when_severe():
    mon = StragglerMonitor(4, StragglerConfig(patience=2))
    for _ in range(6):
        res = mon.observe(np.array([1.0, 1.0, 1.0, 10.0]))
    assert res["actions"].get(3) == "evict"


def test_straggler_no_false_positive_on_noise():
    rng = np.random.default_rng(0)
    mon = StragglerMonitor(16)
    for _ in range(50):
        res = mon.observe(rng.normal(1.0, 0.05, size=16))
    assert not res["actions"]


def test_straggler_all_equal_fleet_never_flags():
    """A perfectly uniform fleet has ratio 1.0 everywhere — no host may
    ever be flagged, no matter how long it runs."""
    mon = StragglerMonitor(4, StragglerConfig(patience=1))
    for _ in range(100):
        res = mon.observe(np.full(4, 0.25))
    assert not res["actions"]
    assert not mon.flag_streak.any()


def test_straggler_zero_median_fleet_no_spurious_flags():
    """Degenerate timings (zero median — cold start, stuck clock) must
    not ratio a positive entry to +inf and evict it: the monitor
    reports no evidence and resets streaks."""
    mon = StragglerMonitor(4, StragglerConfig(patience=1))
    for _ in range(10):
        res = mon.observe(np.array([0.5, 0.0, 0.0, 0.0]))
    assert not res["actions"]
    assert not mon.flag_streak.any()
    assert np.all(res["ratio"] == 1.0)
    # an all-zero fleet is the same degenerate case
    mon2 = StragglerMonitor(3, StragglerConfig(patience=1))
    res2 = mon2.observe(np.zeros(3))
    assert not res2["actions"] and res2["median"] == 0.0
    # ...and recovery to healthy positive timings still detects a real
    # straggler afterwards
    for _ in range(10):
        res3 = mon2.observe(np.array([1.0, 1.0, 5.0]))
    assert res3["actions"].get(2) == "evict"


# ---------------------------------------------------------------------------
# elastic mesh
# ---------------------------------------------------------------------------

def test_feasible_grid_shrinks_data_axis():
    assert feasible_grid(256, model_parallel=16, global_batch=256) == (16, 16)
    # lose one host (4 chips): 252 chips → data 15 doesn't divide 256 → 8
    d, m = feasible_grid(252, model_parallel=16, global_batch=256)
    assert d * 16 <= 252 and 256 % d == 0 and d == 8


def test_elastic_manager_failure_and_recovery():
    hosts = HostSet(n_hosts=4, chips_per_host=4,
                    healthy=np.ones(4, dtype=bool))
    mgr = ElasticMeshManager(hosts, model_parallel=2, global_batch=16)
    assert mgr.current_grid() == (8, 2)
    mgr.mark_failed(0)
    d, m = mgr.current_grid()
    assert d * m <= 12 and 16 % d == 0
    plan = mgr.resume_plan(step=100)
    assert plan["restore_step"] == 100
    assert "rebuild-mesh" in plan["actions"]
    mgr.mark_recovered(0)
    assert mgr.current_grid() == (8, 2)


def test_elastic_infeasible_raises():
    with pytest.raises(ValueError):
        feasible_grid(1, model_parallel=2, global_batch=4)


def test_feasible_grid_too_few_chips_clear_message():
    """chips < model_parallel must explain itself: the error names the
    surviving chip count and the fixed model axis, not just 'no grid'."""
    with pytest.raises(ValueError, match=r"3 surviving chip\(s\).*model-"
                                         r"parallel group of 8"):
        feasible_grid(3, model_parallel=8, global_batch=64)
    with pytest.raises(ValueError, match="0 surviving"):
        feasible_grid(0, model_parallel=1, global_batch=4)
    with pytest.raises(ValueError, match="model_parallel must be >= 1"):
        feasible_grid(4, model_parallel=0, global_batch=4)


def test_elastic_manager_total_loss_raises_clear():
    """Failing every host drives healthy_chips to 0; current_grid must
    raise the hardened chips<model_parallel message (the supervisor's
    fall-back-to-tiled trigger)."""
    hosts = HostSet(n_hosts=2, chips_per_host=1,
                    healthy=np.ones(2, dtype=bool))
    mgr = ElasticMeshManager(hosts, model_parallel=1, global_batch=2)
    mgr.mark_failed(0)
    assert mgr.current_grid() == (1, 1)
    mgr.mark_failed(1)
    with pytest.raises(ValueError, match="0 surviving"):
        mgr.current_grid()


# ---------------------------------------------------------------------------
# fault-tolerant loop: train → crash → resume
# ---------------------------------------------------------------------------

def _tiny_setup(tmp_path, fail_at=None, total=30):
    from repro.configs import get_config, smoke_variant
    from repro.data import DataConfig, host_batch_iterator
    from repro.models import get_model
    from repro.optim import AdamWConfig

    cfg = smoke_variant(get_config("qwen2.5-3b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    return TrainLoop(
        train_loss_fn=lambda p, b: api.train_loss(p, b, cfg),
        params=params,
        batch_iter=host_batch_iterator(dcfg),
        opt_cfg=AdamWConfig(lr=3e-3, use_master=False),
        loop_cfg=TrainLoopConfig(total_steps=total, checkpoint_every=10,
                                 ckpt_dir=str(tmp_path), peak_lr=3e-3,
                                 warmup_steps=5, fail_at_step=fail_at))


def test_loop_loss_improves(tmp_path):
    loop = _tiny_setup(tmp_path, total=25)
    hist = loop.run()
    assert len(hist) == 25
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first


def test_crash_and_resume_bitexact_data_cursor(tmp_path):
    loop = _tiny_setup(tmp_path, fail_at=15, total=25)
    with pytest.raises(RuntimeError, match="simulated host failure"):
        loop.run()
    # fresh process: rebuild everything, restore, continue
    loop2 = _tiny_setup(tmp_path, total=25)
    start = loop2.try_restore()
    assert start == 11                     # checkpoint at step 10
    hist = loop2.run()
    assert hist[0]["step"] == 11 and hist[-1]["step"] == 24
