"""codrlint fixture: traced bodies that are pure (or properly escaped)."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def good_decorated(x):
    return jnp.sum(x * 2)


@jax.jit
def good_escape_hatch(x):
    # sanctioned host compute: concrete at trace time by construction
    with jax.ensure_compile_time_eval():
        bias = jnp.asarray(np.ones(3, np.float32))
    return x + bias


def good_scan(xs):
    def body(carry, x):
        return carry + x, carry
    return jax.lax.scan(body, 0.0, xs)


def host_helper(x):
    return np.asarray(x)            # never traced — host code is fine
