"""Pluggable execution backends for the CoDR engine.

The paper's accelerator is one fixed datapath; a software reproduction
grows several — the fused XLA tile dispatch, the faithful NumPy MPE/APE
execution model, the Pallas SMM kernel, the fused-decode matmul kernel.
Previously each was reachable through a different stringly-typed knob
(``CodrModel.run(backend=...)`` if/else chains, ``smm_forward(kernel=...)``).
This module makes backends first class:

* :class:`BackendCaps` — declarative capability flags (stride support,
  integer-activation requirement, which layer kinds execute natively).
  Kernel-adjacent facts live next to the kernels themselves
  (``repro.kernels.*.ops.KERNEL_CAPS``) and are consumed here.
* :class:`Backend` — the protocol: ``conv(layer, x)`` / ``linear(layer,
  x)`` steps plus ``run_model(model, x)`` chaining, with ``supports``
  answering *can this backend execute that layer, and if not, why not*.
* a **registry** — :func:`register` / :func:`get_backend` /
  :func:`available_backends` / :func:`resolve`.  ``repro.core.engine``
  and ``repro.core.api`` dispatch exclusively through it; the ROADMAP's
  multi-device sharding and async-serving work plug in here as new
  registered backends.

Built-ins registered at import:

``tiled``        fused ``lax.conv`` tile dispatch (any stride, float path)
``smm``          NumPy faithful MPE/APE execution (integer activations)
``smm_kernel``   Pallas MPE/APE kernel, batch in the grid (integer acts)
``codr_matmul``  Pallas fused decode+matmul (linear-only models)
"""
from __future__ import annotations

import abc
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import smm, ucr

__all__ = [
    "Backend", "BackendCaps", "available_backends", "get_backend",
    "register", "resolve", "TiledBackend", "SmmBackend",
    "SmmKernelBackend", "CodrMatmulBackend",
]


# ---------------------------------------------------------------------------
# capabilities
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BackendCaps:
    """What a backend can execute, declaratively.

    ``max_stride``           ``None`` = any stride.
    ``integer_activations``  the backend runs the 8-bit feature datapath:
                             integer-valued inputs execute exactly,
                             anything else is int8-quantized first.
    ``native_kinds``         layer kinds the backend executes itself;
                             other kinds fall back per ``fallback_kinds``.
    ``fallback_kinds``       kinds delegated to the layer's own tiled
                             forward (empty = unsupported kinds error).
    """

    max_stride: int | None = None
    integer_activations: bool = False
    native_kinds: frozenset = frozenset({"conv", "linear"})
    fallback_kinds: frozenset = frozenset()
    description: str = ""

    def supports_stride(self, stride: int) -> bool:
        return self.max_stride is None or stride <= self.max_stride

    def supports_kind(self, kind: str) -> bool:
        return kind in self.native_kinds or kind in self.fallback_kinds


# ---------------------------------------------------------------------------
# backend protocol
# ---------------------------------------------------------------------------

def _finish(layer, y: jax.Array) -> jax.Array:
    """Shared epilogue: bias + activation (what every datapath appends
    after its accumulators drain)."""
    if layer.bias is not None:
        y = y + jnp.asarray(layer.bias)
    return jax.nn.relu(y) if layer.activation == "relu" else y


def _int_activations(x) -> tuple[np.ndarray, float]:
    """The accelerator's 8-bit feature path: integer-valued inputs within
    int8 range pass through exactly; anything else is symmetric
    int8-quantized (its scale folds into the output)."""
    xf = np.asarray(x, dtype=np.float32)
    if np.array_equal(xf, np.rint(xf)) and np.abs(xf).max() <= 127:
        return xf.astype(np.int32), 1.0
    q8, s = ucr.quantize_int8(xf)
    return q8.astype(np.int32), float(np.asarray(s))


class Backend(abc.ABC):
    """One way to execute CoDR layers.  Layers are duck-typed
    (:class:`repro.core.engine.CodrConv2D` / ``CodrLinear`` or anything
    exposing the same ``code`` / ``kind`` / ``stride`` surface)."""

    name: str = ""
    caps: BackendCaps = BackendCaps()

    # -- capability queries -------------------------------------------------
    def supports(self, layer) -> tuple[bool, str]:
        """``(ok, reason)`` — can this backend execute ``layer``?"""
        if not self.caps.supports_kind(layer.kind):
            return False, (f"backend {self.name!r} has no {layer.kind!r} "
                           f"path (native: {sorted(self.caps.native_kinds)})")
        stride = getattr(layer, "stride", 1)
        if layer.kind == "conv" and not self.caps.supports_stride(stride):
            return False, (f"backend {self.name!r} supports stride <= "
                           f"{self.caps.max_stride}, layer {layer.name!r} "
                           f"has stride {stride}")
        return True, ""

    def supports_model(self, layers) -> tuple[bool, str]:
        for layer in layers:
            ok, reason = self.supports(layer)
            if not ok:
                return False, reason
        return True, ""

    # -- execution ----------------------------------------------------------
    @abc.abstractmethod
    def conv(self, layer, x: jax.Array) -> jax.Array:
        """Forward one conv layer: NHWC ``(B, RI, CI, N)`` → NHWC out."""

    def linear(self, layer, x: jax.Array) -> jax.Array:
        """Forward one linear layer ``(B, N)`` → ``(B, M)``.  Default:
        the layer's own fused tiled matmul."""
        return layer(x)

    def step(self, layer, x: jax.Array) -> jax.Array:
        if layer.kind == "conv":
            return self.conv(layer, x)
        if layer.kind == "linear":
            return self.linear(layer, x)
        raise ValueError(f"unknown layer kind {layer.kind!r}")

    def run_model(self, model, batch: jax.Array) -> jax.Array:
        """Forward a batch through a :class:`~repro.core.engine.CodrModel`
        (or any object exposing ``_chain``)."""
        return model._chain(jnp.asarray(batch, jnp.float32), self.step)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Backend] = {}


def register(backend: Backend, *, overwrite: bool = False) -> Backend:
    """Add a backend instance to the registry (name taken from the
    instance).  Future executors — sharded, async, TPU-tuned — register
    here and become selectable everywhere a backend name is accepted."""
    if not backend.name:
        raise ValueError("backend must set a non-empty .name")
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[backend.name] = backend
    return backend


def available_backends() -> tuple[str, ...]:
    """Registered backend names, registration order."""
    return tuple(_REGISTRY)


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; registered: "
                         f"{', '.join(_REGISTRY) or '(none)'}") from None


def resolve(backend: str | Backend) -> Backend:
    """Accept a registered name or a Backend instance."""
    if isinstance(backend, Backend):
        return backend
    return get_backend(backend)


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------

class TiledBackend(Backend):
    """Fused XLA tile dispatch (default): each layer's decoded tile stack
    collapses into ONE ``lax.conv`` / matmul per layer, the whole model
    chain jitted once per input shape (compile-once contract)."""

    name = "tiled"
    caps = BackendCaps(description="fused lax.conv/matmul tile dispatch, "
                                   "any stride, float datapath")

    def conv(self, layer, x):
        return layer(x)

    def run_model(self, model, batch):
        # whole-model jitted chain, cached on the model — XLA fuses across
        # layer boundaries; repeat same-shape requests re-trace nothing
        if model._run_tiled is None:
            model._run_tiled = jax.jit(
                lambda x: model._chain(x, lambda l, xx: l(xx)))
        return model._run_tiled(jnp.asarray(batch, jnp.float32))


class SmmBackend(Backend):
    """Faithful MPE/APE execution model in NumPy
    (:func:`repro.core.smm.conv2d_smm_batched`): differential
    scalar–matrix multiplies + crossbar routing, bit-exact in int32,
    broadcasting every routed window over the batch axis."""

    name = "smm"
    caps = BackendCaps(integer_activations=True,
                       native_kinds=frozenset({"conv"}),
                       fallback_kinds=frozenset({"linear"}),
                       description="NumPy faithful MPE/APE execution "
                                   "(8-bit feature path)")

    def conv(self, layer, x):
        xi, x_scale = _int_activations(x)
        scale = float(np.asarray(layer.code.scale)) * x_scale
        outs = smm.conv2d_smm_batched(np.moveaxis(xi, 3, 1), layer.code,
                                      layer.stride)
        return _finish(layer, jnp.asarray(np.moveaxis(outs, 1, 3),
                                          jnp.float32) * scale)


class SmmKernelBackend(Backend):
    """Pallas MPE/APE kernel (:mod:`repro.kernels.smm_conv`): the whole
    batch in one dispatch via a batch grid dimension, operands packed
    once per layer and cached on it."""

    name = "smm_kernel"
    _caps: BackendCaps | None = None

    @property
    def caps(self) -> BackendCaps:
        # resolved lazily from the kernel's own KERNEL_CAPS so merely
        # importing repro.core never pulls in jax.experimental.pallas
        if self._caps is None:
            from repro.kernels.smm_conv import ops as smm_ops
            kc = smm_ops.KERNEL_CAPS
            self._caps = BackendCaps(
                integer_activations=kc["integer_activations"],
                max_stride=kc["max_stride"],
                native_kinds=frozenset(kc["kinds"]),
                # linear layers fall back to the fused tiled matmul — a
                # backend policy, not a kernel fact
                fallback_kinds=frozenset({"linear"}),
                description=kc["description"])
        return self._caps

    def conv(self, layer, x):
        from repro.kernels.smm_conv import smm_conv_batched
        xi, x_scale = _int_activations(x)
        scale = float(np.asarray(layer.code.scale)) * x_scale
        y = smm_conv_batched(jnp.asarray(np.moveaxis(xi, 3, 1), jnp.float32),
                             layer.code, stride=layer.stride,
                             operands=layer.smm_operands())
        return _finish(layer, jnp.moveaxis(y, 1, 3) * scale)


class CodrMatmulBackend(Backend):
    """Pallas fused decode+matmul (:mod:`repro.kernels.codr_matmul`):
    linear layers execute from the fixed-width unique-index pack, the
    table gather fused into the MXU tiles.  Linear-only — a model with
    conv layers is rejected at compile time via :meth:`supports`."""

    name = "codr_matmul"
    _caps: BackendCaps | None = None

    @property
    def caps(self) -> BackendCaps:
        if self._caps is None:
            from repro.kernels.codr_matmul import ops as mm_ops
            kc = mm_ops.KERNEL_CAPS
            self._caps = BackendCaps(
                native_kinds=frozenset(kc["kinds"]),
                integer_activations=kc["integer_activations"],
                description=kc["description"])
        return self._caps

    def conv(self, layer, x):                      # pragma: no cover
        raise NotImplementedError("codr_matmul is linear-only")

    def linear(self, layer, x):
        from repro.core.codr_linear import pack_unique
        from repro.kernels.codr_matmul import codr_matmul
        packed = getattr(layer, "_mm_packed", None)
        if packed is None:
            # decoded (M, N) int8 → (K=N_in, N=M_out) pack; pad M_out to
            # a multiple of 32 — every per-word width pack_unique may
            # choose divides 32, so the pack always lines up whatever
            # bit-length the (possibly pad-grown) unique table needs —
            # and crop the extra columns after the matmul
            q = layer.decoded_weights().T            # (N_in, M_out) int8
            pad = (-q.shape[1]) % 32
            if pad:
                q = np.pad(q, ((0, 0), (0, pad)))
            packed = pack_unique(q, float(np.asarray(layer.code.scale)),
                                 dtype=jnp.float32)
            layer._mm_packed = packed
        m = layer.code.shape[0]
        y = codr_matmul(jnp.asarray(x, jnp.float32), packed)[:, :m]
        return _finish(layer, y)


register(TiledBackend())
register(SmmBackend())
register(SmmKernelBackend())
register(CodrMatmulBackend())
