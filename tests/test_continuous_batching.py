"""Continuous-batching invariants: per-request bit-identity to solo
decode (no cross-slot leakage), join-mid-stream, EOS retirement freeing
slots, the join-deadline trigger with a half-full pool, streaming
iteration, and stop/drain semantics.

Bit-exactness tests use the dense qwen2.5-3b smoke variant: MoE decode
uses a scatter-add whose per-token summation order varies with the
co-resident token set, so only dense models guarantee identical float
bits under different slot occupancy.
"""
import threading
import time
from concurrent import futures

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.batching import ContinuousBatcher

ARCH = "qwen2.5-3b"


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config(ARCH))
    from repro.models import get_model
    params = get_model(cfg).init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in lens]


def test_cache_slot_helpers_roundtrip(setup):
    """diff_axes finds each leaf's slot axis structurally; write_slot /
    read_slot round-trip a batch-1 cache through the pool, including
    short-seq prefill caches landing at offset 0."""
    cfg, _ = setup
    from repro.models import get_model
    from repro.models.cache import diff_axes, read_slot, write_slot

    api = get_model(cfg)
    axes = diff_axes(jax.eval_shape(lambda: api.init_cache(cfg, 1, 16)),
                     jax.eval_shape(lambda: api.init_cache(cfg, 2, 16)))
    pool = api.init_cache(cfg, 3, 16)
    one = jax.tree.map(lambda l: jax.random.normal(
        jax.random.PRNGKey(0), l.shape, l.dtype),
        api.init_cache(cfg, 1, 16))
    pool = write_slot(pool, one, 1, axes)
    back = read_slot(pool, 1, axes)
    for a, b in zip(jax.tree.leaves(one), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # untouched slots stay zero
    for leaf in jax.tree.leaves(read_slot(pool, 0, axes)):
        assert not np.asarray(leaf, np.float32).any()
    # a shorter-seq cache (prefill at P=5) writes at offset 0
    import jax.numpy as jnp
    short = jax.tree.map(lambda l: jnp.ones(l.shape, l.dtype),
                         jax.eval_shape(lambda: api.init_cache(cfg, 1, 5)))
    pool = write_slot(pool, short, 2, axes)
    assert np.isfinite(np.asarray(
        jax.tree.leaves(read_slot(pool, 2, axes))[0], np.float32)).all()
    # identical shapes leave no discoverable slot axis — rejected
    with pytest.raises(ValueError, match="one differing axis"):
        diff_axes(jax.eval_shape(lambda: api.init_cache(cfg, 1, 16)),
                  jax.eval_shape(lambda: api.init_cache(cfg, 1, 16)))


def test_no_cross_slot_leakage_bit_identical_to_solo(setup):
    """Four mixed-length co-resident requests each produce exactly the
    tokens AND logits bits of their own solo decode — neighbour slots
    and stale cache tails contribute nothing."""
    cfg, params = setup
    cb = ContinuousBatcher(params, cfg, n_slots=4, max_len=32,
                           record_logits=True)
    prompts = _prompts(cfg, [3, 5, 4, 7])
    handles = [cb.submit(p, max_new_tokens=6) for p in prompts]
    outs = [h.result(timeout=300) for h in handles]
    cb.stop_async()
    for p, h, out in zip(prompts, handles, outs):
        ref_toks, ref_rows = cb.generate_reference(
            p, max_new_tokens=6, record_logits=True)
        assert out == ref_toks
        assert h.finish_reason == "length"
        assert len(h.logits) == len(ref_rows)
        for got, ref in zip(h.logits, ref_rows):
            np.testing.assert_array_equal(got, ref)


def test_packed_params_bit_identical_to_solo(setup):
    """Same invariant serving from the packed representation (the exact
    decode-then-matmul lane), params compiled once for both paths."""
    cfg, params = setup
    import repro.api as codr
    compiled = codr.compile_params(params, codr.EncodeConfig(n_unique=16),
                                   backend="tiled")
    cb = ContinuousBatcher(compiled, cfg, n_slots=3, max_len=24)
    prompts = _prompts(cfg, [4, 6, 5], seed=1)
    handles = [cb.submit(p, max_new_tokens=4) for p in prompts]
    outs = [h.result(timeout=300) for h in handles]
    cb.stop_async()
    for p, out in zip(prompts, outs):
        ref_toks, _ = cb.generate_reference(p, max_new_tokens=4)
        assert out == ref_toks


def test_join_mid_stream(setup):
    """A request submitted while another is mid-decode joins the pool
    and both finish with their solo-reference outputs."""
    cfg, params = setup
    cb = ContinuousBatcher(params, cfg, n_slots=2, max_len=32)
    p1, p2 = _prompts(cfg, [4, 6], seed=2)
    h1 = cb.submit(p1, max_new_tokens=10)
    # stream h1 until a few tokens are out, then join h2 mid-stream
    it = iter(h1)
    first = [next(it) for _ in range(3)]
    h2 = cb.submit(p2, max_new_tokens=5)
    rest = list(it)
    out2 = h2.result(timeout=300)
    cb.stop_async()
    ref1, _ = cb.generate_reference(p1, max_new_tokens=10)
    ref2, _ = cb.generate_reference(p2, max_new_tokens=5)
    assert first + rest == ref1
    assert out2 == ref2


def test_eos_retirement_frees_slot(setup):
    """A request hitting its EOS token retires early and frees the slot
    for a later admission (more requests than slots all complete)."""
    cfg, params = setup
    cb = ContinuousBatcher(params, cfg, n_slots=1, max_len=32)
    prompt = _prompts(cfg, [5], seed=3)[0]
    ref, _ = cb.generate_reference(prompt, max_new_tokens=8)
    eos = ref[2]                       # an actually-emitted token → early stop
    h = cb.submit(prompt, max_new_tokens=8, eos_id=eos)
    out = h.result(timeout=300)
    assert h.finish_reason == "eos"
    assert out == ref[:3]              # stops AT the eos token, inclusive
    # the slot is free again: a second request on the 1-slot pool runs
    h2 = cb.submit(prompt, max_new_tokens=4)
    assert h2.result(timeout=300) == ref[:4]
    assert cb.requests_finished == 2
    cb.stop_async()


def test_join_deadline_half_full_pool(setup):
    """With join_deadline_s set and a half-full pool, decode proceeds
    after the deadline even though no co-rider ever joins."""
    cfg, params = setup
    cb = ContinuousBatcher(params, cfg, n_slots=4, max_len=32,
                           join_deadline_s=0.05)
    prompts = _prompts(cfg, [4, 5], seed=4)
    handles = [cb.submit(p, max_new_tokens=4) for p in prompts]
    outs = [h.result(timeout=300) for h in handles]   # resolves ⇒ fired
    assert cb.peak_active == 2                        # pool never filled
    cb.stop_async()
    for p, out in zip(prompts, outs):
        ref, _ = cb.generate_reference(p, max_new_tokens=4)
        assert out == ref


def test_prompt_too_long_rejected(setup):
    cfg, params = setup
    cb = ContinuousBatcher(params, cfg, n_slots=2, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        cb.submit(np.arange(10), max_new_tokens=8)
    with pytest.raises(ValueError, match="empty"):
        cb.submit(np.zeros((0,), np.int32))


def test_submit_exact_fit_boundary(setup):
    """prompt_len + max_new_tokens == max_len exactly fills the KV slot
    and must be admitted; one more token would overflow mid-stream and
    the rejection names both contributions."""
    cfg, params = setup
    cb = ContinuousBatcher(params, cfg, n_slots=1, max_len=16)
    prompt = _prompts(cfg, [12], seed=8)[0]
    h = cb.submit(prompt, max_new_tokens=4)        # 12 + 4 == 16: fits
    out = h.result(timeout=300)
    cb.stop_async()
    ref, _ = cb.generate_reference(prompt, max_new_tokens=4)
    assert out == ref
    with pytest.raises(ValueError,
                       match=r"prompt_len 12 \+ max_new_tokens 5 = 17"):
        cb.submit(prompt, max_new_tokens=5)        # off by one: rejected
    with pytest.raises(ValueError, match="overflow its KV slot"):
        cb.submit(prompt, max_new_tokens=5)


def test_worker_crash_mid_generation_fails_handles_no_hang(setup):
    """Killing the worker loop after partial streaming must _fail every
    live handle — result() raises WorkerCrashed instead of hanging —
    while the tokens already streamed stay readable, and the batcher
    restarts lazily on the next submit."""
    from repro.runtime import resilience as res

    cfg, params = setup
    cb = ContinuousBatcher(params, cfg, n_slots=2, max_len=32)
    # worker-loop call 3: after admission + a couple of decode rounds,
    # i.e. mid-generation with partial output already streamed
    cb.configure_resilience(injector=res.FaultInjector(res.FaultPlan(
        [res.Fault("batcher.worker", 3, "crash")])))
    prompts = _prompts(cfg, [4, 5], seed=9)
    handles = [cb.submit(p, max_new_tokens=12) for p in prompts]
    for h in handles:
        with pytest.raises(res.WorkerCrashed):
            h.result(timeout=60)               # raises; never hangs
    assert all(h.done() for h in handles)
    assert all(h.finish_reason == "error" for h in handles)
    assert cb.worker_crashes == 1
    # partial stream survives the crash and matches the solo prefix
    for p, h in zip(prompts, handles):
        ref, _ = cb.generate_reference(p, max_new_tokens=12)
        assert h.tokens == ref[:len(h.tokens)]
    # lazy restart: the crash fault is consumed, a fresh submit serves
    h2 = cb.submit(prompts[0], max_new_tokens=3)
    out = h2.result(timeout=300)
    cb.stop_async()
    ref, _ = cb.generate_reference(prompts[0], max_new_tokens=3)
    assert out == ref


def test_stop_drain_false_cancels_and_restart(setup):
    """drain=False cancels pending and in-flight handles; the batcher
    restarts lazily on the next submit."""
    cfg, params = setup
    cb = ContinuousBatcher(params, cfg, n_slots=1, max_len=64)
    prompts = _prompts(cfg, [4, 4, 4], seed=5)
    handles = [cb.submit(p, max_new_tokens=40) for p in prompts]
    cb.stop_async(drain=False)
    for h in handles:
        with pytest.raises((futures.CancelledError, Exception)):
            h.result(timeout=60)
    assert all(h.finish_reason in ("cancelled", "error") for h in handles)
    # submitting while stopped restarts the worker
    h2 = cb.submit(prompts[0], max_new_tokens=3)
    out = h2.result(timeout=300)
    cb.stop_async()
    ref, _ = cb.generate_reference(prompts[0], max_new_tokens=3)
    assert out == ref


def test_streaming_iteration_yields_incrementally(setup):
    """Iterating a handle observes tokens before generation completes
    (the stream is not a post-hoc replay of the final result)."""
    cfg, params = setup
    cb = ContinuousBatcher(params, cfg, n_slots=1, max_len=64)
    prompt = _prompts(cfg, [4], seed=6)[0]
    h = cb.submit(prompt, max_new_tokens=20)
    it = iter(h)
    first = next(it)
    assert not h.done()                # stream delivered before finish
    rest = list(it)
    assert h.done()
    assert [first] + rest == h.result(timeout=10)
    cb.stop_async()


def test_encdec_rejected():
    cfg = smoke_variant(get_config("seamless-m4t-medium"))
    with pytest.raises(NotImplementedError, match="decoder-only"):
        ContinuousBatcher({}, cfg)


def test_concurrent_submitters_all_served(setup):
    """Handles submitted from multiple threads all resolve with unique
    ids — the submit path is locked."""
    cfg, params = setup
    cb = ContinuousBatcher(params, cfg, n_slots=4, max_len=24)
    prompt = _prompts(cfg, [4], seed=7)[0]      # one prompt, submitted 8×
    handles: list = []
    lock = threading.Lock()

    def worker():
        h = cb.submit(prompt, max_new_tokens=3)
        with lock:
            handles.append(h)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    outs = [h.result(timeout=300) for h in handles]
    cb.stop_async()
    assert sorted(h.rid for h in handles) == list(range(8))
    ref, _ = cb.generate_reference(prompt, max_new_tokens=3)
    assert all(o == ref for o in outs)      # identical prompts, same bits
