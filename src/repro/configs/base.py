"""Model/run configuration schema shared by all architectures."""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm_type: str = "rmsnorm"
    act: str = "silu"
    tied_embeddings: bool = False
    # MLA (DeepSeek-V2)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    nope_head_dim: int = 0
    rope_head_dim: int = 0
    v_head_dim: int = 0
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1             # MoE on layers with idx % moe_every == moe_offset
    moe_offset: int = 0
    n_dense_layers: int = 0        # leading non-scanned dense layers
    # heterogeneous layer pattern — one period, scanned n_period times
    block_pattern: tuple = ("attn",)
    # SSM (Mamba)
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    # encoder-decoder
    n_encoder_layers: int = 0
    # modality frontend stub: embeddings come precomputed via input_specs()
    frontend: str | None = None    # "audio" | "vision"
    frontend_seq: int = 0
    # attention chunking (flash-style scan block sizes)
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    # numerics / scan
    remat: bool = True
    sub_quadratic: bool = False    # can run long_500k
    mamba_chunk: int = 256
    # ---- perf levers (baseline = defaults; measured via the roofline
    # ---- report, see benchmarks/roofline.py) ----
    decode_attn: str = "naive"     # "dist" = sequence-parallel softmax
    moe_decode_2d: bool = False    # 2-D expert sharding for decode
    attn_f32: bool = True          # False = bf16 score/accum buffers
    norm_f32: bool = True          # False = f32 stats, bf16 normalize

    @property
    def n_scanned_layers(self) -> int:
        return self.n_layers - self.n_dense_layers

    @property
    def n_periods(self) -> int:
        period = len(self.block_pattern)
        assert self.n_scanned_layers % period == 0, \
            (self.name, self.n_scanned_layers, period)
        return self.n_scanned_layers // period

    def layer_plan(self) -> list[tuple[str, str]]:
        """Per-period plan: [(mixer_kind, ffn_kind)] where ffn_kind is
        'dense' | 'moe' | 'none'."""
        plan = []
        period = len(self.block_pattern)
        for i, kind in enumerate(self.block_pattern):
            gidx = self.n_dense_layers + i         # same for every period
            if kind in ("mlstm", "slstm"):
                ffn = "none" if self.d_ff == 0 else "dense"
            elif self.n_experts and gidx % self.moe_every == self.moe_offset:
                ffn = "moe"
            else:
                ffn = "dense"
            plan.append((kind, ffn))
        # uniformity check: the plan must repeat identically every period
        if self.n_experts and self.n_periods > 1:
            assert period % self.moe_every == 0 or self.moe_every == 1, \
                f"{self.name}: moe_every must divide the pattern period"
        return plan

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks)."""
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tied_embeddings else 2)
        per_layer = 0.0
        plan = self.layer_plan()
        total = emb
        for kind, ffn in plan:
            per_layer = 0
            if kind == "attn":
                if self.use_mla:
                    per_layer += d * self.q_lora_rank \
                        + self.q_lora_rank * self.n_heads * (self.nope_head_dim + self.rope_head_dim) \
                        + d * (self.kv_lora_rank + self.rope_head_dim) \
                        + self.kv_lora_rank * self.n_heads * (self.nope_head_dim + self.v_head_dim) \
                        + self.n_heads * self.v_head_dim * d
                else:
                    per_layer += d * self.head_dim * (self.n_heads + 2 * self.n_kv_heads) \
                        + self.n_heads * self.head_dim * d
            elif kind == "mamba":
                d_in = self.ssm_expand * d
                per_layer += d * 2 * d_in + d_in * (d // 16 + 2 * self.ssm_d_state) \
                    + (d // 16) * d_in + d_in * d
            elif kind == "mlstm":
                d_up = 2 * d
                per_layer += d * 2 * d_up + 3 * d_up * d_up + d_up * d
            elif kind == "slstm":
                per_layer += d * 4 * d + d * 4 * (d // self.n_heads) + d * d
            if ffn == "dense":
                per_layer += 3 * d * self.d_ff
            elif ffn == "moe":
                per_layer += d * self.n_experts + 3 * self.n_experts * d * self.moe_d_ff
                per_layer += 3 * d * self.moe_d_ff * self.n_shared_experts
            total += per_layer * self.n_periods
        # prologue dense layers
        if self.n_dense_layers:
            att = d * self.head_dim * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * self.head_dim * d
            if self.use_mla:
                att = d * self.q_lora_rank \
                    + self.q_lora_rank * self.n_heads * (self.nope_head_dim + self.rope_head_dim) \
                    + d * (self.kv_lora_rank + self.rope_head_dim) \
                    + self.kv_lora_rank * self.n_heads * (self.nope_head_dim + self.v_head_dim) \
                    + self.n_heads * self.v_head_dim * d
            total += self.n_dense_layers * (att + 3 * d * self.d_ff)
        if self.n_encoder_layers:
            att = d * self.head_dim * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * self.head_dim * d
            total += self.n_encoder_layers * (att + 2 * d * self.d_ff)
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        plan = self.layer_plan()
        n_moe_layers = sum(1 for _, f in plan if f == "moe") * self.n_periods
        all_routed = 3 * self.n_experts * self.d_model * self.moe_d_ff
        active_routed = 3 * self.moe_top_k * self.d_model * self.moe_d_ff
        return int(full - n_moe_layers * (all_routed - active_routed))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
