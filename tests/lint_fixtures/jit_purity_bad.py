"""codrlint fixture: every traced body here violates jit-purity."""
import jax
import jax.numpy as jnp
import numpy as np
import time


@jax.jit
def bad_decorated(x):
    y = np.asarray(x)               # host NumPy inside the trace
    print("tracing")                # host sync
    return jnp.sum(y)


@jax.jit
def bad_coercions(x):
    v = float(x)                    # device sync
    n = x.item()                    # device sync
    return v + n


def bad_scan(xs):
    def body(carry, x):
        t = time.monotonic()        # wall clock burned into the trace
        carry.count = 1             # attribute mutation side effect
        return carry + x, t
    return jax.lax.scan(body, 0.0, xs)


def bad_lambda(x):
    return jax.jit(lambda t: np.square(t))(x)
