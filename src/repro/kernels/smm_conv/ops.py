"""Wrapper + offline operand packer for the SMM convolution kernel."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.smm import decode_index
from repro.core.ucr import LayerCode
from repro.kernels.smm_conv.kernel import smm_conv_pallas

# Capability facts consumed by the backend registry
# (repro.core.backends.SmmKernelBackend) — kept next to the kernel so the
# registry never hardcodes what a kernel can lower.
KERNEL_CAPS = {
    "kinds": ("conv",),            # this kernel only lowers convolutions
    "max_stride": None,            # native strided crossbar routing
    "integer_activations": True,   # 8-bit feature datapath (exact int math)
    "batched_grid": True,          # batch = leading grid dimension
    "interpret_on_cpu": True,
    "description": "Pallas MPE/APE SMM convolution (batched grid; "
                   "interpret mode off-TPU)",
}


def pack_smm_operands(code: LayerCode, n_in: int
                      ) -> tuple[np.ndarray, np.ndarray, dict]:
    """UCR vectors → padded static-shape kernel operands.

    Returns ``(deltas, entries, meta)``:
      deltas  (m_tiles, N, U_max+1) float32 — Δs of sorted unique weights
      entries (m_tiles, N, L_max, 4) int32 — (u, m_local, r, c) per
              repetition; padding → (U_max, 0, 0, 0) = zero product row.
    """
    m = code.shape[0]
    rk, ck = (code.shape[2], code.shape[3]) if len(code.shape) == 4 else (1, 1)
    m_tiles = -(-m // code.t_m)
    u_max = max((len(u.unique_vals) for u in code.ucr), default=1) or 1
    l_max = max((len(u.indexes) for u in code.ucr), default=1) or 1

    deltas = np.zeros((m_tiles, n_in, u_max + 1), dtype=np.float32)
    entries = np.zeros((m_tiles, n_in, l_max, 4), dtype=np.int32)
    entries[:, :, :, 0] = u_max                     # point at the zero row

    for vi, u in enumerate(code.ucr):
        mt, nn = vi // n_in, vi % n_in
        vals = u.unique_vals.astype(np.float32)
        deltas[mt, nn, : len(vals)] = np.diff(vals, prepend=0.0)
        cursor = 0
        li = 0
        for ui, rep in enumerate(u.reps):
            for idx in u.indexes[cursor : cursor + int(rep)]:
                m_loc, r, c = decode_index(int(idx), (rk, ck))
                entries[mt, nn, li] = (ui, m_loc, r, c)
                li += 1
            cursor += int(rep)
    return deltas, entries, {"m_tiles": m_tiles, "t_m": code.t_m,
                             "u_max": u_max, "l_max": l_max}


def smm_conv_batched(x: jax.Array, code: LayerCode, *, stride: int = 1,
                     interpret: bool | None = None,
                     operands: tuple | None = None) -> jax.Array:
    """Batched CoDR SMM convolution: ``x`` (B, N, RI, CI) → (B, M, RO, CO).

    The whole batch runs in ONE Pallas dispatch (batch = leading grid
    dimension — no per-sample Python loop).  Pass ``operands`` (the
    ``(deltas, entries, meta)`` triple from :func:`pack_smm_operands`,
    device arrays) to reuse a layer's packed operands across calls — the
    engine caches them per layer; otherwise they are packed here.

    ``stride`` is routed into the kernel as strided crossbar window loads.
    Should a backend reject that lowering (Pallas cannot express strided
    dynamic slices everywhere), the call falls back to the reference SMM
    implementation (:func:`repro.core.smm.conv2d_smm_batched` — bit-exact,
    slower).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if stride < 1:
        raise ValueError(f"stride must be >= 1, got {stride}")
    _, n_in, ri, ci = x.shape
    rk, ck = (code.shape[2], code.shape[3]) if len(code.shape) == 4 else (1, 1)
    ro, co = (ri - rk) // stride + 1, (ci - ck) // stride + 1
    if operands is None:
        deltas, entries, meta = pack_smm_operands(code, n_in)
        deltas, entries = jnp.asarray(deltas), jnp.asarray(entries)
    else:
        deltas, entries, meta = operands
    try:
        y = smm_conv_pallas(jnp.asarray(x, jnp.float32), deltas, entries,
                            t_m=meta["t_m"], ro=ro, co=co, stride=stride,
                            interpret=interpret)
    except NotImplementedError:
        from repro.core.smm import conv2d_smm_batched
        y = jnp.asarray(conv2d_smm_batched(
            np.rint(np.asarray(x)).astype(np.int64), code, stride),
            jnp.float32)
    return y[:, : code.shape[0]]


def smm_conv(x: jax.Array, code: LayerCode, *, stride: int = 1,
             interpret: bool | None = None) -> jax.Array:
    """CoDR SMM convolution of ``x`` (N, RI, CI) with an encoded layer.
    Returns pre-activation int-exact accumulations (float32), cropped to
    the true output-channel count."""
    return smm_conv_batched(x[None], code, stride=stride,
                            interpret=interpret)[0]
