"""The packed checkpoint artifact: compress once offline, mmap at boot.

``save_packed(compiled, path)`` serializes a
:class:`repro.core.api.CompiledParams` — the packed bitstreams
(``PackedWeight`` indices/tables/scales for every ``PackedLinear`` /
``PackedEmbedding`` leaf), the remaining dense leaves, the
:class:`~repro.core.api.EncodeConfig`, the per-tensor accounting
reports, and the :class:`repro.tune.TunePlan` (when one drove the
compile) — into one directory:

* ``manifest.json`` — format version, config, tree skeleton (a
  recursive dict/list/tuple/leaf encoding, so no ``treedef`` string
  parsing), per-array dtype/shape, paths, plan, reports.
* ``arr_N.npy`` — one file per array child, loadable with
  ``np.load(mmap_mode="r")`` so boot maps the bitstreams instead of
  copying them (bfloat16 is stored as a uint16 view and re-viewed on
  load — ``.npy`` round-trips it as raw void bytes otherwise).

Writes are atomic (CheckpointManager idiom): everything lands in
``<path>.tmp``, the manifest is fsync'd, then one ``os.rename``
publishes the artifact — a crash mid-save never leaves a readable but
corrupt checkpoint.  ``load_packed`` is the exact inverse; loaded
params produce **bit-identical** logits to the in-memory
``compile_params`` result (the arrays round-trip byte-for-byte).

``CODR_FORMAT_VERSION`` stamps every artifact; readers reject other
versions with :class:`PackedCheckpointError`.  The golden-bitstream
suite (``tests/test_golden_formats.py``) pins the byte layout — bump
the version and regenerate via ``tools/regen_goldens.py`` when the
format changes (docs/DESIGN.md §2.2).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

CODR_FORMAT_VERSION = 1
_MAGIC = "codr-packed"


class PackedCheckpointError(ValueError):
    """A packed checkpoint is unreadable: missing/truncated files,
    format-version mismatch, or on-disk bytes that contradict the
    manifest (wrong dtype/shape)."""


# ---------------------------------------------------------------------------
# tree <-> manifest encoding
# ---------------------------------------------------------------------------

def _encode_tree(node, arrays: list):
    """Recursively encode a params pytree into JSON nodes + an array
    list.  Handles dict/list/tuple containers and PackedLinear /
    PackedEmbedding / array leaves — the full vocabulary of a
    ``CompiledParams.params`` tree."""
    from repro.core.codr_linear import (PackedEmbedding, PackedLinear,
                                        PackedWeight)

    def ref(x):
        arrays.append(np.asarray(x))
        return len(arrays) - 1

    def enc_pw(pw: PackedWeight) -> dict:
        return {"packed": ref(pw.packed), "table": ref(pw.table),
                "scale": ref(pw.scale), "bits": int(pw.bits),
                "shape": [int(s) for s in pw.shape]}

    if isinstance(node, PackedLinear):
        return {"kind": "packed_linear", "weight": enc_pw(node.weight),
                "out_features": int(node.out_features),
                "backend": node.backend}
    if isinstance(node, PackedEmbedding):
        return {"kind": "packed_embedding", "weight": enc_pw(node.weight),
                "d_model": int(node.d_model), "backend": node.backend}
    if isinstance(node, dict):
        return {"kind": "dict",
                "items": {k: _encode_tree(v, arrays)
                          for k, v in node.items()}}
    if isinstance(node, (list, tuple)):
        return {"kind": "list" if isinstance(node, list) else "tuple",
                "items": [_encode_tree(v, arrays) for v in node]}
    return {"kind": "array", "ref": ref(node)}


def _decode_tree(node: dict, arrays: list):
    from repro.core.codr_linear import (PackedEmbedding, PackedLinear,
                                        PackedWeight)

    def dec_pw(d: dict) -> PackedWeight:
        return PackedWeight(packed=arrays[d["packed"]],
                            table=arrays[d["table"]],
                            scale=arrays[d["scale"]],
                            bits=int(d["bits"]),
                            shape=tuple(d["shape"]))

    kind = node["kind"]
    if kind == "packed_linear":
        return PackedLinear(dec_pw(node["weight"]),
                            out_features=int(node["out_features"]),
                            backend=node["backend"])
    if kind == "packed_embedding":
        return PackedEmbedding(dec_pw(node["weight"]),
                               d_model=int(node["d_model"]),
                               backend=node["backend"])
    if kind == "dict":
        return {k: _decode_tree(v, arrays)
                for k, v in node["items"].items()}
    if kind == "list":
        return [_decode_tree(v, arrays) for v in node["items"]]
    if kind == "tuple":
        return tuple(_decode_tree(v, arrays) for v in node["items"])
    if kind == "array":
        return arrays[node["ref"]]
    raise PackedCheckpointError(f"unknown tree node kind {kind!r}")


_BF16 = "bfloat16"


def _array_meta(a: np.ndarray) -> dict:
    return {"dtype": str(a.dtype), "shape": list(a.shape)}


def _save_array(path: str, a: np.ndarray) -> None:
    if str(a.dtype) == _BF16:
        a = a.view(np.uint16)     # .npy cannot round-trip bfloat16
    np.save(path, a)


def _load_array(path: str, meta: dict, *, mmap: bool):
    try:
        a = np.load(path, mmap_mode="r" if mmap else None)
    except Exception as e:
        raise PackedCheckpointError(
            f"packed checkpoint array {os.path.basename(path)} is "
            f"unreadable (truncated or corrupt): {e}") from e
    if meta["dtype"] == _BF16:
        if a.dtype != np.uint16:
            raise PackedCheckpointError(
                f"{os.path.basename(path)}: expected uint16 storage for "
                f"a bfloat16 array, found {a.dtype}")
        a = a.view(np.dtype(jnp.bfloat16))
    elif str(a.dtype) != meta["dtype"]:
        raise PackedCheckpointError(
            f"{os.path.basename(path)}: on-disk dtype {a.dtype} does not "
            f"match the manifest's {meta['dtype']} — the artifact is "
            f"corrupt or was written by an incompatible encoder")
    if list(a.shape) != meta["shape"]:
        raise PackedCheckpointError(
            f"{os.path.basename(path)}: on-disk shape {list(a.shape)} "
            f"does not match the manifest's {meta['shape']}")
    return a


# ---------------------------------------------------------------------------
# save / load
# ---------------------------------------------------------------------------

def build_manifest(compiled) -> tuple[dict, list]:
    """Pure encoding half of :func:`save_packed`: returns
    ``(manifest, host_arrays)`` without touching the filesystem (the
    golden-format tests pin these bytes directly)."""
    arrays: list[np.ndarray] = []
    tree = _encode_tree(compiled.params, arrays)
    plan = getattr(compiled, "plan", None)
    manifest = {
        "magic": _MAGIC,
        "format_version": CODR_FORMAT_VERSION,
        "config": compiled.config.metadata(),
        "backend": compiled.backend,
        "packed_paths": list(compiled.packed_paths),
        "quantized_paths": list(compiled.quantized_paths),
        "embed_paths": list(getattr(compiled, "embed_paths", [])),
        "reports": [dataclasses.asdict(r) for r in compiled.reports],
        "plan": plan.to_json() if plan is not None else None,
        "tree": tree,
        "arrays": [_array_meta(a) for a in arrays],
    }
    return manifest, arrays


def save_packed(compiled, path: str) -> str:
    """Write ``compiled`` (a :class:`repro.core.api.CompiledParams`) as
    a packed checkpoint directory at ``path``.  Atomic: a crash leaves
    either the previous artifact or none.  Returns ``path``."""
    manifest, arrays = build_manifest(compiled)
    tmp = str(path) + ".tmp"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    for i, a in enumerate(arrays):
        _save_array(os.path.join(tmp, f"arr_{i}.npy"), a)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    shutil.rmtree(str(path), ignore_errors=True)
    os.rename(tmp, str(path))
    return str(path)


def load_packed(path: str, *, mmap: bool = True):
    """Load a packed checkpoint back into a
    :class:`repro.core.api.CompiledParams` — bit-identical to the
    object :func:`save_packed` was given (same packed bytes, same
    logits).  ``mmap=True`` maps the array files instead of copying;
    JAX copies pages to device lazily on first dispatch."""
    from repro.core.api import CompiledParams, EncodeConfig
    from repro.core.serving import TensorReport

    mpath = os.path.join(str(path), "manifest.json")
    if not os.path.isdir(str(path)) or not os.path.exists(mpath):
        raise PackedCheckpointError(
            f"{path!r} is not a packed checkpoint (no manifest.json) — "
            f"write one with codr.save_packed(compiled, path)")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except json.JSONDecodeError as e:
        raise PackedCheckpointError(
            f"{path!r}: manifest.json is not valid JSON (truncated "
            f"write?): {e}") from e
    if manifest.get("magic") != _MAGIC:
        raise PackedCheckpointError(
            f"{path!r}: bad magic {manifest.get('magic')!r} — not a "
            f"codr packed checkpoint")
    ver = manifest.get("format_version")
    if ver != CODR_FORMAT_VERSION:
        raise PackedCheckpointError(
            f"{path!r}: format version {ver} but this build reads "
            f"version {CODR_FORMAT_VERSION} — re-encode the checkpoint "
            f"with codr.save_packed (see CODR_FORMAT_VERSION in "
            f"repro/checkpoint/packed.py)")
    arrays = []
    for i, meta in enumerate(manifest["arrays"]):
        apath = os.path.join(str(path), f"arr_{i}.npy")
        if not os.path.exists(apath):
            raise PackedCheckpointError(
                f"{path!r}: missing array file arr_{i}.npy (the "
                f"manifest lists {len(manifest['arrays'])} arrays)")
        arrays.append(_load_array(apath, meta, mmap=mmap))
    params = _decode_tree(manifest["tree"], arrays)
    plan = None
    if manifest.get("plan") is not None:
        from repro.tune.plan import TunePlan
        plan = TunePlan.from_json(manifest["plan"])
    cfg_d = dict(manifest["config"])
    if cfg_d.get("rle_params") is not None:
        cfg_d["rle_params"] = tuple(cfg_d["rle_params"])
    return CompiledParams(
        params=params,
        reports=[TensorReport(**r) for r in manifest["reports"]],
        packed_paths=list(manifest["packed_paths"]),
        quantized_paths=list(manifest["quantized_paths"]),
        config=EncodeConfig(**cfg_d),
        backend=manifest["backend"],
        plan=plan,
        embed_paths=list(manifest.get("embed_paths", [])))
