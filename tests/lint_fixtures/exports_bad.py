"""codrlint fixture: stale __all__ entry and a dangling re-export."""
from repro.core.serving import NoSuchSymbolXYZ  # noqa: F401 — dangling

__all__ = ["exported_fn", "never_defined_name"]


def exported_fn():
    return 1
