"""AdamW with optional fp32 master weights (mixed-precision training:
bf16 params in the forward, fp32 master + moments in the optimizer state,
all sharded like the params — ZeRO-style under the FSDP axis)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    use_master: bool = True        # keep fp32 master copy of bf16 params


def adamw_init(params, cfg: AdamWConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.use_master:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr: jax.Array | float | None = None):
    """Returns (new_params, new_state, metrics)."""
    lr = cfg.lr if lr is None else lr
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state["v"], grads)
    masters = state.get("master", params)

    def upd(p32, m, v):
        mhat = m / b1c
        vhat = v / b2c
        return (p32.astype(jnp.float32)
                - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                        + cfg.weight_decay * p32.astype(jnp.float32)))

    new_master = jax.tree.map(upd, masters, new_m, new_v)
    new_params = jax.tree.map(
        lambda nm, p: nm.astype(p.dtype), new_master, params)
    new_state = {"m": new_m, "v": new_v, "step": step}
    if cfg.use_master:
        new_state["master"] = new_master
    return new_params, new_state, {"grad_norm": gnorm, "step": step}
