"""codrlint fixture: Backend subclasses whose caps lie.

Never imported — Backend/BackendCaps are resolved statically by name.
"""


class NoNameBackend(Backend):                       # noqa: F821
    caps = BackendCaps(packed_matmul=False)         # noqa: F821

    def matmul(self, a, b):                 # override without the flag
        return a @ b


class DeadKindBackend(Backend):                     # noqa: F821
    name = "fixture-dead"
    caps = BackendCaps(packed_matmul=True,          # noqa: F821
                       native_kinds=frozenset({"gather"}))

    def matmul(self, a, b):
        return a @ b

    def gather(self, table, idx):
        raise NotImplementedError           # claimed native, cannot run


class DupNameA(Backend):                            # noqa: F821
    name = "fixture-dup"
    caps = BackendCaps(packed_matmul=False)         # noqa: F821


class DupNameB(Backend):                            # noqa: F821
    name = "fixture-dup"
    caps = BackendCaps(packed_matmul=False)         # noqa: F821


KERNEL_CAPS = {"kinds": ("conv",)}      # missing required keys
