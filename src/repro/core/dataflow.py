"""CoDR dataflow engine: tiling, loop ordering, and SRAM access counting
(paper §III-B, §IV, Table I, Figs. 5/7).

These are analytical loop-nest access counters (the paper uses a
cycle-accurate simulator; the loop-nest algebra below counts the same
events — every SRAM/RF touch implied by the stationarity of each
dataflow).  Counts feed :mod:`repro.core.cost_model` for the Fig. 7/8
reproductions.

Dataflow summaries (per the paper):

* **CoDR** — fully output stationary (each output feature written once) and
  semi input stationary (inputs fetched ``ceil(M / (T_PU*T_M))`` times);
  weights re-streamed per spatial output tile — cheap, they are RLE
  compressed to ~1.69 bits/weight and read in wide sequential rows.
* **UCNN** — dot-product dataflow; partial sums accumulate in SRAM across
  input-channel tiles (outputs touched ~2*ceil(N/T_N) times), inputs
  re-fetched per kernel window overlap.
* **SCNN** — input stationary (inputs read once); scattered partial-sum
  crossbar traffic hits the output SRAM read+write per input-channel step.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["ConvShape", "TilingConfig", "CODR_TILING", "UCNN_TILING",
           "SCNN_TILING", "AccessCounts", "codr_accesses", "ucnn_accesses",
           "scnn_accesses", "codr_tiling"]


@dataclasses.dataclass(frozen=True)
class ConvShape:
    m: int                  # output channels
    n: int                  # input channels
    rk: int                 # kernel rows
    ck: int                 # kernel cols
    ri: int                 # input rows
    ci: int                 # input cols
    stride: int = 1

    @property
    def ro(self) -> int:
        return (self.ri - self.rk) // self.stride + 1

    @property
    def co(self) -> int:
        return (self.ci - self.ck) // self.stride + 1

    @property
    def n_weights(self) -> int:
        return self.m * self.n * self.rk * self.ck

    @property
    def n_outputs(self) -> int:
        return self.m * self.ro * self.co

    @property
    def n_inputs(self) -> int:
        return self.n * self.ri * self.ci

    @property
    def macs(self) -> int:
        return self.n_outputs * self.n * self.rk * self.ck


@dataclasses.dataclass(frozen=True)
class TilingConfig:
    """Table I RTL tiling parameters."""

    name: str
    t_pu: int
    t_m: int
    t_n: int
    t_ro: int
    t_co: int
    t_ri: int
    t_ci: int
    mults_per_pu: int
    weight_row_bits: int = 64   # weight SRAM streams wide sequential rows


CODR_TILING = TilingConfig("CoDR", 8, 4, 4, 8, 8, 20, 20, 64)
UCNN_TILING = TilingConfig("UCNN", 48, 1, 4, 1, 8, 1, 12, 8)
SCNN_TILING = TilingConfig("SCNN", 21, 2, 1, 1, 1, 1, 1, 16)


def codr_tiling(t_m: int | None = None, t_n: int | None = None, *,
                base: TilingConfig = CODR_TILING) -> TilingConfig:
    """A CoDR tiling with per-layer channel-tile overrides — the PU
    count, spatial tiles, and SRAM row width are Table I hardware
    parameters and stay fixed; ``t_m``/``t_n`` are the per-layer encode
    knobs the tuner (:mod:`repro.tune`) sweeps."""
    kw = {}
    if t_m is not None:
        kw["t_m"] = int(t_m)
    if t_n is not None:
        kw["t_n"] = int(t_n)
    return dataclasses.replace(base, **kw) if kw else base


@dataclasses.dataclass
class AccessCounts:
    """All counts are in number of accesses of the stated granularity:
    features are 8-bit word accesses; weight SRAM accesses are wide-row
    reads (``weight_row_bits`` each); RF accesses are 8-bit."""

    name: str
    input_sram: float
    output_sram: float
    weight_sram_rows: float
    weight_bits_streamed: float
    input_rf: float
    weight_rf: float
    output_rf: float
    mults: float
    accums: float
    crossbar: float
    dram_weight_bits: float
    dram_feature_bytes: float

    @property
    def feature_sram(self) -> float:
        return self.input_sram + self.output_sram

    @property
    def total_sram(self) -> float:
        return self.input_sram + self.output_sram + self.weight_sram_rows


def _spatial_tiles(shape: ConvShape, cfg: TilingConfig) -> int:
    return math.ceil(shape.ro / cfg.t_ro) * math.ceil(shape.co / cfg.t_co)


def codr_accesses(shape: ConvShape, cfg: TilingConfig,
                  compressed_bits: float, n_unique: float,
                  n_nonzero: float) -> AccessCounts:
    """CoDR loop ordering (Fig. 5a circled 1–4):

    for m_group in M / (T_PU*T_M):          # ④ outputs written once
      for spatial tile in RO/T_RO × CO/T_CO:  # ③
        for n in N:                           # ② accumulate over inputs
          stream compressed weights           # ① re-streamed per tile
    """
    m_groups = math.ceil(shape.m / (cfg.t_pu * cfg.t_m))
    spatial = _spatial_tiles(shape, cfg)

    output_sram = float(shape.n_outputs)                       # written once
    input_sram = float(shape.n_inputs) * m_groups              # semi-stationary
    weight_bits = compressed_bits * spatial                    # re-streamed
    weight_rows = weight_bits / cfg.weight_row_bits

    # MPE: each unique weight multiplies the halo window its repetitions
    # can address — (T_RO+R_K−1)×(T_CO+C_K−1) lanes (unused tile lanes are
    # gated); APE accumulates one product window per repetition.
    tile_elems = min((cfg.t_ro + shape.rk - 1) * (cfg.t_co + shape.ck - 1),
                     cfg.t_ri * cfg.t_ci)
    out_tile_elems = cfg.t_ro * cfg.t_co
    mults = n_unique * tile_elems * spatial
    accums = n_nonzero * out_tile_elems * spatial
    input_rf = mults                                           # matrix operand reads
    output_rf = 2.0 * accums                                   # read-modify-write
    weight_rf = weight_bits / 8.0                              # decoder feed
    crossbar = accums                                          # MPE→APE routing

    return AccessCounts(
        name=cfg.name, input_sram=input_sram, output_sram=output_sram,
        weight_sram_rows=weight_rows, weight_bits_streamed=weight_bits,
        input_rf=input_rf, weight_rf=weight_rf, output_rf=output_rf,
        mults=mults, accums=accums, crossbar=crossbar,
        dram_weight_bits=compressed_bits,
        dram_feature_bytes=float(shape.n_inputs + shape.n_outputs))


def ucnn_accesses(shape: ConvShape, cfg: TilingConfig,
                  compressed_bits: float, n_unique: float,
                  n_nonzero: float) -> AccessCounts:
    """UCNN dot-product dataflow: activation-group factorized dot products;
    partial sums spill to SRAM across input-channel tiles; inputs re-read
    per overlapping kernel window (T_RI×T_CI = 1×12 buffer only)."""
    n_groups = math.ceil(shape.n / cfg.t_n)
    # outputs: read+write per input-channel group (partial-sum accumulation)
    output_sram = 2.0 * shape.n_outputs * n_groups
    # inputs: 1×T_CI row buffer captures kernel-COLUMN overlap (÷ck) but
    # not row overlap; each output row re-reads its RK rows, amortized
    # over the T_M·T_PU outputs sharing a fetch.
    input_sram = (shape.ro * shape.co * shape.rk * shape.ck * shape.n
                  / max(shape.ck / shape.stride, 1.0)
                  * max(1.0, shape.m / (cfg.t_pu * cfg.t_m)))
    weight_bits = compressed_bits * math.ceil(shape.ro / cfg.t_co)
    weight_rows = weight_bits / cfg.weight_row_bits

    # factorized dot product: one multiply per unique weight per output,
    # adds for every nonzero term.
    mults = n_unique * shape.ro * shape.co
    accums = n_nonzero * shape.ro * shape.co
    return AccessCounts(
        name=cfg.name, input_sram=input_sram, output_sram=output_sram,
        weight_sram_rows=weight_rows, weight_bits_streamed=weight_bits,
        input_rf=accums, weight_rf=weight_bits / 8.0, output_rf=2.0 * mults,
        mults=mults, accums=accums, crossbar=accums,
        dram_weight_bits=compressed_bits,
        dram_feature_bytes=float(shape.n_inputs + shape.n_outputs))


def scnn_accesses(shape: ConvShape, cfg: TilingConfig,
                  compressed_bits: float, n_unique: float,
                  n_nonzero: float) -> AccessCounts:
    """SCNN input-stationary cartesian-product dataflow: inputs read once;
    every nonzero weight × input product is scattered through the crossbar
    into output accumulator banks, spilling partial sums to SRAM per
    input-channel step (T_N = 1)."""
    input_sram = float(shape.n_inputs)                          # stationary
    # psum spills: SCNN's accumulator banks hold one output tile; the
    # cartesian-product scatter revisits outputs once per input-channel
    # step, but an RF-resident fraction (~half) never leaves the banks.
    n_steps = math.ceil(shape.n / cfg.t_n)
    output_sram = 1.0 * shape.n_outputs * n_steps               # psum spills
    weight_bits = compressed_bits
    weight_rows = weight_bits / cfg.weight_row_bits
    density = n_nonzero / max(shape.n_weights, 1)
    mults = shape.macs * density                                # all nonzero
    accums = mults
    return AccessCounts(
        name=cfg.name, input_sram=input_sram, output_sram=output_sram,
        weight_sram_rows=weight_rows, weight_bits_streamed=weight_bits,
        input_rf=mults, weight_rf=weight_bits / 8.0, output_rf=2.0 * mults,
        mults=mults, accums=accums, crossbar=accums,
        dram_weight_bits=compressed_bits,
        dram_feature_bytes=float(shape.n_inputs + shape.n_outputs))
