"""exception-hygiene: broad catches must re-raise, deliver, or log.

The serving stack legitimately catches broad ``Exception`` at isolation
boundaries — a poison batch must fail its own futures, not the worker.
What it must never do is *swallow*: a handler that catches everything
and uses none of it hides real failures (and PR 7's ``InjectedCrash``
semantics depend on broad handlers being exactly ``Exception``-scoped
so ``BaseException`` crashes escape to the supervision net).

Flagged:

* bare ``except:`` — always (it eats ``KeyboardInterrupt`` /
  ``InjectedCrash``; catch ``Exception`` or, at a supervision net,
  ``BaseException`` explicitly);
* ``except Exception`` / ``except BaseException`` handlers that neither
  **re-raise** (a ``raise`` statement anywhere in the handler), nor
  **use the bound exception** (``except ... as e`` with ``e`` read in
  the body — delivering it to a future/handle/record counts), nor
  **log** (a call to ``warnings.warn`` / ``logging`` style
  ``.warning/.error/.exception/...`` / ``print``).

A deliberate swallow (e.g. a best-effort staging fallback) carries a
``# codrlint: disable=exception-hygiene — <why>`` on the handler line.
"""
from __future__ import annotations

import ast

from tools.codrlint.core import (Checker, Finding, ModuleInfo, Project,
                                 dotted_name, register_checker)

BROAD = {"Exception", "BaseException"}
LOG_ATTRS = {"warning", "error", "exception", "critical", "info", "debug",
             "warn", "log"}


def _broad_names(type_node: ast.AST | None) -> list[str]:
    """Broad exception class names caught by this handler ([] if the
    handler is narrow, ['<bare>'] for a bare except)."""
    if type_node is None:
        return ["<bare>"]
    nodes = (type_node.elts if isinstance(type_node, ast.Tuple)
             else [type_node])
    out = []
    for n in nodes:
        name = dotted_name(n).split(".")[-1]
        if name in BROAD:
            out.append(name)
    return out


def _handler_ok(handler: ast.ExceptHandler) -> bool:
    uses_bound = False
    reraises = False
    logs = False
    bound = handler.name
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            reraises = True
        elif (bound and isinstance(node, ast.Name) and node.id == bound
                and isinstance(node.ctx, ast.Load)):
            uses_bound = True
        elif isinstance(node, ast.Call):
            fn = node.func
            name = dotted_name(fn)
            if name == "print":
                logs = True
            elif isinstance(fn, ast.Attribute) and fn.attr in LOG_ATTRS:
                logs = True
    return reraises or uses_bound or logs


class ExceptionHygieneChecker(Checker):
    name = "exception-hygiene"
    description = ("bare excepts are banned; except Exception/"
                   "BaseException must re-raise, use the bound exception, "
                   "or log")

    def check_module(self, mod: ModuleInfo, project: Project):
        findings: list[Finding] = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            broad = _broad_names(node.type)
            if not broad:
                continue
            if "<bare>" in broad:
                findings.append(Finding(
                    "exception-hygiene", mod.rel, node.lineno,
                    f"bare-except:{_context(mod, node)}",
                    "bare 'except:' catches BaseException (incl. "
                    "KeyboardInterrupt and injected crashes) — catch "
                    "Exception, or BaseException explicitly at a "
                    "supervision net"))
                continue
            if not _handler_ok(node):
                findings.append(Finding(
                    "exception-hygiene", mod.rel, node.lineno,
                    f"swallow:{'-'.join(broad)}:{_context(mod, node)}",
                    f"'except {' | '.join(broad)}' neither re-raises, "
                    f"uses the bound exception, nor logs — a silent "
                    f"swallow (narrow it, handle it, or suppress with "
                    f"rationale)"))
        return findings


def _context(mod: ModuleInfo, node: ast.AST) -> str:
    """Nearest enclosing def/class name for a stable baseline key."""
    best = ""
    best_line = -1
    for outer in ast.walk(mod.tree):
        if isinstance(outer, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
            if (outer.lineno <= node.lineno
                    and getattr(outer, "end_lineno", 1 << 30) >= node.lineno
                    and outer.lineno > best_line):
                best, best_line = outer.name, outer.lineno
    return best or "<module>"


register_checker(ExceptionHygieneChecker())
