"""Public CoDR engine API — spec → compile → serve.

    import repro.api as codr

    spec = codr.ModelSpec.from_params(params)      # any conv/dense pytree
    compiled = codr.compile(spec, codr.EncodeConfig(n_unique=16))
    y = compiled.run(x)                            # from the RLE bitstreams
    server = compiled.serve(max_batch=8)

Transformer params pytrees (``repro.models``) compile *in place*: every
projection leaf becomes a packed bitstream the model executes through
the backend registry (``launch/serve.py --codr`` rides this)::

    cp = codr.compile_params(params, codr.EncodeConfig(n_unique=16),
                             backend="codr_matmul")
    logits, cache = api.prefill(cp.params, batch, cfg)   # decode-fused

Everything here re-exports from :mod:`repro.core.api` (the pipeline) and
:mod:`repro.core.backends` (the pluggable execution backends).
"""
from repro.core.api import (CompiledModel, CompiledParams,  # noqa: F401
                            EncodeConfig, LayerSpec, ModelSpec, compile,
                            compile_params)
from repro.core.backends import (Backend, BackendCaps,  # noqa: F401
                                 available_backends, get_backend, register)
from repro.core.codr_linear import (PackedLinear, PackedWeight,  # noqa: F401
                                    dense_weight, pack_projection)

__all__ = [
    "LayerSpec", "ModelSpec", "EncodeConfig", "CompiledModel", "compile",
    "CompiledParams", "compile_params", "PackedLinear", "PackedWeight",
    "dense_weight", "pack_projection",
    "Backend", "BackendCaps", "available_backends", "get_backend",
    "register",
]
