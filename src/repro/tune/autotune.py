"""Cost-model-driven per-layer encoding search (paper §II-D, §III-C, Fig. 6).

The paper's point is that the U budget, tile geometry, and RLE field
widths must follow each layer's sparsity/repetition/similarity
structure.  This module makes that search a first-class artifact:

1. :func:`layer_candidate_table` scores every (n_unique, t_m[, rle])
   candidate per layer — **exact** encoded bits via
   :func:`repro.core.rle.layer_bits_size_only` (statistically exact when
   vector-sampled on huge layers), SRAM accesses and energy via
   :func:`repro.core.cost_model.layer_cost` under that candidate's tile
   geometry, and the relative weight-quantization error as the quality
   proxy.  Tables cache by weight-stats fingerprint
   (:func:`repro.tune.plan.layer_fingerprint`).
2. :func:`select_plan` picks each layer's feasible cost-optimal
   candidate under a :class:`~repro.tune.plan.TuneBudget`, then greedily
   trades quality headroom toward any model-wide bits/SRAM target.
3. :func:`best_global_config` scores every *single* global config over
   the same candidate table — the baseline a per-layer plan must beat.
4. :func:`tune_spec` = 1+2 end to end; :func:`tune_params` is the
   transformer-lane analogue over a params pytree (per-leaf U budgets
   for the ``PackedLinear`` pack path).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import cost_model, rle, ucr
from repro.core.api import PACK_INCLUDE, EncodeConfig, ModelSpec
from repro.core.dataflow import ConvShape, codr_tiling
from repro.tune.plan import LayerPlan, TuneBudget, TunePlan, \
    layer_fingerprint

__all__ = ["TuneGrid", "Candidate", "layer_candidate_table", "select_plan",
           "best_global_config", "tune_spec", "tune_params",
           "clear_cache", "cache_stats"]


@dataclasses.dataclass(frozen=True)
class TuneGrid:
    """The candidate space swept per layer.

    ``t_n`` stays a single value: the input-channel tile only reorders
    vector iteration — neither encoded bits nor the CoDR access counts
    depend on it — so sweeping it would triple the search for identical
    scores.  ``max_vectors`` bounds per-candidate UCR work on huge
    layers (sampled vectors, bits scaled back — same estimator as
    ``benchmarks.common.sampled_layer_vectors``); ``None`` scores every
    vector (exact, required when predicted bits must equal measured).
    """

    n_uniques: tuple[int, ...] = (8, 16, 32, 64, 128, 256)
    t_ms_conv: tuple[int, ...] = (2, 4, 8, 16)
    t_ms_linear: tuple[int, ...] = (64, 128, 256, 512)
    t_n: int = 4
    rle_options: tuple[tuple[int, int, int] | None, ...] = (None,)
    max_vectors: int | None = 2000
    seed: int = 0

    def key(self) -> str:
        return repr((self.n_uniques, self.t_ms_conv, self.t_ms_linear,
                     self.t_n, self.rle_options, self.max_vectors,
                     self.seed))


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One scored (layer × encode-config) point."""

    kind: str
    n_unique: int
    t_m: int                     # requested tile (conv t_m / t_m_linear)
    t_m_eff: int                 # clamped to the layer's M
    rle_params: tuple[int, int, int] | None
    n_weights: int
    bits: float                  # predicted encoded bits (exact unsampled)
    sram: float                  # predicted total SRAM accesses
    energy_uj: float
    rel_err: float               # quality proxy, depends on n_unique only

    @property
    def bits_per_weight(self) -> float:
        return self.bits / max(self.n_weights, 1)

    def config(self, base: EncodeConfig) -> EncodeConfig:
        kw = dict(n_unique=self.n_unique, rle_params=self.rle_params,
                  decode_source=base.decode_source)
        if self.kind == "conv":
            return EncodeConfig(t_m=self.t_m, t_n=base.t_n,
                                t_m_linear=base.t_m_linear, **kw)
        return EncodeConfig(t_m=base.t_m, t_n=base.t_n,
                            t_m_linear=self.t_m, **kw)


# --------------------------------------------------------------------------
# per-layer candidate scoring (cached by weight-stats fingerprint)
# --------------------------------------------------------------------------

_CACHE: dict[tuple[str, str], list[Candidate]] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def clear_cache() -> None:
    _CACHE.clear()
    _CACHE_STATS["hits"] = _CACHE_STATS["misses"] = 0


def cache_stats() -> dict:
    return dict(_CACHE_STATS)


def _score_layer(w: np.ndarray, kind: str, shape: ConvShape,
                 grid: TuneGrid) -> list[Candidate]:
    w = np.asarray(w, dtype=np.float32)
    m = int(w.shape[0])
    kernel = int(np.prod(w.shape[2:])) if w.ndim > 2 else 1
    t_ms = grid.t_ms_conv if kind == "conv" else grid.t_ms_linear
    w_norm = float(np.linalg.norm(w)) or 1.0
    q0, scale = ucr.quantize_int8(w)
    rng = np.random.default_rng(grid.seed)
    out: list[Candidate] = []
    for u in grid.n_uniques:
        q = ucr.restrict_unique(q0, u) if u < 256 else q0
        deq = q.astype(np.float32) * float(np.asarray(scale))
        rel_err = float(np.linalg.norm(deq - w)) / w_norm
        for t_m in t_ms:
            t_m_eff = min(int(t_m), m)
            vecs = ucr.layer_ucr_vectors(q, t_m=t_m, t_n=grid.t_n)
            n_total = len(vecs)
            if grid.max_vectors is not None and n_total > grid.max_vectors:
                idx = rng.choice(n_total, grid.max_vectors, replace=False)
                sample = [vecs[i] for i in sorted(idx)]
                vec_scale = n_total / len(sample)
            else:
                sample, vec_scale = vecs, 1.0
            vector_len = t_m_eff * kernel
            n_unique_sum = vec_scale * sum(len(v.unique_vals)
                                           for v in sample)
            n_nonzero = vec_scale * sum(v.n_nonzero for v in sample)
            tiling = codr_tiling(t_m_eff, grid.t_n)
            for rp in grid.rle_options:
                payload = rle.layer_bits_size_only(sample, vector_len,
                                                   params=rp) \
                    - 3 * rle.HEADER_BITS
                bits = payload * vec_scale + 3 * rle.HEADER_BITS
                cost = cost_model.layer_cost(shape, tiling, bits,
                                             n_unique_sum, n_nonzero)
                out.append(Candidate(
                    kind=kind, n_unique=int(u), t_m=int(t_m),
                    t_m_eff=t_m_eff, rle_params=rp,
                    n_weights=int(w.size), bits=float(bits),
                    sram=float(cost["sram"]),
                    energy_uj=float(cost["energy_uj"]),
                    rel_err=rel_err))
    return out


def _spec_shapes(spec: ModelSpec, input_hw: tuple[int, int]
                 ) -> list[tuple[str, str, np.ndarray, ConvShape]]:
    """(name, kind, weights, ConvShape) per layer, spatial dims tracked
    through the conv stack the way ``CodrModel.sram_report`` does."""
    ri, ci = input_hw
    out = []
    for i, ls in enumerate(spec.layers):
        name = ls.name or f"layer{i}"
        if ls.kind == "conv":
            m, n, rk, ck = ls.weight.shape
            shape = ConvShape(m, n, rk, ck, ri, ci, ls.stride)
            ri = (ri - rk) // ls.stride + 1
            ci = (ci - ck) // ls.stride + 1
        else:
            m, n = ls.weight.shape
            shape = ConvShape(m, n, 1, 1, 1, 1, 1)
        out.append((name, ls.kind, ls.weight, shape))
    return out


def layer_candidate_table(spec: ModelSpec, input_hw: tuple[int, int], *,
                          grid: TuneGrid | None = None,
                          use_cache: bool = True
                          ) -> dict[str, list[Candidate]]:
    """Score the full candidate grid for every layer of a spec.

    Cached per (weight-stats fingerprint + ConvShape, grid): layers with
    identical geometry, quantized-value statistics, AND spatial position
    share one scoring pass — the spatial dims ride in the key because
    SRAM counts depend on the feature-map size, not just the weights.
    """
    grid = TuneGrid() if grid is None else grid
    table: dict[str, list[Candidate]] = {}
    for name, kind, w, shape in _spec_shapes(spec, input_hw):
        key = (layer_fingerprint(w, kind, shape.stride) + repr(shape),
               grid.key())
        if use_cache and key in _CACHE:
            _CACHE_STATS["hits"] += 1
            table[name] = _CACHE[key]
            continue
        _CACHE_STATS["misses"] += 1
        cands = _score_layer(w, kind, shape, grid)
        if use_cache:
            _CACHE[key] = cands
        table[name] = cands
    return table


# --------------------------------------------------------------------------
# selection under a budget
# --------------------------------------------------------------------------

def _objective(budget: TuneBudget):
    attr = {"sram": "sram", "bits": "bits", "energy": "energy_uj"}
    key = attr[budget.objective]

    def obj(c: Candidate) -> tuple:
        return (getattr(c, key), c.bits, c.sram, c.n_unique)
    return obj


def _feasible(cands: list[Candidate],
              budget: TuneBudget) -> list[Candidate]:
    if budget.max_rel_err is None:
        return list(cands)
    ok = [c for c in cands if c.rel_err <= budget.max_rel_err]
    # best effort when the gate is unreachable (e.g. a layer whose amax
    # outlier makes every restricted grid lossy): the least-lossy U
    return ok or [min(cands, key=lambda c: (c.rel_err, c.bits))]


def _greedy_toward_target(chosen: dict[str, Candidate],
                          feasible: dict[str, list[Candidate]],
                          metric, target: float) -> bool:
    """Swap layer candidates, cheapest quality loss per unit of metric
    gained first, until ``sum(metric)`` meets ``target``.  Returns
    whether the target was met."""
    total = sum(metric(c) for c in chosen.values())
    while total > target:
        best = None
        for name, cands in feasible.items():
            cur = chosen[name]
            for c in cands:
                gain = metric(cur) - metric(c)
                if gain <= 0:
                    continue
                loss = max(c.rel_err - cur.rel_err, 0.0)
                score = (loss / gain, -gain)
                if best is None or score < best[0]:
                    best = (score, name, c)
        if best is None:
            return False
        _, name, c = best
        total -= metric(chosen[name]) - metric(c)
        chosen[name] = c
    return True


def select_plan(table: dict[str, list[Candidate]], *,
                budget: TuneBudget | None = None,
                base: EncodeConfig | None = None,
                meta: dict | None = None,
                fingerprints: dict[str, str] | None = None,
                cached: dict[str, bool] | None = None) -> TunePlan:
    """Per-layer feasible cost-optimum, then the greedy walk toward any
    model-wide bits/SRAM target."""
    budget = TuneBudget() if budget is None else budget
    base = EncodeConfig() if base is None else base
    obj = _objective(budget)
    feasible = {name: _feasible(cands, budget)
                for name, cands in table.items()}
    chosen = {name: min(cands, key=obj)
              for name, cands in feasible.items()}

    met = True
    if budget.target_bits_per_weight is not None:
        n_weights = sum(c.n_weights for c in chosen.values())
        met &= _greedy_toward_target(
            chosen, feasible, lambda c: c.bits,
            budget.target_bits_per_weight * n_weights)
    if budget.max_sram_accesses is not None:
        met &= _greedy_toward_target(chosen, feasible,
                                     lambda c: c.sram,
                                     budget.max_sram_accesses)

    layers = {}
    for name, c in chosen.items():
        layers[name] = LayerPlan(
            name=name, kind=c.kind, config=c.config(base),
            n_weights=c.n_weights, predicted_bits=c.bits,
            predicted_sram=c.sram, predicted_energy_uj=c.energy_uj,
            rel_err=c.rel_err,
            fingerprint=(fingerprints or {}).get(name, ""),
            from_cache=(cached or {}).get(name, False))
    plan_meta = dict(meta or {})
    plan_meta["meets_budget"] = met
    return TunePlan(layers, default=base, budget=budget, meta=plan_meta)


def best_global_config(table: dict[str, list[Candidate]], *,
                       budget: TuneBudget | None = None,
                       base: EncodeConfig | None = None,
                       grid: TuneGrid | None = None
                       ) -> tuple[EncodeConfig, dict]:
    """The best SINGLE EncodeConfig over the same candidate table — the
    baseline every per-layer plan is judged against.  Scored with the
    same objective and feasibility gate as :func:`select_plan`; returns
    ``(config, totals)`` where totals carries the predicted sums."""
    budget = TuneBudget() if budget is None else budget
    base = EncodeConfig() if base is None else base
    grid = TuneGrid() if grid is None else grid
    obj = _objective(budget)

    by_key: dict[str, dict] = {}
    kinds: dict[str, str] = {}
    for name, cands in table.items():
        kinds[name] = cands[0].kind
        by_key[name] = {(c.n_unique, c.t_m, c.rle_params): c
                        for c in cands}
    has_conv = any(k == "conv" for k in kinds.values())
    has_linear = any(k == "linear" for k in kinds.values())
    t_ms_conv = grid.t_ms_conv if has_conv else grid.t_ms_conv[:1]
    t_ms_linear = grid.t_ms_linear if has_linear else grid.t_ms_linear[:1]

    best = None
    for u in grid.n_uniques:
        for rp in grid.rle_options:
            for tmc in t_ms_conv:
                for tml in t_ms_linear:
                    picks, worst = [], 0.0
                    for name, kind in kinds.items():
                        tm = tmc if kind == "conv" else tml
                        c = by_key[name].get((u, tm, rp))
                        if c is None:
                            picks = None
                            break
                        picks.append(c)
                        worst = max(worst, c.rel_err)
                    if picks is None:
                        continue
                    feasible = (budget.max_rel_err is None
                                or worst <= budget.max_rel_err)
                    totals = (sum(c.sram for c in picks),
                              sum(c.bits for c in picks),
                              sum(c.energy_uj for c in picks))
                    score = {"sram": (totals[0], totals[1]),
                             "bits": (totals[1], totals[0]),
                             "energy": (totals[2], totals[1])
                             }[budget.objective]
                    entry = (not feasible, score, u, tmc, tml, rp,
                             totals, worst)
                    if best is None or entry[:2] < best[:2]:
                        best = entry
    if best is None:
        raise ValueError("empty candidate table")
    _, _, u, tmc, tml, rp, totals, worst = best
    cfg = EncodeConfig(n_unique=u, t_m=tmc, t_n=base.t_n,
                       t_m_linear=tml, rle_params=rp,
                       decode_source=base.decode_source)
    n_weights = sum(cands[0].n_weights for cands in table.values())
    return cfg, {"sram": totals[0], "bits": totals[1],
                 "energy_uj": totals[2],
                 "bits_per_weight": totals[1] / max(n_weights, 1),
                 "max_rel_err": worst,
                 "feasible": not best[0]}


def tune_spec(spec: ModelSpec, input_hw: tuple[int, int], *,
              budget: TuneBudget | None = None,
              base: EncodeConfig | None = None,
              grid: TuneGrid | None = None,
              use_cache: bool = True) -> TunePlan:
    """End-to-end per-layer search over a :class:`ModelSpec`: candidate
    table (fingerprint-cached) → budgeted selection → serializable
    :class:`TunePlan` consumable by ``codr.compile(spec, plan=plan)``."""
    grid = TuneGrid() if grid is None else grid
    hits_before = _CACHE_STATS["hits"]
    fingerprints, cached = {}, {}
    for name, kind, w, shape in _spec_shapes(spec, input_hw):
        fp = layer_fingerprint(w, kind, shape.stride)
        fingerprints[name] = fp
        cached[name] = use_cache and \
            (fp + repr(shape), grid.key()) in _CACHE
    table = layer_candidate_table(spec, input_hw, grid=grid,
                                  use_cache=use_cache)
    meta = {"input_hw": list(input_hw), "grid": grid.key(),
            "cache_hits": _CACHE_STATS["hits"] - hits_before,
            "sampled": grid.max_vectors is not None}
    return select_plan(table, budget=budget, base=base, meta=meta,
                       fingerprints=fingerprints, cached=cached)


# --------------------------------------------------------------------------
# the transformer lane: per-leaf U budgets for the pack path
# --------------------------------------------------------------------------

def tune_params(params, *,
                budget: TuneBudget | None = None,
                base: EncodeConfig | None = None,
                n_uniques: Sequence[int] = (4, 8, 16, 32, 64),
                include: Sequence[str] = PACK_INCLUDE,
                exclude: Sequence[str] = (),
                min_size: int | None = None) -> TunePlan:
    """Per-leaf U budgets for ``codr.compile_params(params, plan=...)``.

    For every packable projection leaf (same include/size filter as
    ``compile_params``), picks the smallest U whose relative weight
    error passes the budget gate — the packed representation's bits are
    ``ceil(log2 U)`` per weight (:func:`repro.core.codr_linear.choose_bits`),
    so minimizing U minimizes serving HBM directly.  Leaves the filter
    skips stay on the caller's default config.
    """
    import jax

    from repro.core import serving as _serving
    from repro.core.codr_linear import choose_bits

    budget = TuneBudget() if budget is None else budget
    base = EncodeConfig() if base is None else base
    if min_size is None:
        min_size = _serving.MIN_COMPRESS_SIZE

    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    layers: dict[str, LayerPlan] = {}
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = np.asarray(leaf)
        if arr.ndim < 2 or arr.size < min_size:
            continue
        if not (any(tok in pstr for tok in include)
                and not any(tok in pstr for tok in exclude)):
            continue
        mat = arr.reshape(-1, arr.shape[-1]).astype(np.float32)
        w_norm = float(np.linalg.norm(mat)) or 1.0
        q0, scale = ucr.quantize_int8(mat)
        best = None
        for u in sorted(set(int(v) for v in n_uniques)):
            q = ucr.restrict_unique(q0, u) if u < 256 else q0
            deq = q.astype(np.float32) * float(np.asarray(scale))
            rel_err = float(np.linalg.norm(deq - mat)) / w_norm
            bits = float(arr.size * choose_bits(u))
            entry = (rel_err, u, bits)
            feasible = (budget.max_rel_err is None
                        or rel_err <= budget.max_rel_err)
            if feasible:
                best = entry               # smallest feasible U wins
                break
            if best is None or entry < best:
                best = entry               # least-lossy fallback
        rel_err, u, bits = best
        m, n = mat.shape[1], mat.shape[0]  # (d_in, d_out) leaves
        shape = ConvShape(m, n, 1, 1, 1, 1, 1)
        cost = cost_model.layer_cost(
            shape, codr_tiling(min(base.t_m_linear, m), base.t_n),
            bits, float(u), float(np.count_nonzero(q0)))
        layers[pstr] = LayerPlan(
            name=pstr, kind="linear",
            config=dataclasses.replace(base, n_unique=u),
            n_weights=int(arr.size), predicted_bits=bits,
            predicted_sram=cost["sram"],
            predicted_energy_uj=cost["energy_uj"], rel_err=rel_err,
            fingerprint=layer_fingerprint(mat, "linear"))
    if not layers:
        raise ValueError("tune_params found no packable projection "
                         f"leaves (include={tuple(include)!r}, "
                         f"min_size={min_size})")
    return TunePlan(layers, default=base, budget=budget,
                    meta={"lane": "params"})
