"""codrlint fixture: resolving re-export and an accurate __all__."""
from repro.core.serving import CodrBatchServer  # noqa: F401

__all__ = ["CodrBatchServer", "exported_fn"]


def exported_fn():
    return 2
