"""Public CoDR engine API — spec → compile → serve.

    import repro.api as codr

    spec = codr.ModelSpec.from_params(params)      # any conv/dense pytree
    compiled = codr.compile(spec, codr.EncodeConfig(n_unique=16))
    y = compiled.run(x)                            # from the RLE bitstreams
    server = compiled.serve(max_batch=8)

Everything here re-exports from :mod:`repro.core.api` (the pipeline) and
:mod:`repro.core.backends` (the pluggable execution backends).
"""
from repro.core.api import (CompiledModel, EncodeConfig,  # noqa: F401
                            LayerSpec, ModelSpec, compile)
from repro.core.backends import (Backend, BackendCaps,  # noqa: F401
                                 available_backends, get_backend, register)

__all__ = [
    "LayerSpec", "ModelSpec", "EncodeConfig", "CompiledModel", "compile",
    "Backend", "BackendCaps", "available_backends", "get_backend",
    "register",
]
