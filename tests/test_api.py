"""Spec → compile → serve API (`repro.api`): compile-time capability
checks, dense-oracle parity across strides / ragged tiles / every
registered backend that claims support, and checkpoint (pytree)
ingestion via ``ModelSpec.from_params``."""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as codr
from repro.core import backends as backends_mod
from repro.core.engine import CodrConv2D, CodrModel


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _sparse(rng, shape, density=0.5, scale=0.5):
    w = rng.normal(size=shape).astype(np.float32) * scale
    w[rng.random(shape) > density] = 0
    return w


def _supported(compiled):
    """Names of registered backends that claim support for the model."""
    return [n for n in codr.available_backends()
            if codr.get_backend(n).supports_model(compiled.model.layers)[0]]


# ---------------------------------------------------------------------------
# property test: compile(spec).run vs the dense oracle — strides 1–3,
# ragged last output-channel tile, every backend that claims support
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2, 3])
@pytest.mark.parametrize("m", [8, 10])          # 10 → ragged tile at t_m=4
def test_compile_run_matches_oracle_all_backends(stride, m, rng):
    w = _sparse(rng, (m, 3, 3, 3))
    b = rng.normal(size=m).astype(np.float32)
    spec = codr.ModelSpec([
        codr.LayerSpec.conv(w, b, stride=stride, activation="relu",
                            name="c0"),
    ])
    compiled = codr.compile(spec, codr.EncodeConfig())
    compiled.verify_roundtrip()
    # integer-valued activations: every backend (incl. the 8-bit feature
    # datapaths) matches the dequantized oracle near-exactly
    x = rng.integers(-8, 8, size=(2, 13, 13, 3)).astype(np.float32)
    yq = np.asarray(compiled.quantized_reference(x))
    names = _supported(compiled)
    assert {"tiled", "smm", "smm_kernel"} <= set(names)
    for name in names:
        y = np.asarray(compiled.run(x, backend=name))
        np.testing.assert_allclose(y, yq, rtol=1e-4, atol=1e-4,
                                   err_msg=f"backend {name}")
    # float oracle within int8 quantization tolerance
    yr = compiled.reference(x)
    assert float(jnp.abs(compiled.run(x) - yr).max()
                 / (jnp.abs(yr).max() + 1e-9)) < 0.08


def test_compile_linear_only_spec_runs_on_codr_matmul(rng):
    wl = _sparse(rng, (10, 24), density=0.7, scale=0.3)
    spec = codr.ModelSpec([codr.LayerSpec.dense(wl, name="d0")])
    compiled = codr.compile(spec, backend="codr_matmul")
    x = rng.normal(size=(3, 24)).astype(np.float32)
    y = np.asarray(compiled.run(x))
    yq = np.asarray(compiled.quantized_reference(x))
    np.testing.assert_allclose(y, yq, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# registry + capability checks
# ---------------------------------------------------------------------------

def test_compile_rejects_unsupported_backend_with_reason(rng):
    spec = codr.ModelSpec([codr.LayerSpec.conv(_sparse(rng, (4, 2, 3, 3)))])
    with pytest.raises(ValueError, match="no 'conv' path"):
        codr.compile(spec, backend="codr_matmul")
    with pytest.raises(ValueError, match="unknown backend"):
        codr.compile(spec, backend="warp_drive")


def test_run_backend_override_is_capability_checked(rng):
    spec = codr.ModelSpec([codr.LayerSpec.conv(_sparse(rng, (4, 2, 3, 3)))])
    compiled = codr.compile(spec)
    x = rng.integers(-4, 5, size=(1, 8, 8, 2)).astype(np.float32)
    compiled.run(x)                                   # default backend fine
    with pytest.raises(ValueError, match="no 'conv' path"):
        compiled.run(x, backend="codr_matmul")


def test_register_custom_backend_and_dispatch(rng):
    class NegatingBackend(backends_mod.Backend):
        name = "test_negate"
        caps = backends_mod.BackendCaps(description="test-only")

        def conv(self, layer, x):
            return -layer(x)

        def linear(self, layer, x):
            return -layer(x)

    be = backends_mod.register(NegatingBackend())
    try:
        w = _sparse(rng, (4, 2, 3, 3))
        model = CodrModel([CodrConv2D(w, t_m=2)])
        x = rng.normal(size=(1, 6, 6, 2)).astype(np.float32)
        # both the engine entry point and the compiled wrapper see it
        np.testing.assert_allclose(
            np.asarray(model.run(x, backend="test_negate")),
            -np.asarray(model.run(x)), rtol=1e-6, atol=1e-6)
        with pytest.raises(ValueError, match="already registered"):
            backends_mod.register(NegatingBackend())
        backends_mod.register(NegatingBackend(), overwrite=True)
    finally:
        backends_mod._REGISTRY.pop("test_negate", None)
    assert be.name not in codr.available_backends()


# ---------------------------------------------------------------------------
# EncodeConfig knobs
# ---------------------------------------------------------------------------

def test_encode_config_n_unique_restricts_levels_and_shrinks_code(rng):
    w = _sparse(rng, (16, 4, 3, 3), density=0.9)
    spec = codr.ModelSpec([codr.LayerSpec.conv(w, name="c0")])
    full = codr.compile(spec, codr.EncodeConfig())
    small = codr.compile(spec, codr.EncodeConfig(n_unique=8))
    small.verify_roundtrip()                  # roundtrip honors the U knob
    q = small.model.layers[0].decoded_weights()
    assert len(np.unique(q[q != 0])) <= 8
    assert small.total_bits() < full.total_bits()
    st = small.stats()[0]
    assert st.n_unique <= full.stats()[0].n_unique


def test_encode_config_fixed_rle_params_roundtrip(rng):
    w = _sparse(rng, (8, 3, 3, 3))
    spec = codr.ModelSpec([codr.LayerSpec.conv(w, name="c0")])
    cfg = codr.EncodeConfig(rle_params=(4, 4, 4))
    compiled = codr.compile(spec, cfg)
    compiled.verify_roundtrip()               # fixed params still lossless
    assert compiled.model.layers[0].code.params == (4, 4, 4)
    assert cfg.metadata()["rle_params"] == [4, 4, 4]


def test_encode_config_validation():
    with pytest.raises(ValueError, match="n_unique"):
        codr.EncodeConfig(n_unique=1)
    # n_unique=2 leaves only the zero level (every weight collapses to 0
    # under restrict_unique) — a silently dead model, rejected up front
    with pytest.raises(ValueError, match="n_unique"):
        codr.EncodeConfig(n_unique=2)
    with pytest.raises(ValueError, match="decode_source"):
        codr.EncodeConfig(decode_source="telepathy")


# ---------------------------------------------------------------------------
# spec construction + validation
# ---------------------------------------------------------------------------

def test_model_spec_validates_chain(rng):
    c0 = codr.LayerSpec.conv(_sparse(rng, (4, 3, 3, 3)), name="c0")
    bad = codr.LayerSpec.conv(_sparse(rng, (4, 5, 3, 3)), name="c1")
    with pytest.raises(ValueError, match="input channels"):
        codr.ModelSpec([c0, bad])
    d = codr.LayerSpec.dense(_sparse(rng, (4, 8)), name="fc")
    with pytest.raises(ValueError, match="precede"):
        codr.ModelSpec([d, c0])
    with pytest.raises(ValueError, match="4-D"):
        codr.LayerSpec.conv(_sparse(rng, (4, 8)))
    with pytest.raises(ValueError, match="bias"):
        codr.LayerSpec.conv(_sparse(rng, (4, 3, 3, 3)),
                            np.zeros(5, np.float32))


def test_from_shapes_matches_build_random_model(rng):
    """The deprecated builder is a shim over from_shapes + compile — the
    same rng must produce the identical model."""
    from repro.core.dataflow import ConvShape
    from repro.core.engine import build_random_model
    shapes = [ConvShape(6, 3, 3, 3, 10, 10, 1)]
    m1 = build_random_model(shapes, n_out=4, density=0.5,
                            rng=np.random.default_rng(7))
    spec = codr.ModelSpec.from_shapes(shapes, n_out=4, density=0.5,
                                      rng=np.random.default_rng(7))
    m2 = codr.compile(spec).model
    x = rng.normal(size=(2, 10, 10, 3)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(m1.run(x)),
                               np.asarray(m2.run(x)), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# checkpoint ingestion: from_params → compile → run from bitstreams
# ---------------------------------------------------------------------------

def test_from_params_pytree_end_to_end(rng):
    """Acceptance: a repro.models-style conv/dense params pytree executes
    end-to-end from the bitstreams with dense-oracle parity."""
    params = {
        "conv0": {"w": _sparse(rng, (8, 3, 3, 3)),
                  "b": rng.normal(size=8).astype(np.float32)},
        "conv1": {"w": _sparse(rng, (12, 8, 3, 3))},
        "fc": {"w": _sparse(rng, (8 * 8 * 12, 6), scale=0.1)},
    }
    spec = codr.ModelSpec.from_params(
        params, activation={"conv0": "relu", "conv1": "relu"},
        linear_layout="in_out")
    assert [ls.name for ls in spec] == ["conv0", "conv1", "fc"]
    assert spec.layers[0].bias is not None
    assert spec.layers[2].weight.shape == (6, 8 * 8 * 12)   # transposed

    compiled = codr.compile(spec, codr.EncodeConfig(n_unique=16))
    compiled.verify_roundtrip()               # bitstreams are lossless
    assert compiled.bits_per_weight() < 8.0   # beats raw int8

    x = rng.normal(size=(2, 12, 12, 3)).astype(np.float32)
    y = compiled.run(x)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(compiled.quantized_reference(x)),
                               rtol=1e-3, atol=1e-3)
    # every registry backend that claims support agrees on int inputs
    xi = rng.integers(-5, 6, size=(2, 12, 12, 3)).astype(np.float32)
    yt = np.asarray(compiled.run(xi))
    for name in _supported(compiled):
        yb = np.asarray(compiled.run(xi, backend=name))
        rel = np.abs(yb - yt).max() / (np.abs(yt).max() + 1e-9)
        assert rel < 0.05, f"backend {name}: rel err {rel}"


def test_from_params_numbered_layers_keep_natural_order(rng):
    """JAX flattens dicts in sorted-key order ('conv10' < 'conv2');
    ingestion must re-establish the numeric sequence."""
    params = {f"conv{i}": {"w": _sparse(rng, (4, 4, 3, 3))}
              for i in range(12)}
    spec = codr.ModelSpec.from_params(params)
    assert [ls.name for ls in spec] == [f"conv{i}" for i in range(12)]


def test_from_params_same_shape_weights_consume_distinct_biases(rng):
    """Two same-shaped weights in one subtree must each get their own
    bias (pairing consumes), never share the first match."""
    b1 = rng.normal(size=4).astype(np.float32)
    b2 = rng.normal(size=4).astype(np.float32)
    params = {"blk": {"w_a": _sparse(rng, (4, 6)), "b_a": b1,
                      "w_b": _sparse(rng, (4, 6)), "b_b": b2}}
    spec = codr.ModelSpec.from_params(params)
    got = sorted(tuple(ls.bias) for ls in spec.layers)
    assert got == sorted([tuple(b1), tuple(b2)])


def test_from_params_flat_arrays_and_stride(rng):
    params = [_sparse(rng, (4, 2, 3, 3)), _sparse(rng, (6, 4, 3, 3))]
    spec = codr.ModelSpec.from_params(params, stride={"0": 2})
    assert spec.layers[0].stride == 2 and spec.layers[1].stride == 1
    with pytest.raises(ValueError, match="no 2-D/4-D"):
        codr.ModelSpec.from_params({"scalars": {"a": np.zeros(3)}})


def test_compiled_model_serves_requests(rng):
    spec = codr.ModelSpec([codr.LayerSpec.conv(_sparse(rng, (4, 2, 3, 3)),
                                               activation="relu")])
    compiled = codr.compile(spec)
    server = compiled.serve(max_batch=4)
    xs = [rng.normal(size=(8, 8, 2)).astype(np.float32) for _ in range(6)]
    outs = server.serve(xs)
    direct = np.asarray(compiled.run(jnp.asarray(np.stack(xs))))
    for got, want in zip(outs, direct):
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
