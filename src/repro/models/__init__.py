"""Model zoo facade: uniform API over decoder-only and encoder-decoder
families.

    api = get_model(cfg)
    params = api.init_params(key, cfg)
    loss = api.train_loss(params, batch, cfg)
    logits, cache = api.prefill(params, batch, cfg)
    logits, cache = api.decode_step(params, cache, token, pos, cfg)
"""
from __future__ import annotations

import types

import jax.numpy as jnp

from repro.models import encdec, lm


def get_model(cfg) -> types.SimpleNamespace:
    if cfg.family == "encdec":
        def prefill(params, batch, cfg):
            return encdec.prefill(params, batch["prefix"], batch["tokens"],
                                  cfg)

        def init_cache(cfg, batch, seq, dtype=jnp.bfloat16, paged=None):
            if paged is not None:
                raise NotImplementedError(
                    "paged KV cache is decoder-only for now "
                    "(enc-dec caches carry a cross-attention half)")
            return encdec.init_cache(cfg, batch, seq,
                                     enc_seq=cfg.frontend_seq or seq,
                                     dtype=dtype)

        return types.SimpleNamespace(
            init_params=encdec.init_params, train_loss=encdec.train_loss,
            prefill=prefill, decode_step=encdec.decode_step,
            init_cache=init_cache)

    def prefill(params, batch, cfg):
        return lm.prefill(params, batch["tokens"], cfg,
                          prefix=batch.get("prefix"))

    def init_cache(cfg, batch, seq, dtype=jnp.bfloat16, paged=None):
        return lm.init_cache(cfg, batch, seq, dtype=dtype, paged=paged)

    return types.SimpleNamespace(
        init_params=lm.init_params, train_loss=lm.train_loss,
        prefill=prefill, decode_step=lm.decode_step, init_cache=init_cache)
