"""End-to-end CoDR engine benchmark: encode-once / run-many throughput
plus per-layer SRAM-access estimates from the dataflow model.

  PYTHONPATH=src python benchmarks/engine.py [--small] [--batch B]

Exercises the spec → compile → serve API (``repro.api``): a declarative
``ModelSpec`` on paper-CNN geometry is compiled once under an explicit
``EncodeConfig``, then driven through the offline bitstream decode, the
one-time compile, the steady-state (post-compile) forward — the
serving-relevant figure — and the batched request path, in all four
serving modes: the fused ``tiled`` backend, the ``sharded``
tile-parallel executor (over however many local devices the host
exposes — force more with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``), the
synchronous bucketed batch server, and the async futures path
(``submit_async`` + background flush loop) — plus the **transformer
serving mode**: an ``repro.models`` LM prefill/decode with every
projection executing from the packed bitstream through the decode-fused
``codr_matmul`` backend (``repro.launch.serve.run_serve``), with weight
HBM bytes measured on the stored pack, and the **continuous-batching
mode**: a slot-pooled ``ContinuousBatcher`` decode loop streaming
request waves at concurrency 1/4/8 (tokens/s per level lands in the
JSON under ``serve_continuous``).  CSV lines (the harness
format): ``name,us_per_call,derived``; the JSON summary (default
``BENCH_engine.json``) is stamped with the git SHA and the
encode-config metadata so the perf trajectory stays comparable PR over
PR.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

try:
    from benchmarks.common import Timer, bench_meta, csv_line
except ImportError:                                   # run as a script
    import os
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.common import Timer, bench_meta, csv_line

import repro.api as codr


def build(small: bool) -> tuple[codr.CompiledModel, tuple[int, int]]:
    """conv → conv → linear compiled model on paper-CNN channel
    geometry, encoded once under the benchmark's EncodeConfig."""
    rng = np.random.default_rng(0)
    if small:
        spec = codr.ModelSpec.from_paper_cnn("vgg16", n_conv=2, ri=20,
                                             ci=20, n_out=10, density=0.4,
                                             rng=rng)
        hw = (20, 20)
    else:
        spec = codr.ModelSpec.from_paper_cnn("alexnet", n_conv=2, ri=67,
                                             ci=67, n_out=100, density=0.4,
                                             rng=rng)
        hw = (67, 67)
    # the real bitstream decode path — the vectorized bulk decoder makes
    # it cheap enough to benchmark end-to-end
    config = codr.EncodeConfig(decode_source="bitstream")
    return codr.compile(spec, config), hw


def main(small: bool = False, batch: int = 8, iters: int = 5,
         json_path: str | None = "BENCH_engine.json") -> dict:
    compiled, hw = build(small)
    model = compiled.model
    rng = np.random.default_rng(1)
    n_in = model.layers[0].code.shape[1]
    x = rng.normal(size=(batch, *hw, n_in)).astype(np.float32)

    with Timer() as t_dec:                     # offline bitstream decode
        for layer in model.layers:             # (bulk decoder, once ever)
            _ = layer.tiles
    with Timer() as t_compile:                 # compile + first dispatch
        np.asarray(compiled.run(x))

    with Timer() as t_run:                     # steady state (post-compile)
        for _ in range(iters):
            y = compiled.run(x)
        y.block_until_ready()
    us = t_run.dt / iters * 1e6
    imgs_s = batch * iters / t_run.dt
    print(csv_line("engine_decode", t_dec.dt * 1e6,
                   f"bits={compiled.total_bits()};"
                   f"decode_s={t_dec.dt:.4f}"))
    print(csv_line("engine_compile", t_compile.dt * 1e6,
                   f"traces={compiled.trace_count}"))
    print(csv_line("engine_forward", us,
                   f"imgs_per_s={imgs_s:.1f};batch={batch};"
                   f"bits_per_weight={compiled.bits_per_weight():.2f};"
                   f"steady_state=post_compile"))

    # sharded tile-parallel executor (same compiled model, backend
    # override; 1-element mesh = the single-device fallback)
    import jax
    n_dev = len(jax.devices())
    np.asarray(compiled.run(x, backend="sharded"))   # compile + shard once
    with Timer() as t_shard:
        for _ in range(iters):
            y_sh = compiled.run(x, backend="sharded")
        y_sh.block_until_ready()
    us_shard = t_shard.dt / iters * 1e6
    print(csv_line("engine_forward_sharded", us_shard,
                   f"imgs_per_s={batch * iters / t_shard.dt:.1f};"
                   f"devices={n_dev};batch={batch}"))

    server = compiled.serve(max_batch=batch)
    samples = [rng.normal(size=(*hw, n_in)).astype(np.float32)
               for _ in range(batch + 3)]
    server.serve(samples)                      # warm the size buckets
    batches_before = server.batches_run
    with Timer() as t_srv:
        outs = server.serve(samples)
    print(csv_line("engine_serve", t_srv.dt / len(outs) * 1e6,
                   f"requests={len(outs)};"
                   f"batches={server.batches_run - batches_before};"
                   f"buckets={len(server.bucket_counts)}"))

    # async futures path: background flush loop, max_batch load trigger,
    # double-buffered staging — same request stream as the sync server
    aserver = compiled.serve(max_batch=batch, flush_deadline_s=0.005)
    with aserver:
        [f.result() for f in [aserver.submit_async(s) for s in samples]]
        abatches_before = aserver.batches_run
        with Timer() as t_async:
            futs = [aserver.submit_async(s) for s in samples]
            outs_a = [f.result() for f in futs]
    print(csv_line("engine_serve_async", t_async.dt / len(outs_a) * 1e6,
                   f"requests={len(outs_a)};"
                   f"batches={aserver.batches_run - abatches_before};"
                   f"deadline_s={aserver.flush_deadline_s}"))

    # transformer serving from the packed representation: prefill +
    # greedy decode of an repro.models LM with every projection executing
    # through the decode-fused codr_matmul backend (interpret mode on
    # CPU), HBM bytes measured on the stored pack
    from repro.launch.serve import run_serve
    st = run_serve(arch="qwen2.5-3b", batch=2,
                   prompt_len=4 if small else 8,
                   gen_len=4 if small else 16,
                   use_codr=True, verbose=False)
    print(csv_line("engine_serve_transformer", st["ms_per_tok"] * 1e3,
                   f"arch={st['arch']};backend={st['backend']};"
                   f"hbm_bytes={st['hbm_bytes']};"
                   f"kv_bytes={st['kv_bytes']};"
                   f"bits_per_weight={st['bits_per_weight']:.2f}"))

    # continuous batching over the same packed representation: one
    # ContinuousBatcher (8 KV-cache slots, compiled once) streams
    # request waves at concurrency 1 / 4 / 8 — tokens/s should scale
    # with concurrency because every pooled decode step amortizes one
    # packed weight fetch over all active slots
    import jax as _jax
    from repro.configs import get_config, smoke_variant
    from repro.core.batching import ContinuousBatcher
    from repro.models import get_model

    cb_cfg = smoke_variant(get_config("qwen2.5-3b"))
    cb_api = get_model(cb_cfg)
    cb_params = cb_api.init_params(_jax.random.PRNGKey(0), cb_cfg)
    cb_compiled = codr.compile_params(
        cb_params, codr.EncodeConfig(n_unique=16), backend="codr_matmul")
    cb_prompt_len = 4 if small else 8
    cb_gen = 4 if small else 8
    batcher = ContinuousBatcher(cb_compiled, cb_cfg, n_slots=8,
                                max_len=cb_prompt_len + cb_gen)
    prng = np.random.default_rng(2)
    def _wave(n):
        prompts = [prng.integers(0, cb_cfg.vocab_size, size=cb_prompt_len)
                   for _ in range(n)]
        hs = [batcher.submit(p, max_new_tokens=cb_gen) for p in prompts]
        return sum(len(h.result(timeout=600)) for h in hs)
    _wave(8)                                   # warm prefill + step jits
    conc_toks_s: dict[str, float] = {}
    cb_kv_bytes = batcher.kv_bytes()
    for conc in (1, 4, 8):
        with Timer() as t_cb:
            n_toks = _wave(conc)
        conc_toks_s[str(conc)] = n_toks / t_cb.dt
        print(csv_line(f"engine_serve_continuous_c{conc}",
                       t_cb.dt / n_toks * 1e6,
                       f"arch={cb_cfg.name};backend=codr_matmul;"
                       f"n_slots=8;tokens={n_toks};"
                       f"kv_bytes={cb_kv_bytes};"
                       f"toks_per_s={conc_toks_s[str(conc)]:.1f}"))
    batcher.stop_async()
    # int8 paged pool on the same geometry — resident KV bytes are the
    # point of the quantized page pool, so record both side by side
    # (no worker is started; this only materializes the pool)
    cb_kv_bytes_int8 = ContinuousBatcher(
        cb_compiled, cb_cfg, n_slots=8, max_len=cb_prompt_len + cb_gen,
        kv_dtype="int8", kv_page_size=4).kv_bytes()
    print(csv_line("engine_kv_pool_int8", 0.0,
                   f"kv_bytes={cb_kv_bytes_int8};"
                   f"kv_bytes_bf16={cb_kv_bytes};"
                   f"ratio={cb_kv_bytes / max(cb_kv_bytes_int8, 1):.2f}"))

    # packed checkpoint artifact: compress-once/boot-many — save the
    # already-compiled transformer params and time the mmap reload
    import os as _os
    import shutil as _shutil
    import tempfile as _tempfile
    _ckdir = _tempfile.mkdtemp(prefix="codr_bench_")
    _ckpath = _os.path.join(_ckdir, "packed.codr")
    with Timer() as t_ck_save:
        codr.save_packed(cb_compiled, _ckpath)
    ck_disk_bytes = sum(
        _os.path.getsize(_os.path.join(_ckpath, f))
        for f in _os.listdir(_ckpath))
    with Timer() as t_ck_load:
        ck_loaded = codr.load_packed(_ckpath)
    assert len(ck_loaded.packed_paths) == len(cb_compiled.packed_paths)
    _shutil.rmtree(_ckdir)
    print(csv_line("engine_packed_boot", t_ck_load.dt * 1e6,
                   f"save_us={t_ck_save.dt * 1e6:.1f};"
                   f"disk_bytes={ck_disk_bytes};"
                   f"format_version={codr.CODR_FORMAT_VERSION}"))

    # latency under faults: the same async request path, clean vs with a
    # seeded fault plan (transient dispatch errors + injected latency)
    # absorbed by the retry policy — the p50/p95/p99 spread is the cost
    # of resilience actually exercised, not just installed
    import time as _time
    from repro.runtime import resilience as res

    n_fault_req = 12 if small else 24
    fx = [rng.normal(size=(*hw, n_in)).astype(np.float32)
          for _ in range(n_fault_req)]

    def _latencies(server):
        lats = []
        for s in fx:
            t0 = _time.perf_counter()
            server.submit_async(s).result(timeout=600)
            lats.append(_time.perf_counter() - t0)
        return np.asarray(lats)

    fault_stats: dict[str, dict] = {}
    for mode in ("clean", "injected"):
        fsrv = compiled.serve(max_batch=4, flush_deadline_s=0.002)
        inj = None
        if mode == "injected":
            plan = res.FaultPlan.seeded(
                0, (res.SITE_SERVER_DISPATCH,), n_faults=6,
                kinds=("error", "latency"), max_call=n_fault_req,
                latency_s=0.005)
            inj = res.FaultInjector(plan)
            fsrv.configure_resilience(
                injector=inj,
                retry_policy=res.RetryPolicy(max_retries=3,
                                             backoff_s=0.001))
        with fsrv:
            fsrv.submit_async(fx[0]).result(timeout=600)   # warm
            lats = _latencies(fsrv)
        p50, p95, p99 = np.percentile(lats, [50, 95, 99]) * 1e3
        fault_stats[mode] = {
            "p50_ms": float(p50), "p95_ms": float(p95),
            "p99_ms": float(p99),
            "faults_fired": len(inj.fired) if inj else 0,
        }
        print(csv_line(f"engine_serve_faults_{mode}",
                       float(np.mean(lats)) * 1e6,
                       f"requests={n_fault_req};p50_ms={p50:.3f};"
                       f"p95_ms={p95:.3f};p99_ms={p99:.3f};"
                       f"faults={fault_stats[mode]['faults_fired']}"))

    for name, acc in compiled.sram_report(hw):
        print(csv_line(f"engine_sram_{name}", 0.0,
                       f"total_sram={acc.total_sram:.0f};"
                       f"feature_sram={acc.feature_sram:.0f};"
                       f"weight_rows={acc.weight_sram_rows:.0f}"))

    result = {
        "benchmark": "engine", "small": small, "batch": batch,
        "meta": bench_meta(encode_config=compiled.config.metadata(),
                           backend=compiled.backend.name),
        "decode_s": t_dec.dt,
        "compile_s": t_compile.dt,
        "steady_us_per_call": us,
        "imgs_per_s": imgs_s,
        "sharded_us_per_call": us_shard,
        "sharded_imgs_per_s": batch * iters / t_shard.dt,
        "n_devices": n_dev,
        "serve_us_per_request": t_srv.dt / len(outs) * 1e6,
        "serve_async_us_per_request": t_async.dt / len(outs_a) * 1e6,
        "serve_transformer": {
            "arch": st["arch"], "backend": st["backend"],
            "ms_per_tok": st["ms_per_tok"],
            "prefill_s": st["prefill_s"],
            "hbm_bytes": st["hbm_bytes"],
            "kv_bytes": st["kv_bytes"],
            "dense_bf16_bytes": st["dense_bf16_bytes"],
            "bits_per_weight": st["bits_per_weight"],
            "n_packed_tensors": st["n_packed"],
        },
        "serve_continuous": {
            "arch": cb_cfg.name, "backend": "codr_matmul",
            "n_slots": 8, "prompt_len": cb_prompt_len, "gen_len": cb_gen,
            "concurrency_tokens_per_s": conc_toks_s,
            "kv_bytes": cb_kv_bytes,
            "kv_bytes_int8_paged": cb_kv_bytes_int8,
        },
        "packed_boot": {
            "save_s": t_ck_save.dt, "load_s": t_ck_load.dt,
            "disk_bytes": ck_disk_bytes,
            "format_version": codr.CODR_FORMAT_VERSION,
        },
        "serve_faults": {
            "requests": n_fault_req,
            "retry_policy": {"max_retries": 3, "backoff_s": 0.001},
            **{m: s for m, s in fault_stats.items()},
        },
        "bits_per_weight": compiled.bits_per_weight(),
        "trace_count": compiled.trace_count,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def cli(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="tiny model (CI smoke run)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--json", default="BENCH_engine.json",
                    help="JSON output path ('' disables)")
    args = ap.parse_args(argv)
    if args.batch < 1 or args.iters < 1:
        ap.error("--batch and --iters must be >= 1")
    print("name,us_per_call,derived")
    main(small=args.small, batch=args.batch, iters=args.iters,
         json_path=args.json or None)


if __name__ == "__main__":
    cli()
