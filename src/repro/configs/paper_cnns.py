"""The paper's own benchmark CNNs (§V-A): AlexNet, VGG16, GoogleNet —
conv-layer shape tables used by the compression / SRAM-access / energy
reproductions.  Shapes are the canonical published layer dims
(Krizhevsky'12, Simonyan'14, Szegedy'15)."""
from __future__ import annotations

from repro.core.dataflow import ConvShape

# (M, N, RK, CK, RI, CI, stride) — RI/CI include any padding the nets use
ALEXNET = [
    ConvShape(96, 3, 11, 11, 227, 227, 4),
    ConvShape(256, 96, 5, 5, 31, 31, 1),
    ConvShape(384, 256, 3, 3, 15, 15, 1),
    ConvShape(384, 384, 3, 3, 15, 15, 1),
    ConvShape(256, 384, 3, 3, 15, 15, 1),
]

VGG16 = [
    ConvShape(64, 3, 3, 3, 226, 226, 1),
    ConvShape(64, 64, 3, 3, 226, 226, 1),
    ConvShape(128, 64, 3, 3, 114, 114, 1),
    ConvShape(128, 128, 3, 3, 114, 114, 1),
    ConvShape(256, 128, 3, 3, 58, 58, 1),
    ConvShape(256, 256, 3, 3, 58, 58, 1),
    ConvShape(256, 256, 3, 3, 58, 58, 1),
    ConvShape(512, 256, 3, 3, 30, 30, 1),
    ConvShape(512, 512, 3, 3, 30, 30, 1),
    ConvShape(512, 512, 3, 3, 30, 30, 1),
    ConvShape(512, 512, 3, 3, 16, 16, 1),
    ConvShape(512, 512, 3, 3, 16, 16, 1),
    ConvShape(512, 512, 3, 3, 16, 16, 1),
]

# GoogleNet: representative inception branch convs (3a–5b 3×3/5×5/1×1)
GOOGLENET = [
    ConvShape(64, 3, 7, 7, 229, 229, 2),
    ConvShape(192, 64, 3, 3, 58, 58, 1),
    ConvShape(128, 96, 3, 3, 30, 30, 1),
    ConvShape(192, 128, 3, 3, 30, 30, 1),
    ConvShape(208, 96, 3, 3, 16, 16, 1),
    ConvShape(224, 112, 3, 3, 16, 16, 1),
    ConvShape(256, 128, 3, 3, 16, 16, 1),
    ConvShape(288, 144, 3, 3, 16, 16, 1),
    ConvShape(320, 160, 3, 3, 16, 16, 1),
    ConvShape(384, 192, 3, 3, 9, 9, 1),
    ConvShape(48, 16, 5, 5, 32, 32, 1),
    ConvShape(128, 32, 5, 5, 18, 18, 1),
]

PAPER_CNNS = {"alexnet": ALEXNET, "vgg16": VGG16, "googlenet": GOOGLENET}
