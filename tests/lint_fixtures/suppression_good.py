"""codrlint fixture: suppressions carrying a reviewable rationale."""


def swallow_same_line():
    try:
        risky()                     # noqa: F821
    except Exception:  # codrlint: disable=exception-hygiene — fixture: deliberate swallow proving same-line suppression
        pass


def swallow_line_above():
    try:
        risky()                     # noqa: F821
    # codrlint: disable=exception-hygiene — fixture: deliberate swallow proving comment-above suppression
    except Exception:
        pass
