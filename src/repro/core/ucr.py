"""Universal Computation Reuse (paper §II-D).

Offline pipeline, once per model (zero on-chip overhead, as the paper
notes):

  (i)   tile a conv layer into T_N input × T_M output channel tiles;
  (ii)  quantize weights to 8-bit fixed point;
  (iii) regroup the tile's weights per input channel into T_N vectors of
        length ``T_M * R_K * C_K``;
  (iv)  sort → densify (drop zeros) → unify (deduplicate);
  (v)   emit the Δs of the non-zero unique weights, per-repetition output
        indexes, and repetition counts, and hand them to the customized
        RLE encoders (:mod:`repro.core.rle`).

The same transform applies verbatim to fully-connected / linear layers
(paper Fig. 1 is an FC multiplication model): a linear layer is a conv
with R_K = C_K = 1, so a weight *column* (all output neurons for one
input) is a vector of length T_M.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import rle

__all__ = [
    "UCRVector", "ucr_transform", "ucr_reconstruct",
    "quantize_int8", "dequantize_int8", "restrict_unique",
    "encode_conv_layer", "encode_linear_layer", "LayerCode",
    "layer_ucr_vectors", "layer_code_size_only",
]


@dataclasses.dataclass
class UCRVector:
    """Sort/densify/unify decomposition of one weight vector."""

    unique_vals: np.ndarray   # sorted ascending non-zero unique int8 values
    reps: np.ndarray          # repetition count per unique value
    indexes: np.ndarray       # flat per-repetition positions (ascending per group)
    vector_len: int

    @property
    def n_nonzero(self) -> int:
        return int(self.reps.sum())

    @property
    def density(self) -> float:
        return self.n_nonzero / max(self.vector_len, 1)


def ucr_transform(w: np.ndarray) -> UCRVector:
    """Sort, densify, and unify an int8 weight vector (paper Fig. 1 e/g/h)."""
    w = np.asarray(w).reshape(-1)
    nz = np.nonzero(w)[0]
    vals = w[nz].astype(np.int64)
    unique_vals, inverse, reps = np.unique(vals, return_inverse=True,
                                           return_counts=True)
    # per-unique ascending position lists, concatenated in unique order:
    # lexsort by (position) within (unique id) — positions nz are already
    # ascending, so a stable sort on the unique id keeps them ascending.
    order = np.argsort(inverse, kind="stable")
    indexes = nz[order]
    return UCRVector(unique_vals, reps, indexes, int(w.size))


def ucr_reconstruct(u: UCRVector) -> np.ndarray:
    """Inverse transform — rebuilds the dense int8 vector."""
    w = np.zeros(u.vector_len, dtype=np.int8)
    cursor = 0
    for val, rep in zip(u.unique_vals, u.reps):
        idx = u.indexes[cursor : cursor + int(rep)]
        w[idx] = val
        cursor += int(rep)
    return w


# ---------------------------------------------------------------------------
# quantization (paper step ii — 8-bit fixed point, symmetric per-tensor)
# ---------------------------------------------------------------------------

def quantize_int8(w: np.ndarray, *, per_channel_axis: int | None = None
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric int8 quantization.  Returns ``(q, scale)`` with
    ``w ≈ q * scale``."""
    w = np.asarray(w, dtype=np.float32)
    if per_channel_axis is None:
        amax = np.abs(w).max()
        scale = np.float32(amax / 127.0 if amax > 0 else 1.0)
        q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
        return q, np.asarray(scale)
    axes = tuple(i for i in range(w.ndim) if i != per_channel_axis)
    amax = np.abs(w).max(axis=axes, keepdims=True)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize_int8(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * scale


def restrict_unique(q: np.ndarray, n_unique: int) -> np.ndarray:
    """Limit an int8 tensor to ``n_unique`` levels TOTAL including the
    zero level (the paper's U knob; zero is counted here so a U-level
    tensor packs into exactly ``log2(U)``-bit indices on TPU):
    uniform re-quantization of the int8 grid, keeping 0 exactly 0."""
    if n_unique >= 256:
        return q
    step = -(-256 // (n_unique - 1))           # ceil → ≤ n_unique-1 nonzero
    out = (q.astype(np.int32) + 128) // step * step - 128 + step // 2
    out = np.where(q == 0, 0, np.clip(out, -127, 127))
    return out.astype(np.int8)


# ---------------------------------------------------------------------------
# whole-layer encoding
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LayerCode:
    """CoDR code for one layer: one EncodedVector per (tile, input channel).

    ``shape`` is the original weight shape — ``(M, N, R_K, C_K)`` for conv,
    ``(M, N)`` for linear.  Encoding parameters are shared per layer per
    structure (paper §III-C) and counted once in ``total_bits``.
    """

    vectors: list[rle.EncodedVector]
    ucr: list[UCRVector]
    shape: tuple[int, ...]
    scale: np.ndarray
    t_m: int
    t_n: int
    params: tuple[int, int, int] = (4, 4, 4)

    @property
    def total_bits(self) -> int:
        payload = sum(v.deltas.nbits + v.reps.nbits + v.indexes.nbits
                      for v in self.vectors)
        return payload + 3 * rle.HEADER_BITS

    @property
    def n_weights(self) -> int:
        return int(np.prod(self.shape))

    @property
    def bits_per_weight(self) -> float:
        return self.total_bits / max(self.n_weights, 1)


def _iter_tile_vectors(q: np.ndarray, t_m: int, t_n: int):
    """Yield (vector, vector_len) for every (output-tile, input-channel)
    pair.  ``q`` is ``(M, N, R_K, C_K)`` int8."""
    m, n = q.shape[0], q.shape[1]
    kernel = int(np.prod(q.shape[2:])) if q.ndim > 2 else 1
    qr = q.reshape(m, n, kernel)
    for m0 in range(0, m, t_m):
        tile_m = qr[m0 : m0 + t_m]                    # (tm, N, K)
        for n0 in range(0, n, t_n):
            for nn in range(n0, min(n0 + t_n, n)):
                vec = tile_m[:, nn, :].reshape(-1)    # length tm*K
                yield vec


def encode_conv_layer(w: np.ndarray, *, t_m: int = 4, t_n: int = 4,
                      n_unique: int = 256,
                      params: tuple[int, int, int] | None = None) -> LayerCode:
    """Full offline pipeline for a conv weight ``(M, N, R_K, C_K)`` (float).

    ``n_unique`` — the paper's U knob (Fig. 6): restrict the quantized
    grid to ``n_unique`` total levels before the UCR transform.
    ``params`` — optional fixed (delta, rep, index) RLE bit-lengths;
    ``None`` runs the per-layer, per-structure search of §III-C.
    """
    q, scale = quantize_int8(w)
    if n_unique < 256:
        q = restrict_unique(q, n_unique)
    ucrs = [ucr_transform(vec) for vec in _iter_tile_vectors(q, t_m, t_n)]
    vector_len = max((u.vector_len for u in ucrs), default=2)
    if params is None:
        params = rle.layer_params_search(ucrs, vector_len)
    else:
        params = tuple(int(p) for p in params)
        if len(params) != 3 or any(p < 1 for p in params):
            raise ValueError(f"rle params must be 3 positive bit-lengths, "
                             f"got {params}")
    vectors = [rle.encode_vector(u.unique_vals, u.reps, u.indexes,
                                 u.vector_len, params=params)
               for u in ucrs]
    return LayerCode(vectors, ucrs, tuple(w.shape), scale, t_m, t_n, params)


def encode_linear_layer(w: np.ndarray, *, t_m: int = 256, t_n: int = 1,
                        n_unique: int = 256,
                        params: tuple[int, int, int] | None = None
                        ) -> LayerCode:
    """Linear layer ``(M, N)`` = conv with a 1×1 kernel."""
    return encode_conv_layer(np.asarray(w)[:, :, None, None], t_m=t_m,
                             t_n=t_n, n_unique=n_unique, params=params)


def layer_ucr_vectors(q: np.ndarray, *, t_m: int = 4, t_n: int = 4
                      ) -> list[UCRVector]:
    """UCR vectors of an int8 layer under a tile geometry — the
    sort/densify/unify half of the pipeline without any RLE bitstream.
    The tuner (:mod:`repro.tune`) scores candidate tile geometries with
    this + :func:`repro.core.rle.layer_bits_size_only`."""
    q = np.asarray(q)
    if q.ndim == 2:
        q = q[:, :, None, None]
    return [ucr_transform(vec) for vec in _iter_tile_vectors(q, t_m, t_n)]


def layer_code_size_only(w: np.ndarray, *, t_m: int = 4, t_n: int = 4,
                         n_unique: int = 256,
                         params: tuple[int, int, int] | None = None
                         ) -> tuple[int, int]:
    """Fast path: (total encoded bits, total weights) without bitstreams.

    Accepts the same U budget / fixed-RLE-params knobs as
    :func:`encode_conv_layer` so size predictions and real encodes agree.
    """
    q, _ = quantize_int8(w)
    if n_unique < 256:
        q = restrict_unique(q, n_unique)
    if q.ndim == 2:
        q = q[:, :, None, None]
    ucrs = [ucr_transform(vec) for vec in _iter_tile_vectors(q, t_m, t_n)]
    vector_len = max((u.vector_len for u in ucrs), default=2)
    return (rle.layer_bits_size_only(ucrs, vector_len, params=params),
            int(np.prod(q.shape)))
