"""Quickstart: train a reduced-config model end-to-end on CPU.

    PYTHONPATH=src python examples/quickstart.py --steps 100

Uses the real framework stack: synthetic-but-structured data pipeline →
model zoo → AdamW(+clip, cosine) → fault-tolerant loop with atomic async
checkpoints.  Loss decreases because the data has learnable n-gram
motifs.
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.data import DataConfig, host_batch_iterator
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.runtime import TrainLoop, TrainLoopConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} ({n/1e6:.2f}M params), "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, motif_prob=0.8,
                      frontend=cfg.frontend, frontend_seq=cfg.frontend_seq,
                      d_model=cfg.d_model)
    with tempfile.TemporaryDirectory() as ckpt:
        loop = TrainLoop(
            train_loss_fn=lambda p, b: api.train_loss(p, b, cfg),
            params=params,
            batch_iter=host_batch_iterator(dcfg),
            opt_cfg=AdamWConfig(lr=3e-3, use_master=False),
            loop_cfg=TrainLoopConfig(total_steps=args.steps,
                                     checkpoint_every=max(args.steps // 2, 1),
                                     ckpt_dir=ckpt, peak_lr=3e-3,
                                     warmup_steps=min(10, args.steps // 3)))
        hist = loop.run()
    k = max(min(10, len(hist) // 3), 1)
    first = np.mean([h["loss"] for h in hist[:k]])
    last = np.mean([h["loss"] for h in hist[-k:]])
    print(f"loss: {first:.4f} -> {last:.4f} over {len(hist)} steps "
          f"({'improved' if last < first else 'flat'})")


if __name__ == "__main__":
    main()
