"""Property tests for the tuning lane's cost-model invariants.

Three claims the autotuner's selection logic leans on (deterministic
fixed-seed twins live in ``tests/test_tune.py`` so tier-1 covers them
without the optional dependency):

1. ``codr_accesses`` is monotone in the tile counts — growing ``t_m``
   never increases input SRAM traffic; shrinking the spatial tile never
   decreases weight re-streaming.
2. ``energy()`` totals are exactly the sum of their components — the
   greedy budget walk sums per-layer energies and assumes no
   cross-component interaction.
3. The §III-C per-layer RLE parameter search never beats the exhaustive
   fixed-width sweep over the same space (the sweep is the oracle), so
   ``rle_params=None`` is always a safe default in a ``TuneGrid``.
"""
import itertools

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import cost_model, dataflow, rle, ucr
from repro.core.dataflow import ConvShape


def conv_shapes():
    return st.builds(
        ConvShape,
        st.integers(1, 128),          # m
        st.integers(1, 64),           # n
        st.just(3), st.just(3),       # rk, ck
        st.integers(4, 32),           # ri
        st.integers(4, 32),           # ci
        st.just(1))


@given(conv_shapes(), st.integers(1, 16), st.integers(1, 16),
       st.floats(1e2, 1e7), st.floats(1.0, 1e4), st.floats(1.0, 1e5))
@settings(max_examples=100, deadline=None)
def test_codr_accesses_monotone_in_t_m(shape, t_m_a, t_m_b, bits, nu, nn):
    lo, hi = sorted((t_m_a, t_m_b))
    acc_lo = dataflow.codr_accesses(shape, dataflow.codr_tiling(lo),
                                    bits, nu, nn)
    acc_hi = dataflow.codr_accesses(shape, dataflow.codr_tiling(hi),
                                    bits, nu, nn)
    assert acc_hi.input_sram <= acc_lo.input_sram
    assert acc_hi.output_sram == acc_lo.output_sram
    assert acc_hi.weight_sram_rows == acc_lo.weight_sram_rows


@given(conv_shapes(), st.integers(1, 8), st.integers(1, 8),
       st.floats(1e2, 1e7))
@settings(max_examples=100, deadline=None)
def test_weight_restream_monotone_in_spatial_tile(shape, t_sp_a, t_sp_b,
                                                  bits):
    import dataclasses
    lo, hi = sorted((t_sp_a, t_sp_b))
    cfg_small = dataclasses.replace(dataflow.CODR_TILING, t_ro=lo, t_co=lo)
    cfg_big = dataclasses.replace(dataflow.CODR_TILING, t_ro=hi, t_co=hi)
    a_small = dataflow.codr_accesses(shape, cfg_small, bits, 10.0, 10.0)
    a_big = dataflow.codr_accesses(shape, cfg_big, bits, 10.0, 10.0)
    assert a_small.weight_sram_rows >= a_big.weight_sram_rows


@given(conv_shapes(), st.floats(1e2, 1e7), st.floats(1.0, 1e4),
       st.floats(1.0, 1e5))
@settings(max_examples=100, deadline=None)
def test_energy_total_is_sum_of_components(shape, bits, nu, nn):
    acc = dataflow.codr_accesses(shape, dataflow.CODR_TILING, bits, nu, nn)
    e = cost_model.energy(acc)
    assert e.total_uj == pytest.approx(
        e.dram_uj + e.sram_uj + e.rf_uj + e.alu_uj + e.crossbar_uj,
        rel=1e-12)


@given(st.lists(st.integers(-128, 127), min_size=1, max_size=64),
       st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_rle_search_never_beats_exhaustive_sweep(vals, n_vecs):
    w = np.array(vals * n_vecs, dtype=np.int8)
    vector_len = len(vals)
    vecs = [ucr.ucr_transform(w[i * vector_len:(i + 1) * vector_len])
            for i in range(n_vecs)]
    searched = rle.layer_bits_size_only(vecs, vector_len)
    oracle = min(
        rle.layer_bits_size_only(vecs, vector_len, params=p)
        for p in itertools.product(rle.PARAM_SEARCH_SPACE, repeat=3))
    assert oracle <= searched
