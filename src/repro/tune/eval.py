"""Compression-quality eval harness: every knob gets a measured number.

Quality here is *agreement with the dense oracle*, not task accuracy —
no pretrained checkpoints ship offline (docs/DESIGN.md §6), so the
reproduction target is how far the compressed execution drifts from the
uncompressed forward at each bits/weight point:

* **CNN lane** (:func:`cnn_quality`): top-1 logit agreement and mean
  absolute / relative logit error of ``CompiledModel.run`` vs
  ``CompiledModel.reference`` on a fixed input batch.
* **Transformer lane** (:func:`transformer_quality`): perplexity proxy —
  mean absolute logit error and argmax (next-token) agreement of the
  packed forward vs the dense forward over the ``configs/`` smoke zoo.
* **Pareto curves** (:func:`pareto_curve`): quality-vs-bits/weight for a
  sweep of global U budgets plus any tuned plans, the Fig. 6 U-sweep
  with a quality axis attached — written to ``BENCH_tune.json`` by
  ``benchmarks/compression.py``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.api import CompiledModel, EncodeConfig, ModelSpec, compile

__all__ = ["eval_batch", "cnn_quality", "pareto_curve",
           "transformer_quality"]


def eval_batch(spec: ModelSpec, input_hw: tuple[int, int],
               batch: int = 8, seed: int = 0) -> np.ndarray:
    """A deterministic NHWC (or ``(B, N)`` for linear-first specs) eval
    batch shaped for the spec's first layer."""
    rng = np.random.default_rng(seed)
    first = spec.layers[0]
    if first.kind == "conv":
        ri, ci = input_hw
        shape = (batch, ri, ci, first.in_features)
    else:
        shape = (batch, first.in_features)
    return rng.normal(size=shape).astype(np.float32)


def cnn_quality(compiled: CompiledModel, x: np.ndarray) -> dict:
    """Logit agreement of the compressed forward vs the dense oracle."""
    y = np.asarray(compiled.run(x))
    ref = np.asarray(compiled.reference(x))
    y2 = y.reshape(y.shape[0], -1)
    ref2 = ref.reshape(ref.shape[0], -1)
    denom = float(np.linalg.norm(ref2)) or 1.0
    return {
        "top1_match": float(np.mean(np.argmax(y2, -1) == np.argmax(ref2, -1))),
        "mean_abs_logit_err": float(np.abs(y2 - ref2).mean()),
        "rel_logit_err": float(np.linalg.norm(y2 - ref2)) / denom,
    }


def _point(tag: str, compiled: CompiledModel,
           input_hw: tuple[int, int], x: np.ndarray) -> dict:
    sram = sum(acc.total_sram for _, acc in
               compiled.sram_report(input_hw, per_layer_tiling=True))
    return {"tag": tag,
            "bits_per_weight": compiled.bits_per_weight(),
            "sram_accesses": float(sram),
            "config": compiled.config.metadata(),
            **cnn_quality(compiled, x)}


def pareto_curve(spec: ModelSpec, input_hw: tuple[int, int], *,
                 n_uniques=(8, 16, 32, 64, 256),
                 base: EncodeConfig | None = None,
                 plans: dict | None = None,
                 batch: int = 8, seed: int = 0,
                 backend: str = "tiled") -> list[dict]:
    """Quality-vs-bits/weight curve: one point per global U budget, plus
    one per named tuned plan (``plans={tag: TunePlan}``).  Every point
    carries measured bits/weight, measured per-layer-tiling SRAM
    accesses, and the :func:`cnn_quality` agreement numbers."""
    base = EncodeConfig() if base is None else base
    x = eval_batch(spec, input_hw, batch=batch, seed=seed)
    points = []
    for u in n_uniques:
        cfg = dataclasses.replace(base, n_unique=int(u))
        compiled = compile(spec, cfg, backend=backend)
        points.append(_point(f"U{u}", compiled, input_hw, x))
    for tag, plan in (plans or {}).items():
        compiled = compile(spec, base, backend=backend, plan=plan)
        points.append(_point(tag, compiled, input_hw, x))
    return points


def transformer_quality(arch: str, *, plan=None,
                        config: EncodeConfig | None = None,
                        backend: str = "tiled",
                        batch: int = 2, prompt_len: int = 8,
                        seed: int = 0) -> dict:
    """Perplexity proxy for one ``configs/`` zoo arch: mean absolute
    logit error + next-token argmax agreement of the packed prefill vs
    the dense prefill on the smoke variant.  ``backend="tiled"`` is the
    bit-exact decode-then-matmul lane (CPU-friendly); pass
    ``"codr_matmul"`` to measure through the fused kernel instead."""
    import jax

    import repro.api as codr
    from repro.configs import get_config, smoke_variant
    from repro.models import get_model

    cfg = smoke_variant(get_config(arch))
    api = get_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = api.init_params(key, cfg)
    config = EncodeConfig(n_unique=16) if config is None else config

    cp = codr.compile_params(params, config, backend=backend, plan=plan)
    tokens = jax.random.randint(key, (batch, prompt_len), 0,
                                cfg.vocab_size)
    batch_in = {"tokens": tokens}
    if cfg.frontend or cfg.family == "encdec":
        import jax.numpy as jnp
        batch_in["prefix"] = jax.random.normal(
            key, (batch, cfg.frontend_seq, cfg.d_model),
            dtype=jnp.float32)
    dense_logits, _ = api.prefill(params, batch_in, cfg)
    packed_logits, _ = api.prefill(cp.params, batch_in, cfg)
    d = np.asarray(dense_logits, dtype=np.float32)
    p = np.asarray(packed_logits, dtype=np.float32)
    return {
        "arch": arch,
        "bits_per_weight": cp.bits_per_weight(),
        "hbm_mb": cp.hbm_bytes() / 1e6,
        "mean_abs_logit_err": float(np.abs(d - p).mean()),
        "argmax_agreement": float(np.mean(
            np.argmax(d[:, -1], -1) == np.argmax(p[:, -1], -1))),
        "n_packed": len(cp.packed_paths),
    }
