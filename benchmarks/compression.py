"""Paper Fig. 6 — weight compression across three CNNs, swept over
density (D) and unique-weight count (U).  Reports bits/weight for CoDR's
customized RLE vs UCNN (fixed 5-bit RLE + transition bits) and SCNN
(8-bit weights + 4-bit zero run lengths), and the headline ratios
(paper: CoDR 1.69× vs UCNN, 2.80× vs SCNN on the original profiles)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import BASE_DENSITY, Timer, csv_line, \
    make_weights, sampled_layer_vectors
from repro.configs.paper_cnns import PAPER_CNNS
from repro.core import rle
from repro.core.baselines.scnn import scnn_compress_bits
from repro.core.baselines.ucnn import ucnn_vector_bits
from repro.core.dataflow import CODR_TILING

# the paper's sweep: middle group = original profile; right groups lower
# density; left groups fewer unique weights
SWEEPS = [
    ("U16", 1.0, 16), ("U64", 1.0, 64),
    ("orig", 1.0, 256),
    ("D0.6", 0.6, 256), ("D0.4", 0.4, 256), ("D0.2", 0.2, 256),
]


def model_bits(model: str, density: float, n_unique: int, rng) -> dict:
    codr = ucnn = scnn = total_w = 0.0
    for shape in PAPER_CNNS[model]:
        q = make_weights((shape.m, shape.n, shape.rk, shape.ck),
                         density=density * BASE_DENSITY[model],
                         n_unique=n_unique, rng=rng)
        vecs, scale = sampled_layer_vectors(q, CODR_TILING.t_m,
                                            CODR_TILING.t_n)
        codr += scale * rle.layer_bits_size_only(
            vecs, CODR_TILING.t_m * shape.rk * shape.ck)
        ucnn += scale * sum(ucnn_vector_bits(u) for u in vecs)
        scnn += scnn_compress_bits(q)
        total_w += shape.n_weights
    return {"codr_bpw": codr / total_w, "ucnn_bpw": ucnn / total_w,
            "scnn_bpw": scnn / total_w,
            "vs_ucnn": ucnn / codr, "vs_scnn": scnn / codr}


def main(print_fn=print) -> list[str]:
    rng = np.random.default_rng(0)
    lines = []
    ratios_u, ratios_s = [], []
    for model in PAPER_CNNS:
        for tag, density, n_unique in SWEEPS:
            with Timer() as t:
                r = model_bits(model, density, n_unique, rng)
            name = f"fig6_compression/{model}/{tag}"
            derived = (f"codr={r['codr_bpw']:.2f}bpw"
                       f";ucnn={r['ucnn_bpw']:.2f}"
                       f";scnn={r['scnn_bpw']:.2f}"
                       f";x_ucnn={r['vs_ucnn']:.2f}"
                       f";x_scnn={r['vs_scnn']:.2f}")
            lines.append(csv_line(name, t.dt * 1e6, derived))
            print_fn(lines[-1])
            ratios_u.append(r["vs_ucnn"])
            ratios_s.append(r["vs_scnn"])
    lines.append(csv_line(
        "fig6_compression/MEAN", 0.0,
        f"x_ucnn={np.mean(ratios_u):.2f}(paper:1.69)"
        f";x_scnn={np.mean(ratios_s):.2f}(paper:2.80)"))
    print_fn(lines[-1])
    return lines


if __name__ == "__main__":
    main()
