"""HLO parsing: collective byte accounting for the roofline's third term.

``cost_analysis()`` does not report collective traffic, so we parse the
optimized HLO text and sum the operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.

Two subtleties:

* Bytes are **per participating device** (shapes in partitioned HLO are
  already per-device): we take each collective's result shape — the
  buffer a device materializes/moves — which is the quantity to divide
  by per-chip link bandwidth.
* ``lax.scan`` lowers to a ``while`` whose body appears ONCE in the text
  but executes trip-count times.  We build the computation call graph
  (while body/cond, call, conditional branches), extract loop trip
  counts from the condition's comparison constant, and multiply nested
  collective bytes by the product of enclosing trip counts.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[\w\[\]{},]+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_CALLEE_RE = re.compile(r"(?:to_apply|branch_computations)="
                        r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_WHILE_RE = re.compile(r"\bwhile\(.*?condition=%?([\w.\-]+),\s*"
                       r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """Computations start at column 0 with ``%name (...`` (or ``ENTRY``)
    and end with a column-0 ``}``."""
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    entry: str | None = None
    for line in hlo_text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
                continue
        else:
            if line.rstrip() == "}":
                cur = None
                continue
            comps[cur].append(line.strip())
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Loop trip count ≈ the largest scalar integer constant compared in
    the condition (exact for lax.scan's canonical counter)."""
    best = 1
    for ln in cond_lines:
        for m in _CONST_RE.finditer(ln):
            best = max(best, int(m.group(1)))
    return best


_DOT_RE = re.compile(r"%([\w.\-]+)\s+=\s+((?:\([^)]*\))|(?:[\w\[\]{},]+))"
                     r"\s+dot\((?:%?([\w.\-]+)(?:,\s*%?([\w.\-]+))?)?\)?")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s+=\s+"
                     r"((?:\([^)]*\))|(?:[\w\[\]{},]+))\s+([\w\-]+)\(")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(((?:%[\w.\-]+(?:,\s*)?)+)\)")


def _dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def _elems(shape_str: str) -> int:
    n = 1
    for d in _dims(shape_str):
        n *= d
    return n


def flops_bytes_from_hlo(hlo_text: str) -> dict:
    """Loop-aware FLOP and HBM-byte accounting from optimized HLO.

    ``compiled.cost_analysis()`` counts each while body ONCE regardless of
    trip count, which under-counts scanned-layer models by n_layers×.  We
    re-derive:

      * FLOPs — 2·result_elems·K for every ``dot`` (K = product of the
        lhs contracting dims), multiplied through the call graph (while
        trip counts via backend_config known_trip_count).  Elementwise
        FLOPs are ignored («1% for matmul-dominated graphs).
      * bytes — for every *materializing* op (anything except nested
        computations' internals; fusion internals stay in registers) the
        result bytes + resolvable operand bytes, with the same
        multipliers.  This approximates HBM traffic under the standard
        "fusions materialize only their boundaries" model.
    """
    comps = _split_computations(hlo_text)
    if "__entry__" not in comps:
        comps = {"__entry__": hlo_text.splitlines()}

    per_flops: dict[str, float] = {}
    per_bytes: dict[str, float] = {}
    callees: dict[str, list[tuple[str, int]]] = {}
    fusion_comps: set[str] = set()

    # first pass: per-computation shape tables
    shape_tables: dict[str, dict[str, str]] = {}
    for name, lines in comps.items():
        table: dict[str, str] = {}
        for ln in lines:
            md = _DEF_RE.match(ln)
            if md:
                table[md.group(1)] = md.group(2)
        shape_tables[name] = table

    for name, lines in comps.items():
        fl = 0.0
        by = 0.0
        calls: list[tuple[str, int]] = []
        table = shape_tables[name]
        for ln in lines:
            mw = _WHILE_RE.search(ln)
            if mw:
                cond, body = mw.group(1), mw.group(2)
                mt = _TRIP_RE.search(ln)
                tc = int(mt.group(1)) if mt \
                    else _trip_count(comps.get(cond, []))
                calls.append((body, tc))
                calls.append((cond, tc))
            else:
                me = _CALLEE_RE.search(ln)
                if me:
                    for callee in re.split(r",\s*", me.group(1)):
                        calls.append((callee.lstrip("%"), 1))
                mcall = re.search(r"calls=%?([\w.\-]+)", ln)
                if mcall:
                    fusion_comps.add(mcall.group(1))
                    calls.append((mcall.group(1), 1))
            md = _DEF_RE.match(ln)
            if not md:
                continue
            res_shape, op_kind = md.group(2), md.group(3)
            if op_kind == "dot":
                mc = _LHS_CONTRACT_RE.search(ln)
                ops = re.search(r"dot\(%?([\w.\-]+)", ln)
                k = 1
                if mc and ops:
                    lhs_shape = table.get(ops.group(1), "")
                    ldims = _dims(lhs_shape)
                    if mc.group(1):
                        for d in mc.group(1).split(","):
                            di = int(d)
                            if di < len(ldims):
                                k *= ldims[di]
                fl += 2.0 * _elems(res_shape) * k
            # bytes: result + operands (parameters & tuples excluded)
            if op_kind in ("parameter", "tuple", "get-tuple-element",
                           "constant", "bitcast", "while", "conditional"):
                continue
            mops = _OPERANDS_RE.search(ln[ln.find(op_kind + "("):])
            opnames = (re.findall(r"%([\w.\-]+)", mops.group(1))
                       if mops else [])
            if op_kind == "dynamic-update-slice" and len(opnames) >= 2:
                # in-place update: traffic = read+write of the slice only
                b = 2 * _shape_bytes(table.get(opnames[1], ""))
            elif op_kind in ("dynamic-slice", "gather"):
                # reads only the sliced region ≈ result size
                b = 2 * _shape_bytes(res_shape)
            else:
                b = _shape_bytes(res_shape)
                for opname in opnames:
                    b += _shape_bytes(table.get(opname, ""))
            by += b
        per_flops[name] = fl
        per_bytes[name] = by
        callees[name] = calls

    total = {"flops": 0.0, "bytes": 0.0}
    stack: list[str] = []

    def visit(name: str, mult: float) -> None:
        if name not in per_flops or name in stack:
            return
        stack.append(name)
        total["flops"] += per_flops[name] * mult
        if name not in fusion_comps:      # fusion internals ≠ HBM traffic
            total["bytes"] += per_bytes[name] * mult
        for callee, tc in callees[name]:
            visit(callee, mult * tc)
        stack.pop()

    visit("__entry__", 1.0)
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    comps = _split_computations(hlo_text)
    if "__entry__" not in comps:
        # fall back: treat whole text as one computation
        comps = {"__entry__": hlo_text.splitlines()}

    # per-computation direct collective bytes + callees
    direct: dict[str, dict[str, float]] = {}
    counts: dict[str, dict[str, int]] = {}
    callees: dict[str, list[tuple[str, int]]] = {}
    for name, lines in comps.items():
        d = {k: 0.0 for k in _COLLECTIVES}
        c = {k: 0 for k in _COLLECTIVES}
        calls: list[tuple[str, int]] = []
        for ln in lines:
            mw = _WHILE_RE.search(ln)
            if mw:
                cond, body = mw.group(1), mw.group(2)
                mt = _TRIP_RE.search(ln)
                tc = int(mt.group(1)) if mt \
                    else _trip_count(comps.get(cond, []))
                calls.append((body, tc))
                calls.append((cond, tc))
                continue
            if "-done(" in ln:
                continue
            mc = _COLL_RE.search(ln)
            if mc:
                d[mc.group(2)] += _shape_bytes(mc.group(1))
                c[mc.group(2)] += 1
                continue
            me = _CALLEE_RE.search(ln)
            if me:
                for callee in re.split(r",\s*", me.group(1)):
                    calls.append((callee.lstrip("%"), 1))
        direct[name] = d
        counts[name] = c
        callees[name] = calls

    # propagate multipliers from entry through the call graph
    total = {k: 0.0 for k in _COLLECTIVES}
    total_counts = {k: 0 for k in _COLLECTIVES}
    seen_stack: list[str] = []

    def visit(name: str, mult: float) -> None:
        if name not in direct or name in seen_stack:
            return
        seen_stack.append(name)
        for k in _COLLECTIVES:
            total[k] += direct[name][k] * mult
            total_counts[k] += int(counts[name][k] * mult)
        for callee, tc in callees[name]:
            visit(callee, mult * tc)
        seen_stack.pop()

    visit("__entry__", 1.0)
    return {"by_op_bytes": total, "by_op_count": total_counts,
            "total_bytes": sum(total.values())}
