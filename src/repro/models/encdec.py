"""Encoder–decoder transformer (SeamlessM4T backbone).

The audio frontend is a stub per the assignment spec: ``input_specs()``
feeds precomputed frame embeddings (B, S_frames, d) straight into the
encoder.  Encoder layers are bidirectional GQA; decoder layers are causal
self-attention + cross-attention into the cached encoder output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.common import (DEFAULT_DTYPE, constrain_tokens, dense_init,
                                 embed_init, embedding_lookup, unembed,
                                 linear, norm_apply, norm_init,
                                 softmax_xent)


def _init_enc_layer(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    return {"norm1": norm_init(cfg.d_model, cfg.norm_type),
            "mixer": attn.gqa_init(k1, cfg),
            "norm2": norm_init(cfg.d_model, cfg.norm_type),
            "mlp": moe_mod.mlp_init(k2, cfg.d_model, cfg.d_ff, gated=False)}


def _init_dec_layer(key, cfg) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": norm_init(cfg.d_model, cfg.norm_type),
            "self_attn": attn.gqa_init(k1, cfg),
            "norm_x": norm_init(cfg.d_model, cfg.norm_type),
            "cross_attn": attn.gqa_init(k2, cfg),
            "norm2": norm_init(cfg.d_model, cfg.norm_type),
            "mlp": moe_mod.mlp_init(k3, cfg.d_model, cfg.d_ff, gated=False)}


def init_params(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
        "enc_stack": jax.vmap(lambda k: _init_enc_layer(k, cfg))(
            jax.random.split(ks[1], cfg.n_encoder_layers)),
        "dec_stack": jax.vmap(lambda k: _init_dec_layer(k, cfg))(
            jax.random.split(ks[2], cfg.n_periods)),
        "enc_norm": norm_init(cfg.d_model, cfg.norm_type),
        "final_norm": norm_init(cfg.d_model, cfg.norm_type),
        "out_embed": embed_init(ks[3], cfg.vocab_size, cfg.d_model),
    }


def encode(params, frames, cfg):
    """frames (B, S_enc, d) precomputed embeddings → encoder output."""
    x = frames.astype(DEFAULT_DTYPE)
    x = constrain_tokens(x)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(xc, lp):
        h = norm_apply(xc, lp["norm1"], cfg.norm_type, f32=cfg.norm_f32)
        out, _ = attn.gqa_forward(lp["mixer"], h, cfg, positions, causal=False)
        xc = xc + out
        h = norm_apply(xc, lp["norm2"], cfg.norm_type, f32=cfg.norm_f32)
        xc = xc + moe_mod.mlp_forward(lp["mlp"], h, cfg.act)
        return constrain_tokens(xc), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["enc_stack"])
    return norm_apply(x, params["enc_norm"], cfg.norm_type, f32=cfg.norm_f32)


def _dec_block(lp, x, cfg, mode, cache, pos, positions, enc_out, enc_kv):
    # self attention
    h = norm_apply(x, lp["norm1"], cfg.norm_type, f32=cfg.norm_f32)
    if mode == "decode":
        out, new_self = attn.gqa_decode(lp["self_attn"], h, cfg,
                                        cache, pos)
    else:
        out, new_self = attn.gqa_forward(lp["self_attn"], h, cfg, positions)
    x = x + out
    # cross attention into encoder output
    h = norm_apply(x, lp["norm_x"], cfg.norm_type, f32=cfg.norm_f32)
    b, s = h.shape[:2]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(h, lp["cross_attn"]["q_proj"],
               lp["cross_attn"].get("q_bias")).reshape(b, s, hq, hd)
    if enc_kv is None:
        se = enc_out.shape[1]
        k = linear(enc_out, lp["cross_attn"]["k_proj"]).reshape(b, se, hkv, hd)
        v = linear(enc_out, lp["cross_attn"]["v_proj"]).reshape(b, se, hkv, hd)
    else:
        k, v = enc_kv
    if mode == "decode":
        out = attn.decode_attention(q, k, v, k.shape[1] - 1)
    else:
        out = attn.flash_attention(q, k, v, causal=False,
                                   q_chunk=cfg.attn_q_chunk,
                                   kv_chunk=cfg.attn_kv_chunk)
    out = linear(out.reshape(b, s, -1), lp["cross_attn"]["o_proj"])
    x = x + out
    h = norm_apply(x, lp["norm2"], cfg.norm_type, f32=cfg.norm_f32)
    x = x + moe_mod.mlp_forward(lp["mlp"], h, cfg.act)
    return constrain_tokens(x), new_self, (k, v)


def decode_forward(params, tokens, cfg, enc_out=None, *, mode="train",
                   cache=None, pos=None):
    x = embedding_lookup(params["embed"], tokens, DEFAULT_DTYPE)
    x = constrain_tokens(x)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    if mode == "train":
        def body(xc, lp):
            xc, _, _ = _dec_block(lp, xc, cfg, mode, None, pos, positions,
                                  enc_out, None)
            return xc, None
        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, params["dec_stack"])
        new_cache = None
    elif mode == "prefill":
        def body(xc, lp):
            xc, self_kv, cross_kv = _dec_block(lp, xc, cfg, mode, None, pos,
                                               positions, enc_out, None)
            return xc, {"self": self_kv, "cross": cross_kv}
        x, new_cache = jax.lax.scan(body, x, params["dec_stack"])
    else:
        def body(xc, xs):
            lp, c = xs
            xc, self_kv, _ = _dec_block(lp, xc, cfg, mode, c["self"], pos,
                                        positions, None, c["cross"])
            return xc, {"self": self_kv, "cross": c["cross"]}
        x, new_cache = jax.lax.scan(body, x, (params["dec_stack"], cache))

    x = norm_apply(x, params["final_norm"], cfg.norm_type, f32=cfg.norm_f32)
    if mode == "prefill":
        x = x[:, -1:]
    logits = unembed(x, params["out_embed"])
    return logits, new_cache


def train_loss(params, batch, cfg):
    enc_out = encode(params, batch["prefix"], cfg)
    logits, _ = decode_forward(params, batch["tokens"], cfg, enc_out,
                               mode="train")
    mask = batch.get("mask")
    return softmax_xent(logits[:, :-1], batch["tokens"][:, 1:],
                        mask[:, 1:] if mask is not None else None)


def prefill(params, frames, tokens, cfg):
    """Encode frames, run decoder prefill. Returns (last-token logits,
    cache with per-layer self KV + cross KV)."""
    enc_out = encode(params, frames, cfg)
    return decode_forward(params, tokens, cfg, enc_out, mode="prefill")


def decode_step(params, cache, token, pos, cfg):
    logits, cache = decode_forward(params, token[:, None], cfg, None,
                                   mode="decode", cache=cache, pos=pos)
    return logits[:, 0], cache


def init_cache(cfg, batch: int, seq: int, enc_seq: int, dtype=DEFAULT_DTYPE):
    """Decoder cache: self-attn KV (B, seq) + cross KV (B, enc_seq),
    stacked over decoder layers."""
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    layer = {
        "self": (jnp.zeros((batch, seq, hkv, hd), dtype),
                 jnp.zeros((batch, seq, hkv, hd), dtype)),
        "cross": (jnp.zeros((batch, enc_seq, hkv, hd), dtype),
                  jnp.zeros((batch, enc_seq, hkv, hd), dtype)),
    }
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape),
        layer)
