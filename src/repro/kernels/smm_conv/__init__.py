from repro.kernels.smm_conv.ops import (smm_conv, smm_conv_batched,
                                        pack_smm_operands)
from repro.kernels.smm_conv.ref import smm_conv_ref

__all__ = ["smm_conv", "smm_conv_batched", "pack_smm_operands",
           "smm_conv_ref"]
