"""Checkpoint manager: atomic commit, roundtrip, GC, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, restore_latest


def _tree(key):
    return {"a": jax.random.normal(key, (8, 4)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_save_restore_roundtrip(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(key)
    mgr.save(7, tree, extra={"data_cursor": 8}, async_=False)
    restored, extra = mgr.restore(7, tree)
    assert extra == {"data_cursor": 8}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(key)
    for s in (1, 5, 9):
        mgr.save(s, tree, async_=True)
    mgr.wait()
    assert mgr.steps() == [1, 5, 9]
    restored, extra, step = restore_latest(mgr, tree)
    assert step == 9 and restored is not None


def test_gc_keeps_last_k(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(key)
    for s in range(5):
        mgr.save(s, tree, async_=False)
    assert mgr.steps() == [3, 4]


def test_no_tmp_dirs_after_commit(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _tree(key), async_=False)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_elastic_restore_respects_new_sharding(tmp_path, key):
    """Restore onto explicit (different) shardings — elastic re-mesh."""
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(key)
    mgr.save(1, tree, async_=False)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), tree)
    restored, _ = mgr.restore(1, tree, shardings=sh)
    for leaf in jax.tree.leaves(restored):
        assert isinstance(leaf.sharding, jax.sharding.NamedSharding)


def test_restore_empty_dir(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path))
    tree, extra, step = restore_latest(mgr, _tree(key))
    assert tree is None and step == -1
