"""xlstm-350m [ssm] — alternating mLSTM/sLSTM blocks, d_ff=0 (the
blocks carry their own up/down projections). [arXiv:2405.04517;
unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
    sub_quadratic=True,
)
