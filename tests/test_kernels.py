"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp
oracles, swept over shapes, dtypes, and unique-count budgets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ucr
from repro.core.codr_linear import pack_unique, unpack_unique
from repro.core.serving import restrict_unique
from repro.kernels.codr_matmul import codr_matmul
from repro.kernels.codr_matmul.ref import codr_matmul_ref
from repro.kernels.smm_conv import smm_conv, smm_conv_ref


def _packed(rng, k, n, n_unique, dtype=jnp.float32):
    w = rng.normal(size=(k, n)).astype(np.float32)
    q, s = ucr.quantize_int8(w)
    q = restrict_unique(q, n_unique)
    return pack_unique(q, s, dtype=dtype)


# ---------------------------------------------------------------------------
# codr_matmul (performance kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mkn", [(64, 64, 64), (128, 256, 128),
                                 (32, 384, 512), (256, 128, 256)])
@pytest.mark.parametrize("n_unique", [4, 16])
def test_codr_matmul_shapes(mkn, n_unique, rng):
    m, k, n = mkn
    pw = _packed(rng, k, n, n_unique)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    y = codr_matmul(x, pw, bm=64, bn=64, bk=64, interpret=True)
    yr = codr_matmul_ref(x, pw.packed, pw.table, pw.scale.reshape(-1),
                         bits=pw.bits, n=n)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_codr_matmul_dtypes(dtype, rng):
    pw = _packed(rng, 128, 128, 16, dtype=dtype)
    x = jnp.asarray(rng.normal(size=(64, 128)), dtype=dtype)
    y = codr_matmul(x, pw, interpret=True)
    yr = codr_matmul_ref(x, pw.packed, pw.table, pw.scale.reshape(-1),
                         bits=pw.bits, n=128)
    assert y.dtype == dtype
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("blocks", [(32, 32, 32), (64, 128, 32),
                                    (128, 64, 128)])
def test_codr_matmul_block_sweep(blocks, rng):
    bm, bn, bk = blocks
    pw = _packed(rng, 128, 256, 16)
    x = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    y = codr_matmul(x, pw, bm=bm, bn=bn, bk=bk, interpret=True)
    yr = codr_matmul_ref(x, pw.packed, pw.table, pw.scale.reshape(-1),
                         bits=pw.bits, n=256)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-3, atol=2e-4)


def test_pack_unpack_roundtrip(rng):
    for n_unique in (2, 4, 16, 256):
        w = rng.normal(size=(32, 64)).astype(np.float32)
        q, s = ucr.quantize_int8(w)
        q = restrict_unique(q, n_unique)
        pw = pack_unique(q, s, dtype=jnp.float32)
        dense = unpack_unique(pw.packed, pw.table, bits=pw.bits, n=64)
        np.testing.assert_allclose(np.asarray(dense), q.astype(np.float32))


def test_compression_ratio_scales_with_unique_budget(rng):
    w = rng.normal(size=(256, 256)).astype(np.float32)
    q, s = ucr.quantize_int8(w)
    r16 = pack_unique(restrict_unique(q, 16), s).compression_vs_bf16
    r4 = pack_unique(restrict_unique(q, 4), s).compression_vs_bf16
    assert r4 > r16 > 3.0          # 4-bit pack ≈ 4x vs bf16, 2-bit ≈ 8x


# ---------------------------------------------------------------------------
# smm_conv (faithful-mechanism kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 3, 3, 3, 10, 10), (8, 2, 2, 2, 8, 8),
                                   (8, 5, 1, 1, 6, 6)])
@pytest.mark.parametrize("density", [0.2, 0.8])
def test_smm_conv_kernel_exact(shape, density, rng):
    m, n, rk, ck, ri, ci = shape
    w = rng.normal(size=(m, n, rk, ck)).astype(np.float32)
    w[rng.random(w.shape) > density] = 0
    code = ucr.encode_conv_layer(w, t_m=4, t_n=2)
    x = rng.integers(-8, 8, size=(n, ri, ci)).astype(np.int8)
    got = smm_conv(jnp.asarray(x), code, interpret=True)
    ref = smm_conv_ref(x, code)
    assert float(jnp.abs(got - ref).max()) == 0.0


@pytest.mark.parametrize("stride", [1, 2, 3])
@pytest.mark.parametrize("shape", [(6, 2, 3, 3, 11, 11), (4, 3, 2, 2, 12, 12)])
def test_smm_conv_kernel_stride_parity(shape, stride, rng):
    """Strided crossbar routing in the Pallas kernel == strided dense
    conv oracle, bit-exact."""
    m, n, rk, ck, ri, ci = shape
    w = rng.normal(size=(m, n, rk, ck)).astype(np.float32)
    w[rng.random(w.shape) > 0.5] = 0
    code = ucr.encode_conv_layer(w, t_m=2, t_n=2)
    x = rng.integers(-8, 8, size=(n, ri, ci)).astype(np.int8)
    got = smm_conv(jnp.asarray(x), code, stride=stride, interpret=True)
    ref = smm_conv_ref(x, code, stride=stride)
    assert got.shape == ref.shape
    assert float(jnp.abs(got - ref).max()) == 0.0


def test_smm_conv_batched_one_dispatch(rng):
    """The batched entry point covers the whole batch with one kernel
    call (batch grid dim) and matches the per-sample results."""
    from repro.kernels.smm_conv import smm_conv_batched
    w = rng.normal(size=(4, 2, 3, 3)).astype(np.float32)
    w[rng.random(w.shape) > 0.5] = 0
    code = ucr.encode_conv_layer(w, t_m=2, t_n=2)
    x = rng.integers(-8, 8, size=(3, 2, 9, 9)).astype(np.int8)
    got = smm_conv_batched(jnp.asarray(x, jnp.float32), code, interpret=True)
    for b in range(3):
        ref = smm_conv_ref(x[b], code)
        assert float(jnp.abs(got[b] - ref).max()) == 0.0


def test_smm_conv_all_zero_layer(rng):
    w = np.zeros((4, 2, 3, 3), dtype=np.float32)
    code = ucr.encode_conv_layer(w, t_m=4, t_n=2)
    x = rng.integers(-8, 8, size=(2, 8, 8)).astype(np.int8)
    got = smm_conv(jnp.asarray(x), code, interpret=True)
    assert float(jnp.abs(got).max()) == 0.0


# ---------------------------------------------------------------------------
# flash_attention (fused production kernel — EXPERIMENTS §Perf Pair 2 fix)
# ---------------------------------------------------------------------------

from repro.kernels.flash_attention import (flash_attention_kernel,
                                           flash_attention_ref)


@pytest.mark.parametrize("shape", [(2, 128, 4, 2, 32), (1, 256, 8, 8, 16),
                                   (2, 96, 4, 1, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_kernel(shape, causal, key=None):
    import jax
    key = jax.random.PRNGKey(0)
    b, s, hq, hkv, d = shape
    q = jax.random.normal(key, (b, s, hq, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d),
                          jnp.float32)
    got = flash_attention_kernel(q, k, v, causal=causal, bq=64, bk=64,
                                 interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_flash_attention_kernel_block_sweep(rng):
    import jax
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 128, 2, 2, ), jnp.float32)  # placeholder
    b, s, h, d = 1, 128, 2, 32
    q = jax.random.normal(key, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))
    ref = flash_attention_ref(q, k, v, causal=True)
    for bq, bk in ((32, 32), (128, 64), (64, 128)):
        got = flash_attention_kernel(q, k, v, causal=True, bq=bq, bk=bk,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)
