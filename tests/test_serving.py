"""CoDR-as-a-serving-feature: compression of real model params,
quantized-serving consistency, HLO collective analysis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.serving import (FlushDispatchError, codr_compress_params,
                                codr_report, codr_serving_stats,
                                compress_tensor, restrict_unique)
from repro.models import get_model


def test_restrict_unique_levels(rng):
    q = rng.integers(-127, 128, size=(64, 64)).astype(np.int8)
    for u in (4, 16, 64):
        q2 = restrict_unique(q, u)
        assert len(np.unique(q2[q2 != 0])) <= u
        # zeros preserved exactly (sparsity survives re-quantization)
        assert (q2[q == 0] == 0).all()


def test_compress_tensor_beats_baselines(rng):
    w = rng.normal(size=(512, 256)).astype(np.float32) * 0.02
    _, rep = compress_tensor(w, n_unique=16)
    assert rep["codr_bits"] < rep["ucnn_bits"]
    assert rep["codr_bits"] < rep["scnn_bits"]
    assert rep["codr_bits"] / w.size < 8.0      # better than raw int8


def test_codr_compress_params_end_to_end(key):
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    api = get_model(cfg)
    params = api.init_params(key, cfg)
    cparams, reports = codr_compress_params(params, n_unique=16)
    assert reports, "no tensors compressed"
    txt = codr_report(reports)
    assert "bits/weight" in txt
    # compressed model still serves finite logits
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    logits, _ = api.prefill(cparams, {"tokens": tokens}, cfg)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # and bits/weight beats int8 (tiny smoke tensors — production-size
    # tensors compress much further, see test_compress_tensor above)
    tot_w = sum(r.n_weights for r in reports)
    tot_bits = sum(r.codr_bits for r in reports)
    assert tot_bits / tot_w < 8.0


def test_sampled_accounting_matches_full(rng):
    """``sample_rows`` samples the leading ROWS of the reshaped
    ``(rows, d_out)`` matrix and scales the bit counts — on a
    homogeneous seeded tensor the sampled estimate must agree with the
    full encode within 10%."""
    w = (rng.normal(size=(8192, 64)) * 0.02).astype(np.float32)
    params = {"w_proj": w}
    _, full = codr_compress_params(params, n_unique=16, sample_rows=None)
    _, sampled = codr_compress_params(params, n_unique=16,
                                      sample_rows=1024)
    for field in ("codr_bits", "ucnn_bits", "scnn_bits", "pack_bits"):
        f, s = getattr(full[0], field), getattr(sampled[0], field)
        assert abs(f - s) / f < 0.10, (field, f, s)


def test_sample_cols_deprecated_alias(rng):
    w = (rng.normal(size=(4096, 32)) * 0.02).astype(np.float32)
    with pytest.warns(DeprecationWarning, match="sample_rows"):
        _, via_alias = codr_compress_params({"w_proj": w}, n_unique=16,
                                            sample_cols=512)
    _, direct = codr_compress_params({"w_proj": w}, n_unique=16,
                                     sample_rows=512)
    assert via_alias[0].codr_bits == direct[0].codr_bits


def test_pack_bits_surfaced_in_report(rng):
    """compress_tensor's fixed-width kernel pack size must survive into
    TensorReport and the printed report — it is the serving path's
    weight-HBM number."""
    w = (rng.normal(size=(256, 64)) * 0.02).astype(np.float32)
    _, reports = codr_compress_params({"q_proj": w}, n_unique=16)
    assert reports[0].pack_bits > 0
    # U=16 → 4-bit indices over every weight
    assert reports[0].pack_bits_per_weight == pytest.approx(4.0, abs=0.5)
    assert "pack" in codr_report(reports)


def test_batch_server_ids_monotonic_across_flushes_and_failures(rng):
    """Request ids come from a dedicated monotonic counter: interleaved
    submit/flush cycles issue consecutive ids, and a flush that dies
    mid-way must never lead to an already-issued id being reissued (the
    old ``requests_served + queue position`` scheme collided here,
    because ``requests_served`` advances in chunk order during flush)."""
    from repro.core.dataflow import ConvShape
    from repro.core.engine import build_random_model
    from repro.core.serving import CodrBatchServer

    model = build_random_model([ConvShape(4, 2, 3, 3, 8, 8, 1)], n_out=3,
                               density=0.8, rng=rng)
    server = CodrBatchServer(model, max_batch=2)
    issued = []
    good = rng.normal(size=(8, 8, 2)).astype(np.float32)
    issued += [server.submit(good) for _ in range(3)]
    server.flush()
    issued += [server.submit(good) for _ in range(2)]
    server.flush()
    # a flush that fails mid-way: first chunk (2 good) serves, then a
    # malformed sample kills the dispatch of its own chunk
    issued += [server.submit(good) for _ in range(2)]
    bad = rng.normal(size=(3, 3, 2)).astype(np.float32)   # kernel > input
    issued.append(server.submit(bad))
    with pytest.raises(Exception):
        server.flush()
    issued += [server.submit(good) for _ in range(2)]
    server.flush()
    assert issued == list(range(len(issued)))   # monotonic, no collisions


def _conv_server(rng, max_batch=2):
    """Conv-only compiled model (any input spatial size works — needed
    for multi-shape-bucket flush tests, like the async suite uses)."""
    import repro.api as codr_api

    w = rng.normal(size=(6, 3, 3, 3)).astype(np.float32) * 0.5
    w[rng.random(w.shape) > 0.5] = 0
    spec = codr_api.ModelSpec([codr_api.LayerSpec.conv(
        w, rng.normal(size=6).astype(np.float32), activation="relu",
        name="c0")])
    return codr_api.compile(spec, codr_api.EncodeConfig(n_unique=16)).serve(
        max_batch=max_batch)


def test_flush_failure_keeps_undispatched_tail(rng):
    """The PR-6 headline bug: a chunk that raises mid-flush must not
    drop the requests of chunks that never dispatched — they stay
    queued, the next flush serves them without resubmission, and the
    raised error carries the partial results of the chunks that DID
    run."""
    server = _conv_server(rng, max_batch=2)
    good = rng.normal(size=(9, 9, 3)).astype(np.float32)
    bad = rng.normal(size=(9, 9, 4)).astype(np.float32)   # 4 chans ≠ 3
    tail = rng.normal(size=(11, 11, 3)).astype(np.float32)  # valid shape
    # chunk order = shape-group insertion order: [good,good] runs, [bad]
    # raises, [tail, tail] never dispatches
    for x in (good, good, bad, tail, tail):
        server.submit(x)
    with pytest.raises(FlushDispatchError) as ei:
        server.flush()
    err = ei.value
    assert err.requeued == 2                    # the two tail requests
    assert err.failed == [2]                    # queue position of `bad`
    # partial results: the first chunk's outputs survived on the error
    assert err.partial[0] is not None and err.partial[1] is not None
    assert err.partial[2] is None and err.partial[4] is None
    # recovery without resubmission: the tail is still queued
    outs = server.flush()
    assert len(outs) == 2
    assert all(o is not None and o.shape == (9, 9, 6) for o in outs)
    # the poison request was consumed, not requeued — flush is clean now
    assert server.flush() == []


def test_flush_failure_does_not_requeue_poison(rng):
    """The failed chunk itself is consumed: subsequent flushes do not
    re-raise on a long-gone poison request."""
    server = _conv_server(rng, max_batch=2)
    bad = rng.normal(size=(9, 9, 4)).astype(np.float32)
    server.submit(bad)
    with pytest.raises(FlushDispatchError):
        server.flush()
    assert server.flush() == []                 # poison gone
    good = rng.normal(size=(9, 9, 3)).astype(np.float32)
    server.submit(good)
    assert len(server.flush()) == 1


def test_threaded_submit_ids_unique_and_all_served(rng):
    """Sync-path locking: concurrent submitters must neither collide on
    a request id nor corrupt the queue (pre-fix, submit mutated _queue
    and _next_id with no lock)."""
    import threading

    server = _conv_server(rng, max_batch=4)
    good = rng.normal(size=(9, 9, 3)).astype(np.float32)
    ids: list[int] = []
    lock = threading.Lock()

    def worker():
        for _ in range(25):
            rid = server.submit(good)
            with lock:
                ids.append(rid)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sorted(ids) == list(range(100))      # unique, gapless
    outs = server.flush()
    assert len(outs) == 100 and all(o is not None for o in outs)


def test_serving_stats_ordering():
    cfg = get_config("qwen2.5-3b")
    stats = codr_serving_stats(cfg, n_unique=16)
    assert stats["codr_gb"] < stats["int8_gb"] < stats["bf16_gb"]
    assert stats["source"] == "synthetic-estimate"


def test_serving_stats_measured_from_reports(rng):
    """With real TensorReports the stats are computed from the model's
    own tensors (and labeled measured), not the synthetic 512x512
    extrapolation."""
    cfg = get_config("qwen2.5-3b")
    w = (rng.normal(size=(512, 256)) * 0.02).astype(np.float32)
    _, reports = codr_compress_params({"q_proj": w}, n_unique=16)
    stats = codr_serving_stats(cfg, reports=reports)
    assert stats["source"] == "measured"
    tot_w = sum(r.n_weights for r in reports)
    want = sum(r.codr_bits for r in reports) / tot_w
    assert stats["codr_bits_per_weight"] == pytest.approx(want)
    assert stats["pack_bits_per_weight"] == pytest.approx(
        sum(r.pack_bits for r in reports) / tot_w)
    # empty reports fall back to the labeled estimate
    assert codr_serving_stats(cfg, reports=[])["source"] == \
        "synthetic-estimate"


def test_hlo_collective_parser_loop_multiplication():
    from repro.launch.hlo_analysis import collective_bytes_from_hlo
    hlo = """\
HloModule test

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %ar = f32[8]{0} all-reduce(%gte), to_apply=%add
  ROOT %t = (s32[], f32[8]) tuple(%c, %ar)
}

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %c5 = s32[] constant(5)
  ROOT %lt = pred[] compare(%gte, %c5), direction=LT
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %ag = f32[16]{0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""
    res = collective_bytes_from_hlo(hlo)
    assert res["by_op_bytes"]["all-gather"] == 16 * 4
    assert res["by_op_bytes"]["all-reduce"] == 5 * 8 * 4   # ×trip count
    assert res["by_op_count"]["all-reduce"] == 5
