"""codrlint core: finding model, suppressions, baseline, runner.

The checkers themselves live in :mod:`tools.codrlint.checks`; this
module is the harness they plug into:

* :class:`Finding` — one violation: check name, file, line, a stable
  ``key`` (symbol-level, line-number free — what the baseline matches
  on), and the human message.
* :class:`ModuleInfo` — one parsed file: path, source, AST, and the
  per-line suppression table (``# codrlint: disable=<check> — rationale``).
* :class:`Project` — every module of one run plus cross-file indices
  (class map for inheritance, registered-pytree set, ...).  Checkers
  that need whole-program context implement :meth:`Checker.finalize`.
* :class:`Checker` — the plugin protocol; concrete checkers register
  via :func:`register_checker` (import-time, like the backend registry
  in ``repro.core.backends``).
* :func:`run` — parse paths, run every checker, apply suppressions and
  the committed baseline, return a :class:`Report`.

Suppression convention (docs/DESIGN.md §7): a finding is silenced by an
inline comment on the finding's line or the line above::

    x = np.asarray(y)   # codrlint: disable=jit-purity — trace-time only

The rationale (text after the dash/colon) is MANDATORY: a bare
``disable=`` without one is itself reported as a ``bad-suppression``
finding, so silencing a checker always leaves a reviewable why.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re

SUPPRESS_RE = re.compile(
    r"#\s*codrlint:\s*disable=([A-Za-z0-9_,-]+)"
    r"(?:\s*(?:[-—–:]+)\s*(.*))?\s*$")

DEFAULT_PATHS = ("src", "tools")
BASELINE_DEFAULT = pathlib.Path(__file__).parent / "baseline.json"

# files codrlint never lints: its own fixture corpus is deliberately
# full of violations
EXCLUDE_PARTS = {"lint_fixtures", "__pycache__"}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation.  ``key`` is the stable symbol-level identity used
    for baseline matching — it must not contain a line number, so a
    grandfathered finding survives unrelated edits above it."""

    check: str
    path: str                  # repo-relative, forward slashes
    line: int
    key: str                   # e.g. "CodrBatchServer.flush:_queue"
    message: str

    @property
    def fingerprint(self) -> str:
        return f"{self.check}:{self.path}:{self.key}"

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.check}] {self.message}"

    def to_json(self) -> dict:
        return {"check": self.check, "path": self.path, "line": self.line,
                "key": self.key, "message": self.message,
                "fingerprint": self.fingerprint}


@dataclasses.dataclass
class Suppression:
    line: int
    checks: tuple[str, ...]
    rationale: str
    used: bool = False


class ModuleInfo:
    """One parsed source file."""

    def __init__(self, path: pathlib.Path, root: pathlib.Path):
        self.path = path
        self.rel = path.relative_to(root).as_posix()
        self.source = path.read_text(encoding="utf-8", errors="replace")
        self.lines = self.source.splitlines()
        self.tree: ast.Module | None = None
        self.parse_error: str | None = None
        try:
            self.tree = ast.parse(self.source, filename=str(path))
        except SyntaxError as e:
            self.parse_error = f"{type(e).__name__}: {e.msg} (line {e.lineno})"
        self.suppressions: dict[int, Suppression] = {}
        for i, text in enumerate(self.lines, start=1):
            m = SUPPRESS_RE.search(text)
            if m:
                checks = tuple(c.strip() for c in m.group(1).split(",")
                               if c.strip())
                self.suppressions[i] = Suppression(
                    i, checks, (m.group(2) or "").strip())

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def suppression_for(self, check: str, lineno: int) -> Suppression | None:
        """A suppression applies to findings on its own line or the
        line directly below (comment-above style)."""
        for ln in (lineno, lineno - 1):
            s = self.suppressions.get(ln)
            if s and (check in s.checks or "all" in s.checks):
                return s
        return None


class Project:
    """All modules of one run + lazily-built cross-file indices."""

    def __init__(self, modules: list[ModuleInfo]):
        self.modules = modules
        self._class_index: dict[str, list[tuple[ModuleInfo,
                                                ast.ClassDef]]] | None = None

    @property
    def class_index(self) -> dict[str, list[tuple[ModuleInfo, ast.ClassDef]]]:
        """Top-level class name → every (module, ClassDef) defining it.
        Name-based (no import resolution) — good enough for a repo that
        does not reuse class names across packages, and documented as
        such in docs/DESIGN.md §7."""
        if self._class_index is None:
            idx: dict[str, list[tuple[ModuleInfo, ast.ClassDef]]] = {}
            for mod in self.modules:
                if mod.tree is None:
                    continue
                for node in ast.walk(mod.tree):
                    if isinstance(node, ast.ClassDef):
                        idx.setdefault(node.name, []).append((mod, node))
            self._class_index = idx
        return self._class_index

    def module_by_rel(self, rel: str) -> ModuleInfo | None:
        for m in self.modules:
            if m.rel == rel:
                return m
        return None


class Checker:
    """Plugin protocol.  ``check_module`` runs per file;
    ``finalize`` runs once afterwards with whole-project context."""

    name: str = ""
    description: str = ""

    def check_module(self, mod: ModuleInfo, project: Project):
        return ()

    def finalize(self, project: Project):
        return ()


_CHECKERS: dict[str, Checker] = {}


def register_checker(checker: Checker) -> Checker:
    if not checker.name:
        raise ValueError("checker must set a non-empty .name")
    if checker.name in _CHECKERS:
        raise ValueError(f"checker {checker.name!r} already registered")
    _CHECKERS[checker.name] = checker
    return checker


def registered_checkers() -> dict[str, Checker]:
    # import-time registration, like repro.core.backends
    from tools.codrlint import checks  # noqa: F401
    return dict(_CHECKERS)


# ---------------------------------------------------------------------------
# AST helpers shared by checkers
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> str:
    """``jax.tree_util.register_pytree_node`` → that string; '' when the
    expression is not a plain dotted name."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def literal_or_none(node: ast.AST):
    try:
        return ast.literal_eval(node)
    except (ValueError, TypeError, SyntaxError):
        return None


def top_level_bindings(tree: ast.Module) -> set[str]:
    """Names bound at module top level: defs, classes, imports, assigns."""
    names: set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.Import):
            for a in node.names:
                names.add((a.asname or a.name).split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for a in node.names:
                if a.name == "*":
                    continue
                names.add(a.asname or a.name)
        elif isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                for leaf in ast.walk(t):
                    if isinstance(leaf, ast.Name):
                        names.add(leaf.id)
        elif isinstance(node, (ast.If, ast.Try)):
            # common guarded-import patterns bind inside these blocks
            for sub in ast.walk(node):
                if isinstance(sub, (ast.Import, ast.ImportFrom)):
                    for a in sub.names:
                        if a.name != "*":
                            names.add((a.asname or a.name).split(".")[0])
                elif isinstance(sub, (ast.FunctionDef, ast.ClassDef)):
                    names.add(sub.name)
                elif isinstance(sub, ast.Assign):
                    for t in sub.targets:
                        if isinstance(t, ast.Name):
                            names.add(t.id)
    return names


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Report:
    findings: list[Finding]            # new findings (fail the run)
    suppressed: int
    baselined: int
    stale_baseline: list[str]          # fingerprints no longer observed
    bad_suppressions: list[Finding]    # disable= without a rationale
    checked_files: int

    @property
    def ok(self) -> bool:
        return not self.findings and not self.bad_suppressions

    def to_json(self) -> dict:
        return {
            "ok": self.ok,
            "checked_files": self.checked_files,
            "suppressed": self.suppressed,
            "baselined": self.baselined,
            "stale_baseline": self.stale_baseline,
            "findings": [f.to_json() for f in self.findings],
            "bad_suppressions": [f.to_json()
                                 for f in self.bad_suppressions],
        }


def iter_py_files(paths, root: pathlib.Path):
    for p in paths:
        p = (root / p) if not pathlib.Path(p).is_absolute() \
            else pathlib.Path(p)
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not EXCLUDE_PARTS.intersection(f.parts):
                    yield f


def load_baseline(path: pathlib.Path | None) -> set[str]:
    path = path or BASELINE_DEFAULT
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    if isinstance(data, dict):
        data = data.get("fingerprints", [])
    return set(data)


def run(paths=DEFAULT_PATHS, *, root: pathlib.Path | None = None,
        baseline: pathlib.Path | None | bool = None,
        only: tuple[str, ...] | None = None) -> Report:
    """Lint ``paths`` (files or directories, relative to ``root``).

    ``baseline=False`` disables baseline matching entirely (fixture
    tests use this); ``None`` uses the committed ``baseline.json``.
    ``only`` restricts to a subset of checker names.
    """
    root = root or pathlib.Path(__file__).resolve().parent.parent.parent
    checkers = registered_checkers()
    if only:
        unknown = set(only) - set(checkers)
        if unknown:
            raise ValueError(f"unknown checker(s): {sorted(unknown)}; "
                             f"available: {sorted(checkers)}")
        checkers = {k: v for k, v in checkers.items() if k in only}

    modules = [ModuleInfo(f, root) for f in iter_py_files(paths, root)]
    project = Project(modules)

    raw: list[Finding] = []
    for mod in modules:
        if mod.parse_error:
            raw.append(Finding("parse", mod.rel, 1, "parse-error",
                               f"file does not parse: {mod.parse_error}"))
            continue
        for checker in checkers.values():
            raw.extend(checker.check_module(mod, project))
    for checker in checkers.values():
        raw.extend(checker.finalize(project))

    # suppressions (rationale mandatory)
    mod_by_rel = {m.rel: m for m in modules}
    kept: list[Finding] = []
    bad_supp: list[Finding] = []
    suppressed = 0
    for f in raw:
        mod = mod_by_rel.get(f.path)
        supp = mod.suppression_for(f.check, f.line) if mod else None
        if supp is None:
            kept.append(f)
        elif not supp.rationale:
            supp.used = True
            bad_supp.append(Finding(
                "bad-suppression", f.path, supp.line,
                f"{f.check}:{f.key}",
                f"suppression of [{f.check}] has no rationale — write "
                f"'# codrlint: disable={f.check} — <why>'"))
        else:
            supp.used = True
            suppressed += 1

    if baseline is False:
        base: set[str] = set()
    else:
        base = load_baseline(baseline if isinstance(baseline, pathlib.Path)
                             else None)
    new = [f for f in kept if f.fingerprint not in base]
    baselined = len(kept) - len(new)
    stale = sorted(base - {f.fingerprint for f in kept})
    new.sort(key=lambda f: (f.path, f.line, f.check))
    return Report(findings=new, suppressed=suppressed, baselined=baselined,
                  stale_baseline=stale, bad_suppressions=bad_supp,
                  checked_files=len(modules))
