"""Optimizer substrate."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule)


def _quad_setup(use_master):
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, use_master=use_master)
    params = {"w": jnp.ones((4,), jnp.bfloat16 if use_master else jnp.float32)}
    state = adamw_init(params, cfg)
    return cfg, params, state


def test_adamw_minimizes_quadratic():
    cfg, params, state = _quad_setup(use_master=False)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - 3.0))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_master_weights_beat_bf16_resolution():
    """With fp32 master, bf16 params keep improving even when single
    updates are below bf16 resolution."""
    cfg, params, state = _quad_setup(use_master=True)
    loss = lambda p: jnp.sum(jnp.square(p["w"].astype(jnp.float32) - 3.0))
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert params["w"].dtype == jnp.bfloat16
    assert float(loss(params)) < 1e-2
    assert state["master"]["w"].dtype == jnp.float32


def test_grad_clip_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((9,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = sum(float(jnp.sum(jnp.square(x)))
                for x in jax.tree.leaves(clipped))
    assert abs(total - 1.0) < 1e-5
    assert float(gn) > 1.0


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.int32(s), peak_lr=1.0, warmup_steps=10,
                                 total_steps=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6         # warmup rises
    assert np.argmax(lrs) <= 11                  # peak right after warmup
    assert lrs[-1] < 0.2                          # decays toward final_frac
