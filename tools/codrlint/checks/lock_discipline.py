"""lock-discipline: a static race detector for the serving stack.

Convention (docs/DESIGN.md §7): a shared attribute is declared guarded
by annotating its initialization with a trailing comment::

    self._queue = []        # guarded-by: _cv

From then on, every ``self._queue`` access *anywhere in the class or
its subclasses* must happen either

* lexically inside a ``with self._cv:`` block (the named lock attribute
  used as a context manager), or
* in a method whose name ends in ``_locked`` (the repo's convention for
  "caller already holds the lock"), or
* in ``__init__`` (no concurrency before construction completes).

Inheritance is resolved project-wide by class name, so
``ContinuousBatcher`` (``batching.py``) inherits the guarded set of
``AsyncWorkerLoop`` (``serving.py``).  The checker is lexical: it does
not prove the *right* lock instance is held across helper calls, and it
does not track accesses through aliases (``q = self._queue`` then
mutating ``q``) — it is a convention enforcer in the guarded-by
annotation style of Java's ``@GuardedBy`` / Abseil's thread
annotations, not a full happens-before analysis.
"""
from __future__ import annotations

import ast
import re

from tools.codrlint.core import (Checker, Finding, ModuleInfo, Project,
                                 register_checker)

GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)")

EXEMPT_METHOD_SUFFIX = "_locked"
EXEMPT_METHODS = {"__init__"}


def _self_attr(node: ast.AST) -> str | None:
    """``self.X`` → ``"X"``; anything else → None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _guarded_decls(mod: ModuleInfo, cls: ast.ClassDef) -> dict[str, str]:
    """attr → lock name, from ``# guarded-by: <lock>`` trailing comments
    on ``self.X = ...`` statements anywhere in the class body."""
    out: dict[str, str] = {}
    for node in ast.walk(cls):
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            attrs = [a for a in map(_self_attr, targets) if a]
            if not attrs:
                continue
            m = GUARDED_RE.search(mod.line_text(node.lineno))
            if m:
                for a in attrs:
                    out[a] = m.group(1)
    return out


class _MethodScanner(ast.NodeVisitor):
    """Walk one method; track which guard locks are lexically held."""

    def __init__(self, mod: ModuleInfo, cls_name: str, meth: str,
                 guarded: dict[str, str]):
        self.mod = mod
        self.cls_name = cls_name
        self.meth = meth
        self.guarded = guarded
        self.held: set[str] = set()
        self.findings: list[Finding] = []

    def visit_With(self, node: ast.With) -> None:
        acquired = set()
        for item in node.items:
            expr = item.context_expr
            # `with self._cv:` / `with self._cv.acquire_timeout(...)` —
            # any context expression rooted at self.<lock> counts
            attr = _self_attr(expr)
            if attr is None and isinstance(expr, ast.Call):
                attr = _self_attr(expr.func)
                if attr is None and isinstance(expr.func, ast.Attribute):
                    attr = _self_attr(expr.func.value)
            if attr in set(self.guarded.values()):
                acquired.add(attr)
        newly = acquired - self.held
        self.held |= newly
        try:
            self.generic_visit(node)
        finally:
            self.held -= newly

    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = _self_attr(node)
        if attr is not None and attr in self.guarded:
            lock = self.guarded[attr]
            if lock not in self.held:
                self.findings.append(Finding(
                    "lock-discipline", self.mod.rel, node.lineno,
                    f"{self.cls_name}.{self.meth}:{attr}",
                    f"self.{attr} (guarded-by: {lock}) accessed in "
                    f"{self.cls_name}.{self.meth} without holding "
                    f"self.{lock} — wrap in 'with self.{lock}:' or move "
                    f"to a *{EXEMPT_METHOD_SUFFIX} method"))
        self.generic_visit(node)

    # nested defs inside a method run on unknown threads later; the
    # lexical lock context does NOT carry into them unless they are
    # called in place — be conservative and keep the current held set
    # (closures in this repo are dispatch thunks invoked under the same
    # caller; a wrong 'held' would only arise from storing the closure,
    # which the serving stack never does with guarded state).


class LockDisciplineChecker(Checker):
    name = "lock-discipline"
    description = ("attributes annotated '# guarded-by: <lock>' are only "
                   "touched under 'with self.<lock>:' or in *_locked "
                   "methods")

    def finalize(self, project: Project):
        findings: list[Finding] = []
        # pass 1: declarations per class
        decls: dict[str, dict[str, str]] = {}
        bases: dict[str, list[str]] = {}
        for cls_name, defs in project.class_index.items():
            merged: dict[str, str] = {}
            base_names: list[str] = []
            for mod, cls in defs:
                merged.update(_guarded_decls(mod, cls))
                for b in cls.bases:
                    if isinstance(b, ast.Name):
                        base_names.append(b.id)
                    elif isinstance(b, ast.Attribute):
                        base_names.append(b.attr)
            if merged:
                decls[cls_name] = merged
            bases[cls_name] = base_names

        def effective(cls_name: str, seen=None) -> dict[str, str]:
            seen = seen or set()
            if cls_name in seen:
                return {}
            seen.add(cls_name)
            out: dict[str, str] = {}
            for b in bases.get(cls_name, ()):
                out.update(effective(b, seen))
            out.update(decls.get(cls_name, {}))
            return out

        # pass 2: enforce in every class that sees a guarded attr
        for cls_name, defs in project.class_index.items():
            guarded = effective(cls_name)
            if not guarded:
                continue
            for mod, cls in defs:
                for item in cls.body:
                    if not isinstance(item, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)):
                        continue
                    if (item.name in EXEMPT_METHODS
                            or item.name.endswith(EXEMPT_METHOD_SUFFIX)):
                        continue
                    sc = _MethodScanner(mod, cls_name, item.name, guarded)
                    for stmt in item.body:
                        sc.visit(stmt)
                    findings.extend(sc.findings)
        return findings


register_checker(LockDisciplineChecker())
