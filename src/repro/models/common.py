"""Shared model building blocks (pure JAX, dict-pytree params)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codr_linear import (PackedEmbedding, PackedLinear,  # noqa: F401
                                    dense_weight)
from repro.sharding import maybe_constrain

DEFAULT_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32      # master params; cast to compute dtype at use


def dense_init(key, d_in: int, d_out: int, *, scale: float | None = None,
               dtype=PARAM_DTYPE) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


def embed_init(key, vocab: int, d: int, dtype=PARAM_DTYPE) -> jax.Array:
    return jax.random.normal(key, (vocab, d), dtype) * 0.02


def linear(x: jax.Array, w, b: jax.Array | None = None) -> jax.Array:
    """``x @ w (+ b)`` — the single matmul every model projection routes
    through.  A plain array executes as a dense ``jnp.dot``; a
    :class:`repro.core.codr_linear.PackedLinear` leaf (a params tree
    after ``repro.api.compile_params``) resolves through the backend
    registry and executes from the packed bitstream — the decode-fused
    transformer serving path (docs/DESIGN.md §2)."""
    if isinstance(w, PackedLinear):
        from repro.core import backends
        y = backends.resolve(w.backend).matmul(x, w)
    else:
        y = jnp.dot(x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def embedding_lookup(table, tokens: jax.Array,
                     dtype=DEFAULT_DTYPE) -> jax.Array:
    """``table[tokens]`` — the embedding gather every model routes
    through.  A plain ``(V, d)`` array is a ``jnp.take``; a
    :class:`repro.core.codr_linear.PackedEmbedding` leaf resolves
    through the backend registry and gathers *packed rows*, decoding
    only the tokens actually requested (docs/DESIGN.md §2.2)."""
    if isinstance(table, PackedEmbedding):
        from repro.core import backends
        return backends.resolve(table.backend).gather(tokens, table
                                                      ).astype(dtype)
    return jnp.take(table, tokens, axis=0).astype(dtype)


def unembed(x: jax.Array, table) -> jax.Array:
    """``x @ table.T`` — the logit projection against the (possibly
    packed) output embedding."""
    if isinstance(table, PackedEmbedding):
        from repro.core import backends
        return backends.resolve(table.backend).unembed(x, table)
    return jnp.dot(x, table.T.astype(x.dtype))


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6,
             f32: bool = True) -> jax.Array:
    if f32:
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
        return (y * w.astype(jnp.float32)).astype(x.dtype)
    # §Perf lever: f32 only in the (…,1) reduction accumulators — no
    # (B,S,D)-sized f32 tensor is ever materialized, forward or backward
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                   dtype=jnp.float32)
    r = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * r * w.astype(x.dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5, f32: bool = True) -> jax.Array:
    if f32:
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * w.astype(jnp.float32)
                + b.astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                   dtype=jnp.float32) - jnp.square(mu)
    r = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return ((x - mu.astype(x.dtype)) * r * w.astype(x.dtype)
            + b.astype(x.dtype))


def norm_apply(x, p, kind: str, f32: bool = True):
    if kind == "rmsnorm":
        return rms_norm(x, p["w"], f32=f32)
    return layer_norm(x, p["w"], p["b"], f32=f32)


def norm_init(d: int, kind: str):
    if kind == "rmsnorm":
        return {"w": jnp.ones((d,), PARAM_DTYPE)}
    return {"w": jnp.ones((d,), PARAM_DTYPE), "b": jnp.zeros((d,), PARAM_DTYPE)}


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: (B, S, H, D) — rotate the full head dim."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: jax.Array | None = None) -> jax.Array:
    """Token-mean cross entropy; logits (.., V) f32-stable."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def constrain_tokens(x: jax.Array) -> jax.Array:
    """(B, S, D) activations: batch over data axes."""
    return maybe_constrain(x, "batch", None, None)
