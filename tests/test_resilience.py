"""Serving-resilience invariants (``docs/DESIGN.md`` §3.5): seeded
fault plans are deterministic and site-safe; injected dispatch failures
retry to bit-identical results with no request lost or double-counted;
retry-budget exhaustion quarantines exactly the poison chunk; bounded
admission sheds with a retry-after hint; deadlines expire cleanly;
worker crashes either restart with pending work preserved or fail every
live future (never a hang); and the supervisor's mesh-degradation
ladder keeps outputs bit-for-bit identical across every rung.

The model under serve is the tiny conv-only CompiledModel from the
async-server tests — small enough that chaos runs with retries stay in
CI smoke time.
"""
import time

import numpy as np
import pytest

import repro.api as codr
from repro.core import backends
from repro.runtime import resilience as res


@pytest.fixture(scope="module")
def compiled():
    rng = np.random.default_rng(7)
    w = rng.normal(size=(6, 3, 3, 3)).astype(np.float32) * 0.5
    w[rng.random(w.shape) > 0.5] = 0
    spec = codr.ModelSpec([codr.LayerSpec.conv(
        w, rng.normal(size=6).astype(np.float32), activation="relu",
        name="c0")])
    return codr.compile(spec, codr.EncodeConfig(n_unique=16))


@pytest.fixture(scope="module")
def samples():
    rng = np.random.default_rng(3)
    return [rng.normal(size=(9, 9, 3)).astype(np.float32)
            for _ in range(6)]


@pytest.fixture(scope="module")
def clean_ref(compiled, samples):
    """Reference outputs from a run with no resilience configured."""
    srv = compiled.serve(max_batch=2, flush_deadline_s=0.005)
    with srv:
        outs = [f.result(timeout=300)
                for f in [srv.submit_async(s) for s in samples]]
    return outs


# ---------------------------------------------------------------------------
# fault plans + injector
# ---------------------------------------------------------------------------

def test_seeded_plan_deterministic_and_site_safe():
    sites = res.ALL_SITES
    p1 = res.FaultPlan.seeded(42, sites, n_faults=8)
    p2 = res.FaultPlan.seeded(42, sites, n_faults=8)
    assert [(f.site, f.at_call, f.kind) for f in p1] == \
           [(f.site, f.at_call, f.kind) for f in p2]
    p3 = res.FaultPlan.seeded(43, sites, n_faults=8)
    assert [(f.site, f.at_call, f.kind) for f in p1] != \
           [(f.site, f.at_call, f.kind) for f in p3]
    # kind policy: crashes only at worker-loop sites, device loss only
    # at the sharded dispatch — every seeded plan is executable
    for seed in range(25):
        for f in res.FaultPlan.seeded(seed, sites, n_faults=8,
                                      kinds=res.Fault.KINDS):
            if f.kind == "crash":
                assert f.site.endswith(".worker")
            if f.kind == "device_loss":
                assert f.site == res.SITE_SHARDED_DISPATCH
            if f.site.endswith(".worker"):
                assert f.kind in ("latency", "crash")


def test_plan_validation():
    with pytest.raises(ValueError, match="duplicate"):
        res.FaultPlan([res.Fault("a.dispatch", 0),
                       res.Fault("a.dispatch", 0, "latency")])
    with pytest.raises(ValueError, match="unknown fault kind"):
        res.Fault("a.dispatch", 0, "meteor")
    with pytest.raises(ValueError, match="at_call"):
        res.Fault("a.dispatch", -1)
    assert len(res.FaultPlan()) == 0
    assert "empty" in res.FaultPlan().describe()


def test_injector_fires_at_exact_call_index():
    inj = res.FaultInjector(res.FaultPlan(
        [res.Fault("x.dispatch", 2, "error")]))
    inj.fire("x.dispatch")                  # call 0
    inj.fire("x.dispatch")                  # call 1
    inj.fire("y.dispatch")                  # other site: own counter
    with pytest.raises(res.InjectedFault):
        inj.fire("x.dispatch")              # call 2 → scheduled fault
    inj.fire("x.dispatch")                  # call 3: clean again
    assert inj.calls("x.dispatch") == 4
    assert inj.calls("y.dispatch") == 1
    assert [f.at_call for f in inj.fired] == [2]
    assert inj.remaining() == 0


# ---------------------------------------------------------------------------
# retry_call semantics
# ---------------------------------------------------------------------------

def test_retry_call_transient_then_success():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise res.TransientDispatchError("blip")
        return "ok"

    pol = res.RetryPolicy(max_retries=3, backoff_s=1e-4)
    assert res.retry_call(flaky, policy=pol) == "ok"
    assert len(calls) == 3


def test_retry_call_non_transient_raises_immediately():
    calls = []

    def broken():
        calls.append(1)
        raise ValueError("shape mismatch")      # never retryable

    with pytest.raises(ValueError):
        res.retry_call(broken,
                       policy=res.RetryPolicy(max_retries=5,
                                              backoff_s=1e-4))
    assert len(calls) == 1


def test_retry_call_exhaustion_quarantines_with_cause():
    calls = []

    def poison():
        calls.append(1)
        raise res.TransientDispatchError("always")

    with pytest.raises(res.QuarantinedError) as ei:
        res.retry_call(poison,
                       policy=res.RetryPolicy(max_retries=2,
                                              backoff_s=1e-4))
    assert ei.value.attempts == 3               # initial + 2 retries
    assert isinstance(ei.value.__cause__, res.TransientDispatchError)
    assert len(calls) == 3
    # no policy and no supervisor: exactly fn()
    assert res.retry_call(lambda: 5) == 5


def test_retry_policy_backoff_grows_and_jitters_bounded():
    pol = res.RetryPolicy(backoff_s=0.01, backoff_mult=2.0, jitter=0.25)
    rng = np.random.default_rng(0)
    for attempt in range(4):
        nominal = 0.01 * 2.0 ** attempt
        d = pol.delay(attempt, rng)
        assert 0.75 * nominal <= d <= 1.25 * nominal
    assert res.RetryPolicy(jitter=0.0).delay(1) == 0.005 * 2.0


# ---------------------------------------------------------------------------
# server: retry / quarantine / shedding / deadlines
# ---------------------------------------------------------------------------

def test_async_retry_bit_identical_no_request_lost(compiled, samples,
                                                   clean_ref):
    """Transient dispatch failures + retry: every request resolves to
    exactly the clean-run bits, served exactly once (no loss, no double
    dispatch)."""
    inj = res.FaultInjector(res.FaultPlan(
        [res.Fault(res.SITE_SERVER_DISPATCH, 0, "error"),
         res.Fault(res.SITE_SERVER_DISPATCH, 3, "error"),
         res.Fault(res.SITE_SERVER_DISPATCH, 4, "latency",
                   latency_s=0.003)]))
    srv = compiled.serve(max_batch=2, flush_deadline_s=0.005)
    srv.configure_resilience(
        injector=inj,
        retry_policy=res.RetryPolicy(max_retries=2, backoff_s=1e-3))
    with srv:
        outs = [f.result(timeout=300)
                for f in [srv.submit_async(s) for s in samples]]
    for got, ref in zip(outs, clean_ref):
        np.testing.assert_array_equal(got, ref)
    assert srv.requests_served == len(samples)      # exactly once each
    assert srv.requests_quarantined == 0
    assert len(inj.fired) >= 1


def test_async_quarantine_isolates_poison_chunk(compiled, samples,
                                                clean_ref):
    """A chunk that fails through the whole retry budget is quarantined:
    its futures get the QuarantinedError, every other chunk still
    serves.  Nothing is requeued — poison cannot wedge the loop."""
    # errors at dispatch calls 0,1,2 exhaust max_retries=2 for the first
    # chunk; calls 3+ are clean for the rest
    inj = res.FaultInjector(res.FaultPlan(
        [res.Fault(res.SITE_SERVER_DISPATCH, i, "error")
         for i in range(3)]))
    srv = compiled.serve(max_batch=len(samples), flush_deadline_s=0.01)
    srv.configure_resilience(
        injector=inj,
        retry_policy=res.RetryPolicy(max_retries=2, backoff_s=1e-3))
    with srv:
        f_poison = srv.submit_async(samples[0])
        with pytest.raises(res.QuarantinedError):
            f_poison.result(timeout=300)
        # the loop survived: later requests are served normally
        f_ok = srv.submit_async(samples[1])
        np.testing.assert_array_equal(f_ok.result(timeout=300),
                                      clean_ref[1])
    assert srv.requests_quarantined == 1
    assert len(srv.quarantined) == 1
    assert srv.quarantined[0]["attempts"] == 3


def test_bounded_admission_sheds_with_retry_after(compiled, samples):
    srv = compiled.serve(max_batch=64, flush_deadline_s=0.2,
                         max_pending=2)
    with srv:
        f1 = srv.submit_async(samples[0])
        f2 = srv.submit_async(samples[1])
        with pytest.raises(res.RejectedError) as ei:
            srv.submit_async(samples[2])
        assert ei.value.retry_after_s == pytest.approx(0.2)
        f1.result(timeout=300)
        f2.result(timeout=300)
        # capacity freed: admission works again
        srv.submit_async(samples[2]).result(timeout=300)
    assert srv.requests_shed == 1
    assert srv.requests_served == 3


def test_async_deadline_expiry_cancels_cleanly(compiled, samples,
                                               clean_ref):
    srv = compiled.serve(max_batch=64, flush_deadline_s=0.05)
    with srv:
        f_dead = srv.submit_async(samples[0], deadline_s=1e-9)
        f_live = srv.submit_async(samples[1])
        with pytest.raises(res.DeadlineExceeded):
            f_dead.result(timeout=300)
        np.testing.assert_array_equal(f_live.result(timeout=300),
                                      clean_ref[1])
    assert srv.requests_expired == 1
    assert srv.requests_served == 1


def test_sync_flush_retry_and_quarantine(compiled, samples, clean_ref):
    """Sync path: transient failures retry inside flush; exhaustion
    raises FlushDispatchError chaining QuarantinedError with the tail
    requeued (PR-6 tail-restore semantics extended, not replaced)."""
    from repro.core.serving import FlushDispatchError

    # retry success case: error at dispatch call 0 only
    srv = compiled.serve(max_batch=2)
    srv.configure_resilience(
        injector=res.FaultInjector(res.FaultPlan(
            [res.Fault(res.SITE_SERVER_DISPATCH, 0, "error")])),
        retry_policy=res.RetryPolicy(max_retries=2, backoff_s=1e-3))
    outs = srv.serve(samples[:4])
    for got, ref in zip(outs, clean_ref[:4]):
        np.testing.assert_array_equal(got, ref)

    # exhaustion case: errors at calls 0,1 beat max_retries=1 → first
    # chunk quarantined, second chunk requeued; next flush (call 2
    # errors once, call 3 clean) serves the tail
    srv2 = compiled.serve(max_batch=2)
    srv2.configure_resilience(
        injector=res.FaultInjector(res.FaultPlan(
            [res.Fault(res.SITE_SERVER_DISPATCH, i, "error")
             for i in (0, 1, 2)])),
        retry_policy=res.RetryPolicy(max_retries=1, backoff_s=1e-3))
    for s in samples[:4]:
        srv2.submit(s)
    with pytest.raises(FlushDispatchError) as ei:
        srv2.flush()
    assert isinstance(ei.value.__cause__, res.QuarantinedError)
    assert ei.value.failed == [0, 1]
    assert ei.value.requeued == 2
    assert srv2.requests_quarantined == 2
    tail = srv2.flush()
    assert len(tail) == 2
    for got, ref in zip(tail, clean_ref[2:4]):
        np.testing.assert_array_equal(got, ref)


def test_sync_submit_deadline_and_shedding(compiled, samples):
    srv = compiled.serve(max_batch=4, max_pending=2)
    srv.submit(samples[0], deadline_s=1e-9)
    srv.submit(samples[1])
    with pytest.raises(res.RejectedError):
        srv.submit(samples[2])
    time.sleep(0.005)
    outs = srv.flush()
    assert outs[0] is None                      # expired, never dispatched
    assert outs[1] is not None
    assert srv.requests_expired == 1 and srv.requests_shed == 1


# ---------------------------------------------------------------------------
# worker crash: fail-live vs supervised restart
# ---------------------------------------------------------------------------

def test_worker_crash_without_restart_fails_futures_no_hang(compiled,
                                                            samples):
    """An unsupervised worker crash fails every pending future with
    WorkerCrashed — result() raises instead of hanging — and the loop
    restarts lazily on the next submit."""
    inj = res.FaultInjector(res.FaultPlan(
        [res.Fault(res.SITE_SERVER_WORKER, 0, "crash")]))
    srv = compiled.serve(max_batch=64, flush_deadline_s=0.02)
    srv.configure_resilience(injector=inj)      # no RestartPolicy
    f = srv.submit_async(samples[0])
    with pytest.raises(res.WorkerCrashed):
        f.result(timeout=60)
    assert srv.worker_crashes == 1 and srv.worker_restarts == 0
    # lazy restart: a fresh worker serves the next request (the crash
    # fault at worker call 0 is already consumed)
    f2 = srv.submit_async(samples[1])
    assert f2.result(timeout=300) is not None
    srv.stop_async()


def test_worker_crash_with_restart_preserves_pending(compiled, samples,
                                                     clean_ref):
    """With a RestartPolicy the crashed worker re-enters its loop and
    the requests that were pending at crash time are still served —
    bit-identically."""
    inj = res.FaultInjector(res.FaultPlan(
        [res.Fault(res.SITE_SERVER_WORKER, 0, "crash")]))
    srv = compiled.serve(max_batch=2, flush_deadline_s=0.01)
    srv.configure_resilience(
        injector=inj,
        restart_policy=res.RestartPolicy(max_restarts=2, backoff_s=1e-3))
    with srv:
        outs = [f.result(timeout=300)
                for f in [srv.submit_async(s) for s in samples]]
    for got, ref in zip(outs, clean_ref):
        np.testing.assert_array_equal(got, ref)
    assert srv.worker_crashes == 1
    assert srv.worker_restarts == 1
    assert srv.requests_served == len(samples)


# ---------------------------------------------------------------------------
# supervisor: degradation ladder
# ---------------------------------------------------------------------------

def test_supervisor_device_loss_degrades_bit_identical(compiled,
                                                       samples,
                                                       clean_ref):
    """An injected device loss on the sharded lane degrades to the next
    rung (smaller mesh, or tiled at the bottom) and the dispatch that
    observed the loss retries there — outputs stay bit-for-bit."""
    inj = res.FaultInjector(res.FaultPlan(
        [res.Fault(res.SITE_SHARDED_DISPATCH, 1, "device_loss")]))
    sharded = backends.resolve("sharded")
    sharded.set_fault_injector(inj)
    try:
        sup = res.ServingSupervisor(backend="sharded", fallback="tiled")
        srv = compiled.serve(max_batch=2, flush_deadline_s=0.005)
        srv.configure_resilience(
            injector=inj, supervisor=sup,
            retry_policy=res.RetryPolicy(max_retries=2, backoff_s=1e-3))
        with srv:
            outs = [f.result(timeout=300)
                    for f in [srv.submit_async(s) for s in samples]]
    finally:
        sharded.set_fault_injector(None)
    for got, ref in zip(outs, clean_ref):
        np.testing.assert_array_equal(got, ref)
    assert sup.degradations >= 1
    assert sup.history[0]["from"] == "sharded"
    assert sup.backend_name != "sharded"
    # the ladder shrank the mesh (sharded@N on multi-device hosts) or
    # fell back to the single-device lane
    assert (sup.backend_name.startswith("sharded@")
            or sup.backend_name == "tiled")


def test_supervisor_ladder_exhaustion_falls_back_to_tiled():
    sup = res.ServingSupervisor(backend="sharded", fallback="tiled")
    last = None
    for _ in range(32):                         # walk the whole ladder
        name = sup.degrade("test walk")
        if name is None:
            break
        last = name
    assert last == "tiled"                      # bottom rung
    assert sup.degrade("past bottom") is None   # exhausted: no-op
    assert sup.backend_name == "tiled"
    assert [h["from"] for h in sup.history][0] == "sharded"


def test_supervisor_latency_watch_degrades_on_sustained_slowness():
    from repro.runtime.straggler import StragglerConfig
    sup = res.ServingSupervisor(
        backend="sharded", fallback="tiled", warmup=4,
        monitor_cfg=StragglerConfig(ewma_alpha=0.5, threshold=1.5,
                                    patience=2))
    for _ in range(4):                          # establish the baseline
        assert sup.record_latency(0.001) is None
    assert sup.baseline_s == pytest.approx(0.001)
    lane = None
    for _ in range(10):                         # sustained 20x slowness
        lane = sup.record_latency(0.02)
        if lane is not None:
            break
    assert lane is not None
    assert sup.degradations == 1
    assert "latency sustained" in sup.history[0]["reason"]
    # transient blips after the reset do not immediately re-degrade
    assert sup.record_latency(0.001) is None


# ---------------------------------------------------------------------------
# acceptance: mixed chaos run (ISSUE criterion)
# ---------------------------------------------------------------------------

def test_mixed_chaos_run_no_loss_no_dup_bit_identical(compiled, samples,
                                                      clean_ref):
    """Seeded plan injecting dispatch failures, a worker crash, and a
    simulated device loss into a CodrBatchServer + ContinuousBatcher
    mix: zero requests lost or duplicated, every handle resolves, the
    sharded lane degrades with bit-identical outputs."""
    import jax
    from repro.configs import get_config, smoke_variant
    from repro.core.batching import ContinuousBatcher
    from repro.models import get_model

    # --- server side: dispatch error + worker crash + device loss ----
    plan = res.FaultPlan(
        [res.Fault(res.SITE_SERVER_DISPATCH, 0, "error"),
         res.Fault(res.SITE_SERVER_WORKER, 1, "crash"),
         res.Fault(res.SITE_SHARDED_DISPATCH, 2, "device_loss"),
         res.Fault(res.SITE_SERVER_DISPATCH, 4, "latency",
                   latency_s=0.003)])
    inj = res.FaultInjector(plan)
    sharded = backends.resolve("sharded")
    sharded.set_fault_injector(inj)
    try:
        sup = res.ServingSupervisor(backend="sharded", fallback="tiled")
        srv = compiled.serve(max_batch=2, flush_deadline_s=0.005)
        srv.configure_resilience(
            injector=inj, supervisor=sup,
            retry_policy=res.RetryPolicy(max_retries=3, backoff_s=1e-3),
            restart_policy=res.RestartPolicy(max_restarts=2,
                                             backoff_s=1e-3))
        with srv:
            futs = [srv.submit_async(s) for s in samples]
            outs = [f.result(timeout=300) for f in futs]
    finally:
        sharded.set_fault_injector(None)
    for got, ref in zip(outs, clean_ref):
        np.testing.assert_array_equal(got, ref)      # bit-identical
    assert srv.requests_served == len(samples)       # exactly once each
    assert srv.requests_quarantined == 0
    assert all(f.done() for f in futs)               # every one resolves

    # --- batcher side: decode error + worker crash, outputs checked
    # against the sequential solo-decode oracle ----------------------
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = get_model(cfg).init_params(jax.random.PRNGKey(0), cfg)
    cb = ContinuousBatcher(params, cfg, n_slots=2, max_len=24)
    cb.configure_resilience(
        injector=res.FaultInjector(res.FaultPlan(
            [res.Fault(res.SITE_BATCHER_DECODE, 1, "error"),
             res.Fault(res.SITE_BATCHER_WORKER, 2, "crash")])),
        retry_policy=res.RetryPolicy(max_retries=2, backoff_s=1e-3),
        restart_policy=res.RestartPolicy(max_restarts=1, backoff_s=1e-3))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (4, 6)]
    handles = [cb.submit(p, max_new_tokens=5) for p in prompts]
    outs_cb = [h.result(timeout=300) for h in handles]
    cb.stop_async()
    assert cb.worker_crashes == 1 and cb.worker_restarts == 1
    for p, out in zip(prompts, outs_cb):
        ref, _ = cb.generate_reference(p, max_new_tokens=5)
        assert out == ref                            # bit-identical


def test_batcher_decode_retry_bit_identity():
    """Injected decode-step failures retried in place recompute from
    unchanged pool state — the emitted tokens match the solo oracle."""
    import jax
    from repro.configs import get_config, smoke_variant
    from repro.core.batching import ContinuousBatcher
    from repro.models import get_model

    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = get_model(cfg).init_params(jax.random.PRNGKey(0), cfg)
    cb = ContinuousBatcher(params, cfg, n_slots=2, max_len=24)
    cb.configure_resilience(
        injector=res.FaultInjector(res.FaultPlan(
            [res.Fault(res.SITE_BATCHER_DECODE, 0, "error"),
             res.Fault(res.SITE_BATCHER_PREFILL, 1, "error")])),
        retry_policy=res.RetryPolicy(max_retries=2, backoff_s=1e-3))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (3, 5)]
    handles = [cb.submit(p, max_new_tokens=4) for p in prompts]
    outs = [h.result(timeout=300) for h in handles]
    cb.stop_async()
    for p, out in zip(prompts, outs):
        ref, _ = cb.generate_reference(p, max_new_tokens=4)
        assert out == ref


def test_batcher_deadline_and_shedding():
    import jax
    from repro.configs import get_config, smoke_variant
    from repro.core.batching import ContinuousBatcher
    from repro.models import get_model

    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = get_model(cfg).init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)

    # deadline expiry while queued: finish_reason "deadline", no hang
    cb = ContinuousBatcher(params, cfg, n_slots=1, max_len=32,
                           max_pending=2)
    h_long = cb.submit(prompt, max_new_tokens=20)
    h_dead = cb.submit(prompt, max_new_tokens=4, deadline_s=1e-9)
    with pytest.raises(res.DeadlineExceeded):
        h_dead.result(timeout=300)
    assert h_dead.finish_reason == "deadline"
    assert h_long.result(timeout=300)           # the long one completes
    assert cb.requests_expired == 1
    # bounded admission: occupy the slot (first streamed token proves
    # h1 left the pending queue), fill the queue, next submit sheds
    h1 = cb.submit(prompt, max_new_tokens=20)
    next(iter(h1))                              # h1 admitted to its slot
    h2 = cb.submit(prompt, max_new_tokens=4)
    h3 = cb.submit(prompt, max_new_tokens=4)
    with pytest.raises(res.RejectedError):
        cb.submit(prompt, max_new_tokens=4)
    assert cb.requests_shed == 1
    for h in (h1, h2, h3):
        h.result(timeout=300)
    cb.stop_async()


def test_validation_errors():
    with pytest.raises(ValueError, match="max_retries"):
        res.RetryPolicy(max_retries=0)
    with pytest.raises(ValueError, match="max_restarts"):
        res.RestartPolicy(max_restarts=0)
    with pytest.raises(ValueError, match="at least one site"):
        res.FaultPlan.seeded(0, ())
