"""End-to-end system behaviour: the paper's pipeline wired through the
framework — offline encode → compressed serving; full training run on
real (synthetic-structured) data; dry-run cell builder sanity."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import get_model

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_quickstart_example_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "examples/quickstart.py", "--steps", "5"],
        capture_output=True, text=True, timeout=600, cwd=REPO, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "loss" in out.stdout


def test_codr_end_to_end_compressed_serving(key):
    """Paper pipeline on a transformer: quantize+UCR+RLE the weights,
    then serve — logits stay finite, measured bits beat int8."""
    from repro.core.serving import codr_compress_params
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    api = get_model(cfg)
    params = api.init_params(key, cfg)
    cparams, reports = codr_compress_params(params, n_unique=16)
    tokens = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    lgc, _ = api.prefill(cparams, {"tokens": tokens}, cfg)
    assert np.isfinite(np.asarray(lgc, np.float32)).all()
    bits = sum(r.codr_bits for r in reports) / sum(r.n_weights
                                                   for r in reports)
    assert bits < 8.0


def test_smm_conv_matches_float_conv_through_kernel(rng):
    """CNN path: float conv ≈ scale × SMM(int8) through the Pallas
    kernel — the paper's inference model end-to-end."""
    import jax.lax as lax
    from repro.core import ucr
    from repro.kernels.smm_conv import smm_conv
    w = rng.normal(size=(8, 4, 3, 3)).astype(np.float32)
    w[rng.random(w.shape) < 0.5] = 0
    x = rng.integers(-8, 8, size=(4, 12, 12)).astype(np.float32)
    code = ucr.encode_conv_layer(w, t_m=4, t_n=2)
    y_smm = np.asarray(smm_conv(jnp.asarray(x), code)) * float(code.scale)
    y_ref = np.asarray(lax.conv_general_dilated(
        jnp.asarray(x)[None], jnp.asarray(w), (1, 1), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))[0])
    denom = np.abs(y_ref).max() + 1e-6
    assert np.abs(y_smm - y_ref).max() / denom < 0.05


def test_benchmark_harness_importable():
    from benchmarks import run as bench_run
    assert callable(bench_run.main)


@pytest.mark.parametrize("shape_name", ["train_4k", "decode_32k"])
def test_dryrun_cell_builder_abstract(shape_name):
    """build_cell produces coherent abstract shapes/shardings on a tiny
    mesh (the 512-device path is exercised by repro.launch.dryrun)."""
    from repro.configs.base import SHAPES
    from repro.launch.steps import build_cell
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = dataclasses.replace(SHAPES[shape_name], global_batch=2,
                                seq_len=64)
    cfg = smoke_variant(get_config("granite-moe-1b-a400m"))
    fn, arg_shapes, in_sh, _ = build_cell(cfg, shape, mesh)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*arg_shapes)
        assert lowered.compile() is not None
