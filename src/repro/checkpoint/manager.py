"""Fault-tolerant checkpointing.

Design (orbax-free, stdlib + numpy only):

* **Sharded save** — each leaf is written as one ``.npy`` per *host data
  shard* (on a real multi-host cluster every host writes only the shards
  it owns; here one process owns all).  A JSON manifest records the tree
  structure, leaf shapes/dtypes, step, and data-pipeline cursor.
* **Atomic commit** — writes go to ``step_N.tmp/`` and are renamed to
  ``step_N/`` only after the manifest is fsync'd; a crash mid-save never
  corrupts the latest checkpoint.
* **Async** — a single background writer thread snapshots device arrays
  to host memory synchronously (cheap) and does the file I/O off the
  critical path; ``wait()`` joins before the next save or exit.
* **Elastic restore** — leaves are loaded host-side and re-placed with
  ``jax.device_put`` against whatever sharding the *new* mesh prescribes,
  so a checkpoint taken on N hosts restores onto M ≠ N hosts (docs/DESIGN.md
  §5 elastic re-mesh).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, *, extra: dict | None = None,
             async_: bool = True) -> None:
        self.wait()
        leaves, treedef = _flatten(tree)
        # snapshot to host (synchronous, so training can mutate buffers)
        host_leaves = [np.asarray(x) for x in leaves]
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "extra": extra or {},
            "leaves": [{"shape": list(x.shape), "dtype": str(x.dtype)}
                       for x in host_leaves],
        }

        def write():
            tmp = os.path.join(self.directory, f"step_{step}.tmp")
            final = os.path.join(self.directory, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for i, x in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"leaf_{i}.npy"), x)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if async_:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def restore(self, step: int, target_tree, *, shardings=None):
        """Load leaves and place them on device (optionally against a new
        mesh's shardings — elastic restore)."""
        path = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = _flatten(target_tree)
        assert manifest["n_leaves"] == len(leaves), "tree structure changed"
        host = [np.load(os.path.join(path, f"leaf_{i}.npy"))
                for i in range(len(leaves))]
        if shardings is not None:
            shard_leaves, _ = _flatten(shardings)
            placed = [jax.device_put(h, s) for h, s in zip(host, shard_leaves)]
        else:
            placed = [jax.device_put(h.astype(l.dtype))
                      for h, l in zip(host, leaves)]
        return jax.tree_util.tree_unflatten(treedef, placed), manifest["extra"]


def restore_latest(manager: CheckpointManager, target_tree, *,
                   shardings=None):
    steps = manager.steps()
    if not steps:
        return None, None, -1
    tree, extra = manager.restore(steps[-1], target_tree,
                                  shardings=shardings)
    return tree, extra, steps[-1]
