"""codrlint fixture: broad catches that re-raise, deliver, or log."""
import logging

log = logging.getLogger(__name__)


def reraises():
    try:
        risky()                     # noqa: F821
    except Exception:
        raise


def uses_bound(handle):
    try:
        risky()                     # noqa: F821
    except Exception as e:
        handle.fail(e)              # delivered, not swallowed


def logs():
    try:
        risky()                     # noqa: F821
    except Exception:
        log.warning("risky() failed; degrading")
        return None


def narrow():
    try:
        risky()                     # noqa: F821
    except ValueError:
        return 0                    # narrow catch — out of scope
