"""codrlint fixture: jit-crossing dataclasses missing registration."""
import dataclasses

import jax


@dataclasses.dataclass
class UnregisteredLeaf:
    data: jax.Array                 # array field ⇒ registration required
    scale: float = 1.0


@dataclasses.dataclass
class WrapsLeaf:
    inner: UnregisteredLeaf         # transitively required
    label: str = ""
