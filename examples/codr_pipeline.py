"""The paper's full pipeline on a conv layer, end to end:

  quantize → UCR (sort/densify/unify/Δ) → customized RLE → bitstream
  → decode → scalar-matrix-multiply conv (Pallas kernel, MPE/APE
  datapath) → verify exactness vs dense convolution,

plus compression vs the SCNN/UCNN baselines, the dataflow's SRAM
access / energy accounting (paper Figs. 6–8 in miniature), and the
spec → compile → serve engine API (``repro.api``): a declarative
conv → conv → linear ModelSpec compiled once into a CompiledModel that
serves batched requests from the compressed code.

    PYTHONPATH=src python examples/codr_pipeline.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.api as codr
from repro.core import cost_model, dataflow, rle, ucr
from repro.core.baselines import scnn_compress_bits, ucnn_compress_bits
from repro.core.dataflow import CODR_TILING, SCNN_TILING, UCNN_TILING, ConvShape
from repro.kernels.smm_conv import smm_conv, smm_conv_ref


def main() -> None:
    rng = np.random.default_rng(0)
    shape = ConvShape(32, 16, 3, 3, 20, 20)
    w = rng.normal(size=(shape.m, shape.n, shape.rk, shape.ck)
                   ).astype(np.float32) * 0.5
    w[rng.random(w.shape) < 0.6] = 0           # 40% density

    # -- offline encode (paper §II-D steps i–v) -----------------------------
    code = ucr.encode_conv_layer(w, t_m=CODR_TILING.t_m, t_n=CODR_TILING.t_n)
    q, _ = ucr.quantize_int8(w)
    n_unique = sum(len(u.unique_vals) for u in code.ucr)
    n_nonzero = sum(u.n_nonzero for u in code.ucr)
    print(f"layer {shape.m}x{shape.n}x{shape.rk}x{shape.ck}: "
          f"{code.n_weights} weights, {n_nonzero} nonzero, "
          f"{n_unique} unique-per-vector total")
    print(f"  CoDR customized RLE : {code.bits_per_weight:.2f} bits/weight")
    print(f"  UCNN fixed 5-bit RLE: "
          f"{ucnn_compress_bits(code.ucr)/code.n_weights:.2f} bits/weight")
    print(f"  SCNN zero-run 4-bit : "
          f"{scnn_compress_bits(q)/code.n_weights:.2f} bits/weight")

    # -- exact bitstream roundtrip ------------------------------------------
    enc = code.vectors[0]
    dec = rle.decode_vector(enc)
    u0 = code.ucr[0]
    assert np.array_equal(dec, ucr.ucr_reconstruct(u0))
    print(f"  bitstream roundtrip lossless ✓ "
          f"(vector 0: {enc.total_bits} bits for {enc.vector_len} weights)")

    # -- execute on the Pallas MPE/APE kernel -------------------------------
    x = rng.integers(-8, 8, size=(shape.n, shape.ri, shape.ci)
                     ).astype(np.int8)
    y_kernel = smm_conv(jnp.asarray(x), code)
    y_dense = smm_conv_ref(x, code)
    err = float(jnp.abs(y_kernel - y_dense).max())
    print(f"  SMM kernel vs dense conv: max err = {err} (exact) ✓")

    # -- dataflow accounting (Figs. 7/8) ------------------------------------
    a_codr = dataflow.codr_accesses(shape, CODR_TILING, code.total_bits,
                                    n_unique, n_nonzero)
    a_ucnn = dataflow.ucnn_accesses(shape, UCNN_TILING,
                                    float(ucnn_compress_bits(code.ucr)),
                                    n_unique, n_nonzero)
    a_scnn = dataflow.scnn_accesses(shape, SCNN_TILING,
                                    float(scnn_compress_bits(q)),
                                    n_unique, n_nonzero)
    for acc in (a_codr, a_ucnn, a_scnn):
        e = cost_model.energy(acc)
        print(f"  {acc.name}: SRAM accesses={acc.total_sram:,.0f} "
              f"(features {acc.feature_sram:,.0f}) "
              f"energy={e.total_uj:.1f} µJ "
              f"[dram {e.dram_uj:.1f} | sram {e.sram_uj:.1f} | "
              f"alu {e.alu_uj:.1f}]")

    # -- spec → compile → serve (encode once, run many) ---------------------
    spec = codr.ModelSpec.from_shapes(
        [ConvShape(16, 3, 3, 3, 16, 16, 1), ConvShape(24, 16, 3, 3, 14, 14, 1)],
        n_out=10, density=0.4, rng=rng)
    compiled = codr.compile(spec, codr.EncodeConfig(), backend="tiled")
    compiled.verify_roundtrip()
    x = rng.normal(size=(8, 16, 16, 3)).astype(np.float32)
    y = compiled.run(x)
    yr = compiled.reference(x)
    rel = float(jnp.abs(y - yr).max() / (jnp.abs(yr).max() + 1e-9))
    print(f"  engine conv→conv→linear on batch {x.shape[0]}: out {y.shape}, "
          f"{compiled.bits_per_weight():.2f} bits/weight, "
          f"rel err vs dense float ref = {rel:.4f} "
          f"(backends: {', '.join(codr.available_backends())})")
    server = compiled.serve(max_batch=4)
    outs = server.serve([x[i] for i in range(6)])
    print(f"  batch server: {len(outs)} requests in {server.batches_run} "
          f"batches ✓")
    for name, acc in compiled.sram_report((16, 16)):
        print(f"    {name}: est. SRAM accesses/sample={acc.total_sram:,.0f}")

    # -- serving at scale: sharded executor + async request path ------------
    # (docs/DESIGN.md §3 — on one device the sharded mesh degrades to a
    # 1-element fallback; outputs are bit-identical to "tiled" either way)
    y_sh = compiled.run(x, backend="sharded")
    assert bool(jnp.all(y_sh == y)), "sharded != tiled"
    print(f"  sharded executor over {len(jax.devices())} device(s): "
          f"bit-identical to tiled ✓")
    aserver = compiled.serve(max_batch=4, flush_deadline_s=0.01)
    with aserver:                       # background flush loop
        futs = [aserver.submit_async(x[i]) for i in range(6)]
        aouts = [f.result(timeout=120) for f in futs]
    print(f"  async server: {len(aouts)} futures in {aserver.batches_run} "
          f"batches (deadline {aserver.flush_deadline_s*1000:.0f} ms) ✓")


if __name__ == "__main__":
    main()
