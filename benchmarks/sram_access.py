"""Paper Fig. 7 — SRAM access analysis (GoogleNet, density / unique
sweeps).  Counts input/output/weight SRAM accesses under the three
dataflows' loop orderings and reports CoDR's reduction factors
(paper: 5.08× vs UCNN, 7.99× vs SCNN)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import BASE_DENSITY, Timer, csv_line, \
    make_weights, sampled_layer_vectors
from repro.configs.paper_cnns import PAPER_CNNS
from repro.core import dataflow, rle
from repro.core.baselines.scnn import scnn_compress_bits
from repro.core.baselines.ucnn import ucnn_vector_bits
from repro.core.dataflow import CODR_TILING, SCNN_TILING, UCNN_TILING

SWEEPS = [("U16", 1.0, 16), ("orig", 1.0, 256), ("D0.4", 0.4, 256)]


def model_accesses(model: str, density: float, n_unique: int, rng) -> dict:
    totals = {"CoDR": 0.0, "UCNN": 0.0, "SCNN": 0.0}
    weight_share = {"CoDR": 0.0}
    feat = {"CoDR": 0.0, "UCNN": 0.0, "SCNN": 0.0}
    for shape in PAPER_CNNS[model]:
        q = make_weights((shape.m, shape.n, shape.rk, shape.ck),
                         density=density * BASE_DENSITY[model],
                         n_unique=n_unique, rng=rng)
        vecs, scale = sampled_layer_vectors(q, CODR_TILING.t_m,
                                            CODR_TILING.t_n)
        codr_bits = scale * rle.layer_bits_size_only(
            vecs, CODR_TILING.t_m * shape.rk * shape.ck)
        ucnn_bits = scale * sum(ucnn_vector_bits(u) for u in vecs)
        scnn_bits = float(scnn_compress_bits(q))
        nu = scale * sum(len(u.unique_vals) for u in vecs)
        nn = scale * sum(u.n_nonzero for u in vecs)

        a_codr = dataflow.codr_accesses(shape, CODR_TILING, codr_bits, nu, nn)
        a_ucnn = dataflow.ucnn_accesses(shape, UCNN_TILING, ucnn_bits, nu, nn)
        a_scnn = dataflow.scnn_accesses(shape, SCNN_TILING, scnn_bits, nu, nn)
        totals["CoDR"] += a_codr.total_sram
        totals["UCNN"] += a_ucnn.total_sram
        totals["SCNN"] += a_scnn.total_sram
        feat["CoDR"] += a_codr.feature_sram
        feat["UCNN"] += a_ucnn.feature_sram
        feat["SCNN"] += a_scnn.feature_sram
        weight_share["CoDR"] += a_codr.weight_sram_rows
    return {
        "x_ucnn": totals["UCNN"] / totals["CoDR"],
        "x_scnn": totals["SCNN"] / totals["CoDR"],
        "codr_weight_frac": weight_share["CoDR"]
        / max(totals["CoDR"], 1),
        "feat_x_ucnn": feat["UCNN"] / max(feat["CoDR"], 1),
        "feat_x_scnn": feat["SCNN"] / max(feat["CoDR"], 1),
    }


def main(print_fn=print) -> list[str]:
    rng = np.random.default_rng(1)
    lines = []
    for tag, density, n_unique in SWEEPS:
        with Timer() as t:
            r = model_accesses("googlenet", density, n_unique, rng)
        name = f"fig7_sram/googlenet/{tag}"
        derived = (f"x_ucnn={r['x_ucnn']:.2f}(paper:5.08)"
                   f";x_scnn={r['x_scnn']:.2f}(paper:7.99)"
                   f";codr_weight_frac={r['codr_weight_frac']:.2f}(paper:0.50)"
                   f";feat_x_ucnn={r['feat_x_ucnn']:.1f}"
                   f";feat_x_scnn={r['feat_x_scnn']:.1f}")
        lines.append(csv_line(name, t.dt * 1e6, derived))
        print_fn(lines[-1])
    return lines


if __name__ == "__main__":
    main()
