"""Benchmark harness — one module per paper table/figure plus the
roofline report.  Prints ``name,us_per_call,derived`` CSV lines.

  python -m benchmarks.run [--only fig6|fig7|fig8|kernels|roofline|engine|decode]
                           [--small]

``--small`` runs the size-aware suites (engine — the spec→compile→serve
API path — and decode) in their CI smoke configuration; the CI workflow
uses it so every PR appends a comparable, SHA-stamped point to the
``BENCH_*.json`` perf trajectories.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks import compression, decode, energy, engine, kernels, \
    roofline, sram_access

SUITES = {
    "fig6": compression.main,
    "fig7": sram_access.main,
    "fig8": energy.main,
    "kernels": kernels.main,
    "roofline": roofline.main,
    "engine": engine.main,
    "decode": decode.main,
}
SMALL_AWARE = {"engine", "decode"}     # mains accepting a small= kwarg


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=list(SUITES), default=None)
    ap.add_argument("--small", action="store_true",
                    help="CI smoke sizes for the suites that support it "
                         f"({', '.join(sorted(SMALL_AWARE))})")
    args = ap.parse_args(argv)
    suites = {args.only: SUITES[args.only]} if args.only else SUITES
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        try:
            if args.small and name in SMALL_AWARE:
                fn(small=True)
            else:
                fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0.00,ERROR:{type(e).__name__}:{e}",
                  file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
