"""Serving driver: batched prefill + decode with CoDR-compressed weights.

Demonstrates the paper's technique as a first-class serving feature:
``--codr`` compiles the params pytree onto the packed bitstream
representation (``repro.api.compile_params``) so every projection matmul
resolves through the backend registry into the decode-fused
``codr_matmul`` kernel (interpret mode on CPU, Mosaic on TPU) — the
model serves *from* the compressed weights, not from a dense copy that
merely had quantization applied — and the reported weight HBM bytes are
measured on the stored pack rather than estimated.

``--packed-ckpt [PATH]`` boots from a packed checkpoint artifact
(``repro.api.save_packed``): if PATH exists it is mmap-loaded (no
re-encode); otherwise the run compiles once, saves the artifact, and
reloads it — so the flag is self-contained in CI.  Packed boots default
to the quantized **paged** KV cache (``--kv-dtype int8``); ``--kv-dtype
bf16`` with ``--kv-page-size`` gives the bit-identical paged escape
hatch, and ``--check`` verifies each mode against the dense-cache
sequential reference (token-exact for bf16, teacher-forced logit bound
for int8 — docs/DESIGN.md §2.2).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.api as codr
from repro.configs import get_config, smoke_variant
from repro.core.serving import codr_serving_stats
from repro.models import get_model


def run_serve(*, arch: str = "qwen2.5-3b", batch: int = 4,
              prompt_len: int = 32, gen_len: int = 32, use_codr: bool = False,
              codr_unique: int = 16, codr_backend: str = "codr_matmul",
              verbose: bool = True) -> dict:
    """One serving run: prefill + greedy decode on the smoke variant of
    ``arch``.  Returns a metrics dict (timings, generated tokens, and —
    under ``use_codr`` — the measured packed-representation HBM bytes).
    Importable so tests, benchmarks, and CI drive the same path as the
    CLI."""
    cfg = smoke_variant(get_config(arch))
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg)

    compiled = None
    if use_codr:
        compiled = codr.compile_params(
            params, codr.EncodeConfig(n_unique=codr_unique),
            backend=codr_backend)
        params = compiled.params
        if verbose:
            print(compiled.summary())

    total = prompt_len + gen_len
    tokens = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    batch_in = {"tokens": tokens}
    if cfg.frontend or cfg.family == "encdec":
        batch_in["prefix"] = jax.random.normal(
            key, (batch, cfg.frontend_seq, cfg.d_model))

    t0 = time.monotonic()
    logits, cache = api.prefill(params, batch_in, cfg)
    t_prefill = time.monotonic() - t0

    step = jax.jit(lambda p, c, t, i: api.decode_step(p, c, t, i, cfg))
    out_tokens: list[np.ndarray] = []
    cache_self_len = None
    n_steps = 0                      # decode_step calls actually executed
    t0 = time.monotonic()
    if cfg.family == "encdec":
        # Continue from the prefill cache: pad the decoder self-attention
        # KV out to the full prompt+gen length (decode writes positions
        # >= prompt_len; the tail stays masked until written).  The
        # cross-attention KV carries the encoder output and must be kept
        # — re-initializing it (the old replay path) served decode steps
        # against an all-zero encoder.
        pad = total - cache["self"][0].shape[2]
        if pad > 0:
            cache = {**cache, "self": tuple(
                jnp.pad(kv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                for kv in cache["self"])}
        cache_self_len = int(cache["self"][0].shape[2])
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        if gen_len > 0:
            out_tokens.append(np.asarray(tok))
        for i in range(prompt_len, total - 1):
            logits, cache = step(params, cache, tok, jnp.int32(i))
            n_steps += 1
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out_tokens.append(np.asarray(tok))
    else:
        # greedy decode continuing from a fresh full-length cache: replay
        # the prompt then generate (keeps cache shapes static)
        cache = api.init_cache(cfg, batch, total)
        tok = tokens[:, 0]
        for i in range(total - 1):
            logits, cache = step(params, cache, tok, jnp.int32(i))
            n_steps += 1
            if i + 1 < prompt_len:
                tok = tokens[:, i + 1]
            else:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                out_tokens.append(np.asarray(tok))
    t_decode = time.monotonic() - t0
    gen = (np.stack(out_tokens, 1) if out_tokens
           else np.zeros((batch, 0), np.int32))

    # per executed decode_step call — the LM path replays the prompt
    # through decode, so dividing by generated tokens alone would
    # overstate the per-token cost
    ms_per_tok = t_decode / max(n_steps, 1) * 1e3
    kv_bytes = sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(cache))
    if verbose:
        print(f"prefill {prompt_len} toks: {t_prefill*1e3:.1f} ms; "
              f"decode {n_steps} steps ({len(out_tokens)} generated): "
              f"{t_decode*1e3:.1f} ms ({ms_per_tok:.2f} ms/step)")
        if gen.size:
            print("sample generation (first row):", gen[0][:16])

    result = {
        "arch": arch, "family": cfg.family, "gen": gen,
        "prefill_s": t_prefill, "decode_s": t_decode,
        "n_decode_steps": n_steps,
        "ms_per_tok": ms_per_tok,
        "cache_self_len": cache_self_len,
        "kv_bytes": kv_bytes,
    }
    if compiled is not None:
        # measured on the stored packed representation, not estimated
        result.update(
            hbm_bytes=compiled.hbm_bytes(),
            dense_bf16_bytes=compiled.dense_bf16_bytes(),
            bits_per_weight=compiled.bits_per_weight(),
            n_packed=len(compiled.packed_paths),
            backend=compiled.backend)
        if verbose:
            print(f"weight HBM, measured on the packed representation "
                  f"({compiled.backend}): "
                  f"{compiled.hbm_bytes()/1e6:.3f} MB vs "
                  f"bf16 {compiled.dense_bf16_bytes()/1e6:.3f} MB "
                  f"({compiled.compression_vs_bf16():.1f}x, "
                  f"{compiled.bits_per_weight():.2f} bits/weight)")
    elif verbose:
        stats = codr_serving_stats(cfg, n_unique=codr_unique)
        unit, scale = ("GB", 1.0) if stats["bf16_gb"] > 0.5 else ("MB", 1e3)
        print(f"decode HBM weight traffic/token ({stats['source']}: "
              f"extrapolated from one synthetic matrix, NOT measured — "
              f"full {cfg.name} geometry): "
              f"bf16={stats['bf16_gb']*scale:.2f} {unit}, "
              f"int8={stats['int8_gb']*scale:.2f} {unit}, "
              f"codr(U={codr_unique})≈{stats['codr_gb']*scale:.2f} {unit} "
              f"({stats['codr_bits_per_weight']:.2f} bits/weight)")
    return result


def run_serve_continuous(*, arch: str = "qwen2.5-3b", n_requests: int = 4,
                         n_slots: int = 4, prompt_len: int = 8,
                         gen_len: int = 8, max_len: int = 64,
                         use_codr: bool = False, codr_unique: int = 16,
                         codr_backend: str = "codr_matmul",
                         check: bool = False, seed: int = 0,
                         chaos_seed: int | None = None,
                         kv_dtype: str | None = None,
                         kv_page_size: int | None = None,
                         packed_ckpt: str | None = None,
                         verbose: bool = True) -> dict:
    """Continuous-batching serving run: ``n_requests`` mixed-length
    prompts streamed through a :class:`repro.core.batching
    .ContinuousBatcher` slot pool.  With ``check=True`` every streamed
    output is asserted bit-identical to the sequential solo-decode
    reference on the same params (the CI smoke contract); lossy KV
    modes (``kv_dtype="int8"``) additionally replay the dense-cache
    reference's tokens teacher-forced through the paged pipeline and
    bound the per-step logit deviation.

    ``packed_ckpt`` boots the weights from a packed checkpoint
    artifact (saving one first if the path does not exist) and — unless
    overridden — turns on the quantized paged KV cache, so one flag
    exercises the full "compress offline, serve packed" path.

    ``chaos_seed`` arms a deterministic fault plan
    (:meth:`repro.runtime.resilience.FaultPlan.seeded` over the
    batcher's worker/prefill/decode sites: transient dispatch errors,
    injected latency, worker crashes) with retry + supervised-restart
    budgets sized to the plan — the chaos contract is that every
    request still finishes with bit-identical outputs, which
    ``--chaos <seed> --check`` asserts in CI."""
    from repro.core.batching import ContinuousBatcher

    cfg = smoke_variant(get_config(arch))
    api = get_model(cfg)
    key = jax.random.PRNGKey(seed)

    if kv_dtype is None:
        # packed boots default to the quantized paged cache; plain runs
        # keep today's dense bf16 pool
        kv_dtype = "int8" if packed_ckpt is not None else "bf16"
    if kv_dtype == "int8" and kv_page_size is None:
        kv_page_size = 4 if max_len <= 128 else 16

    compiled = None
    boot_s = None
    if packed_ckpt is not None:
        import os
        if not os.path.exists(packed_ckpt):
            # self-contained: compile once and persist the artifact,
            # then boot from it like any later run would
            params = api.init_params(key, cfg)
            t0 = time.monotonic()
            cp = codr.compile_params(
                params, codr.EncodeConfig(n_unique=codr_unique),
                backend=codr_backend)
            codr.save_packed(cp, packed_ckpt)
            if verbose:
                print(f"packed checkpoint written to {packed_ckpt} "
                      f"({time.monotonic()-t0:.2f}s compile+save)")
        t0 = time.monotonic()
        compiled = codr.load_packed(packed_ckpt)
        boot_s = time.monotonic() - t0
        params = compiled.params
        if verbose:
            print(f"booted from packed checkpoint {packed_ckpt} in "
                  f"{boot_s*1e3:.1f} ms (format v"
                  f"{codr.CODR_FORMAT_VERSION}, mmap)")
            print(compiled.summary())
    else:
        params = api.init_params(key, cfg)
        if use_codr:
            compiled = codr.compile_params(
                params, codr.EncodeConfig(n_unique=codr_unique),
                backend=codr_backend)
            params = compiled.params
            if verbose:
                print(compiled.summary())

    rng = np.random.default_rng(seed)
    # mixed prompt lengths around prompt_len: the join-on-prefill path
    # must handle ragged admissions
    lens = [max(1, prompt_len + (i % 3) - 1) for i in range(n_requests)]
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in lens]
    max_len = max(max_len, max(lens) + gen_len)    # pool must fit every req

    batcher = ContinuousBatcher(params, cfg, n_slots=n_slots,
                                max_len=max_len, kv_dtype=kv_dtype,
                                kv_page_size=kv_page_size)
    injector = None
    if chaos_seed is not None:
        from repro.runtime import resilience as res
        plan = res.FaultPlan.seeded(
            chaos_seed,
            (res.SITE_BATCHER_WORKER, res.SITE_BATCHER_PREFILL,
             res.SITE_BATCHER_DECODE),
            n_faults=4, max_call=max(4, n_requests * gen_len // 2),
            latency_s=0.002)
        injector = res.FaultInjector(plan)
        # budgets sized to the plan: every injected fault is survivable,
        # so the run must finish with bit-identical outputs
        batcher.configure_resilience(
            injector=injector,
            retry_policy=res.RetryPolicy(max_retries=max(2, len(plan)),
                                         backoff_s=0.001),
            restart_policy=res.RestartPolicy(
                max_restarts=max(1, len(plan)), backoff_s=0.001))
        if verbose:
            print(f"chaos seed {chaos_seed}: {plan.describe()}")
    t0 = time.monotonic()
    handles = [batcher.submit(p, max_new_tokens=gen_len) for p in prompts]
    streamed = [[tok for tok in h] for h in handles]
    t_total = time.monotonic() - t0
    batcher.stop_async()

    n_tokens = sum(len(s) for s in streamed)
    toks_per_s = n_tokens / max(t_total, 1e-9)
    kv_bytes = batcher.kv_bytes()
    if verbose:
        print(f"continuous batching: {n_requests} requests "
              f"(prompt lens {lens}) over {n_slots} slots → "
              f"{n_tokens} tokens in {t_total*1e3:.1f} ms "
              f"({toks_per_s:.1f} tok/s); steps={batcher.steps_run} "
              f"prefills={batcher.prefills_run} "
              f"peak_active={batcher.peak_active}")
        print(f"KV pool: {kv_dtype}"
              + (f" paged (page_size={kv_page_size})"
                 if kv_page_size is not None else " dense")
              + f", {kv_bytes/1e3:.1f} kB resident")
        if injector is not None:
            print(f"chaos: {len(injector.fired)}/{len(injector.plan)} "
                  f"scheduled faults fired "
                  f"({[f'{f.site}#{f.at_call}:{f.kind}' for f in injector.fired]}); "
                  f"worker crashes={batcher.worker_crashes} "
                  f"restarts={batcher.worker_restarts}")
        if compiled is not None:
            stats = codr_serving_stats(cfg, reports=compiled.reports)
            print(f"weight HBM ({stats['source']} on this model's "
                  f"tensors): {compiled.hbm_bytes()/1e6:.3f} MB packed, "
                  f"{stats['pack_bits_per_weight']:.2f} pack bits/weight")

    matched = None
    check_dev = None
    if check:
        matched = 0
        # a dense-cache twin on the SAME served params is the oracle for
        # paged modes: bf16-paged must reproduce its tokens bit-exactly;
        # int8 is lossy, so its contract is the teacher-forced logit
        # bound (free-running greedy legitimately diverges on near-tied
        # logits — see ContinuousBatcher.replay_logits)
        dense_ref = (ContinuousBatcher(params, cfg, n_slots=n_slots,
                                       max_len=max_len)
                     if kv_page_size is not None else batcher)
        for p, s in zip(prompts, streamed):
            same, _ = batcher.generate_reference(p, max_new_tokens=gen_len)
            assert s == same, (
                f"streamed output diverged from the sequential reference:"
                f" {s} vs {same}")
            dense_toks, _ = dense_ref.generate_reference(
                p, max_new_tokens=gen_len)
            if kv_dtype == "int8":
                dense_rows = dense_ref.replay_logits(p, dense_toks)
                paged_rows = batcher.replay_logits(p, dense_toks)
                assert np.array_equal(paged_rows[0], dense_rows[0]), (
                    "prefill logits must be bit-exact across KV modes")
                spread = float(dense_rows.max() - dense_rows.min()) or 1.0
                dev = float(np.abs(paged_rows - dense_rows).max()) / spread
                check_dev = max(check_dev or 0.0, dev)
                assert dev < 0.10, (
                    f"int8-paged teacher-forced logits deviate "
                    f"{dev:.4f} of the dense logit spread (bound 0.10)")
            else:
                assert s == dense_toks, (
                    f"bf16 KV must match the dense-cache reference "
                    f"bit-exactly: {s} vs {dense_toks}")
            matched += 1
        if verbose:
            print(f"check: {matched}/{n_requests} streamed outputs "
                  f"verified against the dense-cache sequential "
                  f"reference"
                  + (f" (worst teacher-forced logit deviation "
                     f"{check_dev:.4f} of spread, bound 0.10)"
                     if check_dev is not None else " (bit-identical)"))

    return {
        "arch": arch, "n_requests": n_requests, "n_slots": n_slots,
        "prompt_lens": lens, "gen": streamed, "total_s": t_total,
        "tokens_per_s": toks_per_s, "steps_run": batcher.steps_run,
        "prefills_run": batcher.prefills_run,
        "peak_active": batcher.peak_active, "checked": matched,
        "backend": compiled.backend if compiled is not None else None,
        "chaos_seed": chaos_seed,
        "faults_fired": (len(injector.fired) if injector is not None
                         else None),
        "worker_restarts": batcher.worker_restarts,
        "kv_dtype": kv_dtype, "kv_page_size": kv_page_size,
        "kv_bytes": kv_bytes, "boot_s": boot_s,
        "packed_ckpt": packed_ckpt, "check_dev": check_dev,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--codr", action="store_true",
                    help="serve from the packed CoDR weight representation")
    ap.add_argument("--codr-unique", type=int, default=16,
                    help="unique-weight budget per tensor (paper Fig. 6 U)")
    ap.add_argument("--codr-backend", default="codr_matmul",
                    help="packed-matmul backend: codr_matmul (fused "
                         "decode+matmul kernel) or tiled/sharded "
                         "(decode-then-matmul reference lane)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching mode: stream --requests "
                         "concurrent mixed-length prompts through a "
                         "slot-pooled decode loop")
    ap.add_argument("--requests", type=int, default=4,
                    help="concurrent requests (--continuous)")
    ap.add_argument("--slots", type=int, default=4,
                    help="KV-cache pool slots (--continuous)")
    ap.add_argument("--check", action="store_true",
                    help="assert streamed outputs are bit-identical to "
                         "the sequential reference (--continuous)")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="inject a deterministic seeded fault plan "
                         "(dispatch errors, latency, worker crashes) "
                         "into the continuous-batching run; combine "
                         "with --check to assert outputs survive "
                         "bit-identically (--continuous)")
    ap.add_argument("--packed-ckpt", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="boot from a packed checkpoint artifact "
                         "(codr.save_packed); writes one first if PATH "
                         "is missing.  Without PATH a per-arch default "
                         "under /tmp is used.  Implies --kv-dtype int8 "
                         "unless overridden (--continuous)")
    ap.add_argument("--kv-dtype", choices=("bf16", "int8"), default=None,
                    help="KV cache storage: bf16 (bit-identical; dense "
                         "unless --kv-page-size) or int8 (quantized "
                         "paged) (--continuous)")
    ap.add_argument("--kv-page-size", type=int, default=None,
                    help="tokens per KV page; enables the paged pool "
                         "for bf16 too (--continuous)")
    args = ap.parse_args()
    packed_ckpt = args.packed_ckpt
    if packed_ckpt == "":
        packed_ckpt = f"/tmp/codr_packed_{args.arch.replace('/', '_')}.codr"
    if args.continuous:
        run_serve_continuous(
            arch=args.arch, n_requests=args.requests, n_slots=args.slots,
            prompt_len=args.prompt_len, gen_len=args.gen_len,
            use_codr=args.codr, codr_unique=args.codr_unique,
            codr_backend=args.codr_backend, check=args.check,
            chaos_seed=args.chaos, kv_dtype=args.kv_dtype,
            kv_page_size=args.kv_page_size, packed_ckpt=packed_ckpt)
    else:
        run_serve(arch=args.arch, batch=args.batch,
                  prompt_len=args.prompt_len, gen_len=args.gen_len,
                  use_codr=args.codr, codr_unique=args.codr_unique,
                  codr_backend=args.codr_backend)


if __name__ == "__main__":
    main()
