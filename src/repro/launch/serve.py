"""Serving driver: batched prefill + decode with CoDR-compressed weights.

Demonstrates the paper's technique as a first-class serving feature:
``--codr`` converts every 2-D projection weight to the CoDR unique-index
format (offline UCR + per-tensor parameter search), reports the measured
compression (HBM bytes vs bf16), and serves with the decode-fused
reference path (the Pallas kernel on TPU).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.serving import (codr_compress_params, codr_report,
                                codr_serving_stats)
from repro.models import get_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--codr", action="store_true",
                    help="serve with CoDR-compressed weights")
    ap.add_argument("--codr-unique", type=int, default=16,
                    help="unique-weight budget per tensor (paper Fig. 6 U)")
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg)

    if args.codr:
        params, report = codr_compress_params(params, n_unique=args.codr_unique)
        print(codr_report(report))

    total = args.prompt_len + args.gen_len
    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend or cfg.family == "encdec":
        batch["prefix"] = jax.random.normal(
            key, (args.batch, cfg.frontend_seq, cfg.d_model))

    t0 = time.monotonic()
    if cfg.family == "encdec":
        logits, cache = api.prefill(params, batch, cfg)
        # decoder cache: pad self-attn cache to total length
        pad = total - cache["self"][0].shape[2] if False else 0  # noqa: F841
    else:
        logits, cache = api.prefill(params, batch, cfg)
    t_prefill = time.monotonic() - t0

    # greedy decode continuing from a fresh full-length cache: replay the
    # prompt then generate (keeps cache shapes static)
    cache = api.init_cache(cfg, args.batch, total)
    step = jax.jit(lambda p, c, t, i: api.decode_step(p, c, t, i, cfg))
    out_tokens = []
    tok = tokens[:, 0]
    t0 = time.monotonic()
    for i in range(total - 1):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        if i + 1 < args.prompt_len:
            tok = tokens[:, i + 1]
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out_tokens.append(np.asarray(tok))
    t_decode = time.monotonic() - t0
    gen = np.stack(out_tokens, 1)
    print(f"prefill {args.prompt_len} toks: {t_prefill*1e3:.1f} ms; "
          f"decode {len(out_tokens)} steps: {t_decode*1e3:.1f} ms "
          f"({t_decode/max(len(out_tokens),1)*1e3:.2f} ms/tok)")
    print("sample generation (first row):", gen[0][:16])
    stats = codr_serving_stats(cfg)
    unit, scale = ("GB", 1.0) if stats["bf16_gb"] > 0.5 else ("MB", 1e3)
    print(f"decode HBM weight traffic/token: "
          f"bf16={stats['bf16_gb']*scale:.2f} {unit}, "
          f"int8={stats['int8_gb']*scale:.2f} {unit}, "
          f"codr(U={args.codr_unique})≈{stats['codr_gb']*scale:.2f} {unit} "
          f"({stats['codr_bits_per_weight']:.2f} bits/weight)")


if __name__ == "__main__":
    main()
