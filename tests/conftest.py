"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — the
smoke tests must see the real single CPU device (the 512-device override
belongs exclusively to repro.launch.dryrun)."""
import jax
import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed_global_rngs():
    """Deterministic runs: pin NumPy's global RNG before every test (JAX
    randomness is already explicit via PRNGKey fixtures below)."""
    np.random.seed(0)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
