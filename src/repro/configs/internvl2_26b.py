"""internvl2-26b [vlm] — InternViT frontend (stub per spec) +
InternLM2-20B backbone. [arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553,
    frontend="vision", frontend_seq=1024,
    rope_theta=1e6,
)
