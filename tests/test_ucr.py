"""Universal Computation Reuse invariants (paper §II-D)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ucr


@given(st.lists(st.integers(-128, 127), min_size=1, max_size=512))
@settings(max_examples=200, deadline=None)
def test_transform_reconstruct_roundtrip(vals):
    w = np.array(vals, dtype=np.int8)
    u = ucr.ucr_transform(w)
    assert np.array_equal(ucr.ucr_reconstruct(u), w)


@given(st.lists(st.integers(-128, 127), min_size=1, max_size=512))
@settings(max_examples=200, deadline=None)
def test_unify_invariants(vals):
    w = np.array(vals, dtype=np.int8)
    u = ucr.ucr_transform(w)
    # sorted strictly ascending unique non-zero values
    assert (np.diff(u.unique_vals) > 0).all()
    assert (u.unique_vals != 0).all()
    # reps count every nonzero exactly once
    assert u.reps.sum() == (w != 0).sum()
    # per-group indexes ascend (CoDR orders repetitions by position)
    cursor = 0
    for rep in u.reps:
        grp = u.indexes[cursor:cursor + int(rep)]
        assert (np.diff(grp) > 0).all()
        cursor += int(rep)
    # multiplications needed = unique count ≤ nonzero count ≤ total
    assert len(u.unique_vals) <= u.n_nonzero <= u.vector_len


def test_quantize_int8_bounds_and_inverse(rng):
    w = rng.normal(size=(64, 32)).astype(np.float32)
    q, scale = ucr.quantize_int8(w)
    assert q.dtype == np.int8 and np.abs(q).max() <= 127
    err = np.abs(ucr.dequantize_int8(q, scale) - w).max()
    assert err <= scale * 0.5 + 1e-6


def test_per_channel_quantization(rng):
    w = rng.normal(size=(16, 8)).astype(np.float32) * \
        np.logspace(-2, 2, 8)[None, :]
    q, scale = ucr.quantize_int8(w, per_channel_axis=1)
    assert scale.shape == (1, 8)
    err = np.abs(ucr.dequantize_int8(q, scale) - w)
    assert (err <= scale * 0.5 + 1e-6).all()


def test_layer_encoding_matches_size_only(rng):
    w = rng.normal(size=(8, 4, 3, 3)).astype(np.float32)
    w[rng.random(w.shape) < 0.5] = 0
    code = ucr.encode_conv_layer(w, t_m=4, t_n=2)
    size, n = ucr.layer_code_size_only(w, t_m=4, t_n=2)
    assert n == w.size
    assert size == code.total_bits
