"""Customized Run-Length Encoding (paper §III-C, Fig. 4).

CoDR stores three data structures per weight vector (one vector = the
weights of one input channel across a T_M-output-channel tile, paper
§II-D step iii):

  (a) **Unique-weight Δs** — differences between successive *sorted*
      non-zero unique weights (the first entry is the Δ from zero, i.e.
      the smallest unique weight itself, which may be negative).
      Encoded as ``b`` low-precision bits + 1 escape bit; values that do
      not fit fall back to full precision (8 bits for int8 weights).
  (b) **Repetition counts** — how many times each unique weight repeats
      (range ``[1, T_M*R_K*C_K]``).  Fixed ``b``-bit fields; on overflow a
      *dummy unique weight with Δ=0* is inserted to carry the remainder
      (paper: "a dummy unique weight with Δ=0 is inserted ... to track the
      overflowed portion").
  (c) **Indexes** — output indexes of every repetition.  Same escape
      scheme as (a) except the fallback is the *absolute* index, used when
      the index Δ is negative or does not fit.

The encoder searches the encoding parameter (bit-length) of each structure
independently and per layer, exactly as §III-C prescribes, and the chosen
parameters ride along in the header.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.packing import (BitReader, escape_field_offsets_batch,
                                gather_bitfields, pack_varbits)

FULL_BITS = 8            # full-precision fallback width for int8 weight deltas
HEADER_BITS = 32         # per-stream header: 4b param + 28b count (modelled)
PARAM_SEARCH_SPACE = range(1, 9)


@dataclasses.dataclass
class Stream:
    """One encoded RLE stream."""

    packed: np.ndarray       # uint8 payload
    nbits: int               # exact payload bits
    param: int               # chosen low-precision bit-length
    count: int               # number of fields
    mode_bits: int           # width of the absolute/full-precision fallback

    @property
    def total_bits(self) -> int:
        return self.nbits + HEADER_BITS


@dataclasses.dataclass
class EncodedVector:
    """All three streams for one UCR weight vector + metadata."""

    deltas: Stream
    reps: Stream
    indexes: Stream
    vector_len: int          # T_M * R_K * C_K (index space)
    n_unique: int            # unique non-zero weights incl. overflow dummies
    n_weights: int           # non-zero weight count (== number of indexes)

    @property
    def total_bits(self) -> int:
        return self.deltas.total_bits + self.reps.total_bits + self.indexes.total_bits


# ---------------------------------------------------------------------------
# escape-coded streams (Δs and indexes)
# ---------------------------------------------------------------------------

def _escape_fields(values: np.ndarray, low_bits: int, full_bits: int,
                   absolute: np.ndarray | None = None):
    """Compute (field_values, field_widths, escape_flags) for the escape
    scheme: each field is ``payload`` then 1 flag bit appended at the LSB
    position of the *next* read — we model it as flag(1) + payload(w).

    ``absolute`` — when given (index stream), values that escape are encoded
    as these absolute values instead of their Δ (paper §III-C "Indexes").
    """
    values = np.asarray(values, dtype=np.int64)
    fits = (values >= 0) & (values < (1 << low_bits))
    payload = np.where(fits, values, 0)
    if absolute is not None:
        payload = np.where(fits, values, absolute)
    else:
        # two's complement into full_bits for negatives / overflow
        payload = np.where(fits, values, values & ((1 << full_bits) - 1))
    widths = np.where(fits, low_bits, full_bits)
    # field = flag bit (0 = low precision, 1 = escape) + payload
    fields = (payload.astype(np.uint64) << np.uint64(1)) | (~fits).astype(np.uint64)
    return fields, widths + 1, fits


def escape_stream_bits(values: np.ndarray, low_bits: int, full_bits: int) -> int:
    """Vectorized size-only path (used by the parameter search and the
    compression benchmarks — no bitstream materialization)."""
    values = np.asarray(values, dtype=np.int64)
    fits = (values >= 0) & (values < (1 << low_bits))
    return int(np.where(fits, low_bits + 1, full_bits + 1).sum())


def encode_escape_stream(values: np.ndarray, low_bits: int, full_bits: int,
                         absolute: np.ndarray | None = None) -> Stream:
    fields, widths, _ = _escape_fields(values, low_bits, full_bits, absolute)
    packed, nbits = pack_varbits(fields, widths)
    return Stream(packed, nbits, low_bits, len(values), full_bits)


def decode_escape_stream(stream: Stream, *, absolute_mode: bool = False) -> np.ndarray:
    """Decode an escape stream.  Payloads are unsigned (Δ streams are
    pre-biased to non-negative values — see ``delta_transform``).  With
    ``absolute_mode`` the caller also gets the escape flags to rebuild a
    mixed Δ/absolute position sequence."""
    reader = BitReader(stream.packed, stream.nbits)
    out = np.empty(stream.count, dtype=np.int64)
    escaped = np.zeros(stream.count, dtype=bool)
    for i in range(stream.count):
        flag = reader.read(1)
        if flag:
            out[i] = reader.read(stream.mode_bits)
            escaped[i] = True
        else:
            out[i] = reader.read(stream.param)
    return out if not absolute_mode else np.stack([out, escaped.astype(np.int64)])


# ---------------------------------------------------------------------------
# fixed-width repetition-count stream
# ---------------------------------------------------------------------------

def split_rep_overflow(reps: np.ndarray, rep_bits: int) -> tuple[np.ndarray, np.ndarray]:
    """Split repetition counts that overflow ``rep_bits`` into chains of
    entries, inserting dummy unique weights (Δ=0) for the carried portion.

    Returns ``(rep_entries, dummy_mask)`` where ``dummy_mask[i]`` is True for
    entries that correspond to an inserted dummy (their Δ is 0).  Each entry
    stores ``count - 1`` in ``rep_bits`` bits, so one entry covers counts in
    ``[1, 2**rep_bits]``.
    """
    cap = 1 << rep_bits
    reps = np.asarray(reps, dtype=np.int64)
    n_entries = np.maximum(1, np.ceil(reps / cap)).astype(np.int64)
    total = int(n_entries.sum())
    entries = np.full(total, cap, dtype=np.int64)
    dummy = np.ones(total, dtype=bool)
    # first entry of each chain is the real unique weight; remainder entries
    # are dummies.  The *last* entry of a chain holds the leftover count.
    starts = np.cumsum(n_entries) - n_entries
    ends = starts + n_entries - 1
    leftover = reps - (n_entries - 1) * cap
    entries[ends] = leftover
    dummy[starts] = False
    return entries, dummy


def rep_stream_bits(reps: np.ndarray, rep_bits: int, delta_cost_bits: float) -> float:
    """Size of the repetition stream *including* the Δ-stream bits induced by
    overflow dummies (each dummy adds one Δ=0 field to the Δ stream)."""
    cap = 1 << rep_bits
    reps = np.asarray(reps, dtype=np.int64)
    n_entries = np.maximum(1, np.ceil(reps / cap)).astype(np.int64)
    n_dummies = int(n_entries.sum()) - len(reps)
    return float(int(n_entries.sum()) * rep_bits + n_dummies * delta_cost_bits)


def encode_rep_stream(entries: np.ndarray, rep_bits: int) -> Stream:
    entries = np.asarray(entries, dtype=np.int64)
    fields = (entries - 1).astype(np.uint64)          # store count-1
    widths = np.full(len(entries), rep_bits, dtype=np.int64)
    packed, nbits = pack_varbits(fields, widths)
    return Stream(packed, nbits, rep_bits, len(entries), rep_bits)


def decode_rep_stream(stream: Stream) -> np.ndarray:
    reader = BitReader(stream.packed, stream.nbits)
    return np.array([reader.read(stream.param) + 1 for _ in range(stream.count)],
                    dtype=np.int64)


# ---------------------------------------------------------------------------
# full vector encode / decode
# ---------------------------------------------------------------------------

def delta_transform(unique_vals: np.ndarray) -> np.ndarray:
    """Sorted unique int8 values → non-negative Δ fields.

    The first field is the *absolute* smallest unique weight biased by
    +128 (∈ [1, 255]); subsequent fields are the strictly positive Δs
    (∈ [1, 254]).  Both fit the unsigned 8-bit full-precision fallback —
    a signed encoding would need 9 bits for Δs up to 254 (paper Fig. 4
    shows unsigned payloads).  Dummy overflow entries use Δ = 0.
    """
    unique_vals = np.asarray(unique_vals, dtype=np.int64)
    out = np.empty(len(unique_vals), dtype=np.int64)
    if len(out):
        out[0] = unique_vals[0] + 128
        out[1:] = np.diff(unique_vals)
    return out


def delta_untransform_first(field: int) -> int:
    return field - 128


def index_delta_fields(indexes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Δ between subsequent indexes in the flat stream; first index and any
    negative Δ use absolute fallback (handled by the escape encoder)."""
    indexes = np.asarray(indexes, dtype=np.int64)
    deltas = np.empty_like(indexes)
    if len(indexes):
        deltas[0] = -1                        # force absolute for the first
        deltas[1:] = indexes[1:] - indexes[:-1]
    return deltas, indexes


def search_delta_param(deltas: np.ndarray) -> int:
    sizes = {b: escape_stream_bits(deltas, b, FULL_BITS) for b in PARAM_SEARCH_SPACE}
    return min(sizes, key=sizes.get)


def search_index_param(index_deltas: np.ndarray, index_bits: int) -> int:
    space = [b for b in PARAM_SEARCH_SPACE if b <= index_bits] or [index_bits]
    sizes = {b: escape_stream_bits(index_deltas, b, index_bits) for b in space}
    return min(sizes, key=sizes.get)


def search_rep_param(reps: np.ndarray, delta_cost_bits: float) -> int:
    sizes = {b: rep_stream_bits(reps, b, delta_cost_bits) for b in PARAM_SEARCH_SPACE}
    return min(sizes, key=sizes.get)


def encode_vector(unique_vals: np.ndarray, reps: np.ndarray,
                  indexes: np.ndarray, vector_len: int,
                  params: tuple[int, int, int] | None = None
                  ) -> EncodedVector:
    """Encode one UCR-transformed weight vector (see :mod:`repro.core.ucr`).

    ``unique_vals`` — sorted non-zero unique int8 values (ascending);
    ``reps[i]``     — repetition count of ``unique_vals[i]``;
    ``indexes``     — flat index stream (per-unique ascending positions).
    ``params``      — optional (delta, rep, index) bit-lengths shared
                      across a layer (paper §III-C: the encoder searches
                      per layer and per structure; headers are then paid
                      once per layer, see ``layer_params_search``).
    """
    unique_vals = np.asarray(unique_vals, dtype=np.int64)
    reps = np.asarray(reps, dtype=np.int64)
    indexes = np.asarray(indexes, dtype=np.int64)
    index_bits = max(1, math.ceil(math.log2(max(vector_len, 2))))

    # --- parameter search (independent per structure, §III-C) -------------
    base_deltas = delta_transform(unique_vals)
    if params is not None:
        delta_param, rep_param, index_param_fixed = params
    else:
        delta_param = search_delta_param(base_deltas)
        delta_cost = escape_stream_bits(base_deltas, delta_param,
                                        FULL_BITS) / max(len(base_deltas), 1)
        rep_param = search_rep_param(reps, delta_cost)
        index_param_fixed = None

    # --- overflow dummies --------------------------------------------------
    rep_entries, dummy = split_rep_overflow(reps, rep_param)
    # expand Δs with Δ=0 dummies at the dummy positions
    full_deltas = np.zeros(len(rep_entries), dtype=np.int64)
    full_deltas[~dummy] = base_deltas

    idx_deltas, idx_abs = index_delta_fields(indexes)
    index_param = (index_param_fixed if index_param_fixed is not None
                   else search_index_param(idx_deltas, index_bits))
    index_param = min(index_param, index_bits)

    deltas_s = encode_escape_stream(full_deltas, delta_param, FULL_BITS)
    reps_s = encode_rep_stream(rep_entries, rep_param)
    indexes_s = encode_escape_stream(idx_deltas, index_param, index_bits,
                                     absolute=idx_abs)
    return EncodedVector(deltas_s, reps_s, indexes_s, vector_len,
                         len(rep_entries), len(indexes))


def decode_vector(enc: EncodedVector) -> np.ndarray:
    """Reconstruct the dense int8 weight vector (inverse of UCR+RLE)."""
    deltas = decode_escape_stream(enc.deltas)
    reps = decode_rep_stream(enc.reps)
    raw = decode_escape_stream(enc.indexes, absolute_mode=True)
    vals, escaped = raw[0], raw[1].astype(bool)
    # rebuild absolute indexes from the Δ/absolute mix
    indexes = np.empty(enc.indexes.count, dtype=np.int64)
    prev = 0
    for i in range(enc.indexes.count):
        indexes[i] = vals[i] if escaped[i] else prev + vals[i]
        prev = indexes[i]

    weights = np.zeros(enc.vector_len, dtype=np.int8)
    running = 0
    cursor = 0
    for u in range(enc.n_unique):
        if u == 0:
            running = delta_untransform_first(int(deltas[0]))
        else:
            running += int(deltas[u])
        for _ in range(int(reps[u])):
            weights[indexes[cursor]] = running
            cursor += 1
    return weights


# ---------------------------------------------------------------------------
# vectorized bulk decode — whole-layer, no per-field Python loop
# ---------------------------------------------------------------------------

def _stream_bits(streams) -> tuple[np.ndarray, np.ndarray]:
    """Bit-level concatenation of many packed streams: one ``unpackbits``
    over the joined payload bytes, then one gather dropping each stream's
    byte-alignment slack.  Returns ``(bits, stream_bit_starts)``."""
    allbits = np.unpackbits(
        np.concatenate([np.asarray(s.packed, dtype=np.uint8)
                        for s in streams]) if streams
        else np.zeros(0, dtype=np.uint8), bitorder="little")
    nbytes = np.array([len(s.packed) for s in streams], dtype=np.int64)
    nbits = np.array([s.nbits for s in streams], dtype=np.int64)
    starts = np.cumsum(nbits) - nbits
    within = (np.arange(int(nbits.sum()), dtype=np.int64)
              - np.repeat(starts, nbits))
    idx = np.repeat((np.cumsum(nbytes) - nbytes) * 8, nbits) + within
    return allbits[idx], starts


def _flat_dest(field_start: np.ndarray, counts: np.ndarray,
               idxs: list[int]) -> np.ndarray:
    """Flat positions of the fields of streams ``idxs`` inside the
    all-streams field order (stream-major)."""
    sub_counts = counts[idxs]
    total = int(sub_counts.sum())
    within = (np.arange(total, dtype=np.int64)
              - np.repeat(np.cumsum(sub_counts) - sub_counts, sub_counts))
    return np.repeat(field_start[idxs], sub_counts) + within


def _grouped_escape_decode(streams) -> tuple[np.ndarray, np.ndarray]:
    """Decode many escape streams in one vectorized pass per parameter
    group.  Streams sharing ``(param, mode_bits)`` — the common case, since
    params are per layer (§III-C) — are concatenated at the bit level and
    decoded together: field-start offsets from the lockstep cursor advance
    of :func:`repro.core.packing.escape_field_offsets_batch`, payloads from
    one shift/mask gather.

    Returns ``(values, escaped)`` concatenated in stream order.
    """
    counts = np.array([s.count for s in streams], dtype=np.int64)
    total = int(counts.sum())
    values = np.zeros(total, dtype=np.int64)
    escaped = np.zeros(total, dtype=bool)
    if total == 0:
        return values, escaped
    field_start = np.cumsum(counts) - counts
    groups: dict[tuple[int, int], list[int]] = {}
    for si, s in enumerate(streams):
        if s.count:
            groups.setdefault((s.param, s.mode_bits), []).append(si)
    for (param, mode), idxs in groups.items():
        bits, starts = _stream_bits([streams[i] for i in idxs])
        ends = starts + np.array([streams[i].nbits for i in idxs],
                                 dtype=np.int64)
        offsets = escape_field_offsets_batch(bits, starts, counts[idxs],
                                             param + 1, mode + 1, ends)
        flags = bits[offsets].astype(bool)
        vals = gather_bitfields(bits, offsets + 1,
                                np.where(flags, mode, param))
        dest = _flat_dest(field_start, counts, idxs)
        values[dest] = vals
        escaped[dest] = flags
    return values, escaped


def _grouped_rep_decode(streams) -> np.ndarray:
    """Decode many fixed-width repetition streams in one gather per
    ``rep_bits`` group (field offsets are arithmetic)."""
    counts = np.array([s.count for s in streams], dtype=np.int64)
    total = int(counts.sum())
    out = np.zeros(total, dtype=np.int64)
    if total == 0:
        return out
    field_start = np.cumsum(counts) - counts
    groups: dict[int, list[int]] = {}
    for si, s in enumerate(streams):
        if s.count:
            groups.setdefault(s.param, []).append(si)
    for param, idxs in groups.items():
        bits, starts = _stream_bits([streams[i] for i in idxs])
        nbits = np.array([streams[i].nbits for i in idxs], dtype=np.int64)
        short = np.nonzero(counts[idxs] * param != nbits)[0]
        if len(short):                       # truncated/corrupt rep stream
            i = idxs[int(short[0])]
            raise EOFError(
                f"corrupt rep stream {i}: {int(counts[i])} x {param}-bit "
                f"fields vs a {int(streams[i].nbits)}-bit payload")
        within = _flat_dest(np.zeros_like(field_start), counts, idxs)
        offsets = np.repeat(starts, counts[idxs]) + within * param
        vals = gather_bitfields(bits, offsets, param) + 1
        out[_flat_dest(field_start, counts, idxs)] = vals
    return out


def decode_layer(code, *, pad_to: int | None = None) -> np.ndarray:
    """Decode every vector of a :class:`repro.core.ucr.LayerCode` — or of
    a plain sequence of :class:`EncodedVector` (e.g. one tile's slice) —
    in one vectorized pass: the bulk counterpart of :func:`decode_vector`
    (which stays as the parity oracle; tests assert bit-exact agreement).

    Returns int8 ``(n_vectors, pad_to)``; row ``i`` equals
    ``decode_vector(vectors[i])`` zero-padded to ``pad_to`` (default:
    the layer's max ``vector_len``).  All three structures decode without
    a per-field Python loop: escape streams via pointer-doubling offset
    resolution + shift/mask gathers, repetition streams via one arithmetic
    gather, running weights and Δ/absolute index mixes via segmented
    cumulative sums, and the final placement via one fancy-indexed scatter.
    """
    vectors = getattr(code, "vectors", code)
    n_vec = len(vectors)
    max_len = max((v.vector_len for v in vectors), default=0)
    if pad_to is None:
        pad_to = max_len
    elif pad_to < max_len:
        raise ValueError(f"pad_to={pad_to} < max vector_len={max_len}")
    out = np.zeros((n_vec, pad_to), dtype=np.int8)
    if n_vec == 0:
        return out

    d_vals, _ = _grouped_escape_decode([v.deltas for v in vectors])
    reps = _grouped_rep_decode([v.reps for v in vectors])
    i_vals, i_esc = _grouped_escape_decode([v.indexes for v in vectors])

    # running weight values: segmented cumsum over Δ fields (the first
    # field of each vector carries the +128 bias, dummies are Δ=0)
    n_unique = np.array([v.n_unique for v in vectors], dtype=np.int64)
    cs = np.cumsum(d_vals)
    if len(cs):
        seg_first = np.cumsum(n_unique) - n_unique
        base = np.where(seg_first > 0, cs[np.maximum(seg_first - 1, 0)], 0)
        running = cs - np.repeat(base, n_unique) - 128
    else:                                    # all-zero layer: no uniques
        running = cs

    # absolute indexes from the Δ/absolute mix: every vector's first index
    # field is absolute (escaped), so a global "reset at last escape"
    # segmented cumsum rebuilds all positions at once
    n_idx = np.array([v.indexes.count for v in vectors], dtype=np.int64)
    if len(i_vals):
        if not i_esc[0]:
            raise ValueError("corrupt index stream: first field not absolute")
        pos = np.arange(len(i_vals), dtype=np.int64)
        last_esc = np.maximum.accumulate(np.where(i_esc, pos, -1))
        ics = np.cumsum(np.where(i_esc, 0, i_vals))
        idx_abs = i_vals[last_esc] + ics - ics[last_esc]
    else:
        idx_abs = np.zeros(0, dtype=np.int64)

    w_vals = np.repeat(running, reps)
    row = np.repeat(np.arange(n_vec), n_idx)
    out[row, idx_abs] = w_vals.astype(np.int8)
    return out


def decode_layer_vectors(code) -> list[np.ndarray]:
    """Per-vector views of :func:`decode_layer`, each cropped to its true
    ``vector_len`` (drop-in for a ``decode_vector`` loop)."""
    padded = decode_layer(code)
    return [padded[i, : v.vector_len] for i, v in enumerate(code.vectors)]


def layer_params_search(ucr_vectors, vector_len: int) -> tuple[int, int, int]:
    """Per-layer, per-structure parameter search over ALL of a layer's
    vectors (paper §III-C: params are stored once per structure per layer
    — headers amortize across the layer)."""
    index_bits = max(1, math.ceil(math.log2(max(vector_len, 2))))
    all_deltas = np.concatenate(
        [delta_transform(u.unique_vals) for u in ucr_vectors]) \
        if ucr_vectors else np.zeros(0, dtype=np.int64)
    all_reps = np.concatenate([u.reps for u in ucr_vectors]) \
        if ucr_vectors else np.zeros(0, dtype=np.int64)
    all_idx = np.concatenate(
        [index_delta_fields(u.indexes)[0] for u in ucr_vectors]) \
        if ucr_vectors else np.zeros(0, dtype=np.int64)
    dp = search_delta_param(all_deltas)
    dcost = escape_stream_bits(all_deltas, dp, FULL_BITS) / max(len(all_deltas), 1)
    rp = search_rep_param(all_reps, dcost)
    ip = search_index_param(all_idx, index_bits)
    return dp, rp, ip


def layer_bits_size_only(ucr_vectors, vector_len: int,
                         params: tuple[int, int, int] | None = None) -> int:
    """Exact encoded size of a whole layer under shared per-layer params
    (vectorized — concatenated streams decompose per element).

    ``params`` — optional fixed (delta, rep, index) bit-lengths; ``None``
    runs :func:`layer_params_search` first.  Sizes here match
    ``encode_conv_layer(...).total_bits`` bit for bit under the same
    params — the tuner and the oracle tests both rely on that parity.
    """
    if not ucr_vectors:
        return 3 * HEADER_BITS
    index_bits = max(1, math.ceil(math.log2(max(vector_len, 2))))
    if params is None:
        dp, rp, ip = layer_params_search(ucr_vectors, vector_len)
    else:
        dp, rp, ip = (int(p) for p in params)
    ip = min(ip, index_bits)
    all_deltas = np.concatenate(
        [delta_transform(u.unique_vals) for u in ucr_vectors])
    all_reps = np.concatenate([u.reps for u in ucr_vectors])
    all_idx = np.concatenate(
        [index_delta_fields(u.indexes)[0] for u in ucr_vectors])
    entries, dummy = split_rep_overflow(all_reps, rp)
    full_deltas = np.zeros(len(entries), dtype=np.int64)
    full_deltas[~dummy] = all_deltas
    return (escape_stream_bits(full_deltas, dp, FULL_BITS)
            + len(entries) * rp
            + escape_stream_bits(all_idx, ip, index_bits)
            + 3 * HEADER_BITS)


def encoded_bits_size_only(unique_vals: np.ndarray, reps: np.ndarray,
                           indexes: np.ndarray, vector_len: int) -> int:
    """Fast vectorized total-bit count (no bitstream) — used by benchmarks."""
    unique_vals = np.asarray(unique_vals, dtype=np.int64)
    reps = np.asarray(reps, dtype=np.int64)
    index_bits = max(1, math.ceil(math.log2(max(vector_len, 2))))
    base_deltas = delta_transform(unique_vals)
    delta_param = search_delta_param(base_deltas)
    delta_cost = escape_stream_bits(base_deltas, delta_param, FULL_BITS) / max(len(base_deltas), 1)
    rep_param = search_rep_param(reps, delta_cost)
    rep_entries, dummy = split_rep_overflow(reps, rep_param)
    full_deltas = np.zeros(len(rep_entries), dtype=np.int64)
    full_deltas[~dummy] = base_deltas
    idx_deltas, _ = index_delta_fields(indexes)
    index_param = search_index_param(idx_deltas, index_bits)
    return (escape_stream_bits(full_deltas, delta_param, FULL_BITS)
            + len(rep_entries) * rep_param
            + escape_stream_bits(idx_deltas, index_param, index_bits)
            + 3 * HEADER_BITS)
