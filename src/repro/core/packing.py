"""Bit-level packing utilities for the CoDR run-length encoder.

The CoDR RLE streams are true variable-width bitstreams (paper Fig. 4):
each field is ``flag_bit + payload`` where the payload is either the
low-precision width ``b`` or the full-precision width.  We implement an
exact bit-accurate packer/unpacker so compression ratios are measured in
real bits, not estimates.

Packing is fully vectorized (numpy).  Unpacking of variable-width streams
*looks* inherently sequential (the width of field ``k+1`` depends on the
flag bit of field ``k``), but because an escape-coded field takes only
two possible widths the field-start offsets form a jump chain over the
bit array that :func:`escape_field_offsets` resolves in ``O(log n)``
vectorized pointer-doubling passes; :func:`gather_bitfields` then
extracts every payload with shifts and masks in one pass.  The scalar
:class:`BitReader` is kept as the parity oracle.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "pack_varbits", "unpack_bits", "BitReader",
    "escape_field_offsets", "escape_field_offsets_batch", "gather_bitfields",
]


def pack_varbits(values: np.ndarray, widths: np.ndarray) -> tuple[np.ndarray, int]:
    """Pack ``values[i]`` into ``widths[i]`` bits each, LSB-first per field.

    Returns ``(packed_uint8, total_bits)``.  Values must be non-negative and
    fit in their widths (masked to width — caller is responsible for
    two's-complement pre-encoding of negatives).
    """
    values = np.asarray(values, dtype=np.uint64)
    widths = np.asarray(widths, dtype=np.int64)
    if values.shape != widths.shape:
        raise ValueError(f"shape mismatch {values.shape} vs {widths.shape}")
    total_bits = int(widths.sum())
    if total_bits == 0:
        return np.zeros(0, dtype=np.uint8), 0
    # index of the source value for every output bit
    field_idx = np.repeat(np.arange(len(values)), widths)
    # bit position within each field (0 = LSB)
    offsets = np.cumsum(widths) - widths
    bitpos = np.arange(total_bits, dtype=np.int64) - np.repeat(offsets, widths)
    bits = ((values[field_idx] >> bitpos.astype(np.uint64)) & 1).astype(np.uint8)
    packed = np.packbits(bits, bitorder="little")
    return packed, total_bits


def unpack_bits(packed: np.ndarray, total_bits: int) -> np.ndarray:
    """Inverse of the bit-expansion in :func:`pack_varbits` — returns the raw
    0/1 bit array of length ``total_bits``."""
    bits = np.unpackbits(np.asarray(packed, dtype=np.uint8), bitorder="little")
    return bits[:total_bits]


# ---------------------------------------------------------------------------
# vectorized variable-width decode primitives
# ---------------------------------------------------------------------------

def escape_field_offsets(bits: np.ndarray, n_fields: int,
                         low_width: int, full_width: int) -> np.ndarray:
    """Start offsets of ``n_fields`` escape-coded fields in ``bits``.

    Field ``k`` starts at ``o_k``; its total width (flag + payload) is
    ``low_width`` when ``bits[o_k] == 0`` and ``full_width`` otherwise, so
    ``o_{k+1} = o_k + width(o_k)`` — a jump chain.  Resolved with pointer
    doubling: ``offsets[m:2m] = jump^m[offsets[:m]]``, composing the jump
    table with itself between blocks, i.e. ``O(|bits| · log n_fields)``
    vectorized work instead of a Python loop over fields.
    """
    offsets = np.empty(n_fields, dtype=np.int64)
    if n_fields == 0:
        return offsets
    t = len(bits)
    pad = max(low_width, full_width, 1)          # safe gather past the end
    jump = np.arange(t + pad, dtype=np.int64)
    jump[:t] += np.where(bits[:t] == 0, low_width, full_width)
    np.minimum(jump, t + pad - 1, out=jump)
    offsets[0] = 0
    m = 1
    while m < n_fields:
        k = min(m, n_fields - m)
        offsets[m : m + k] = jump[offsets[:k]]
        m *= 2
        if m < n_fields:                         # compose: jump^m → jump^2m
            jump = np.minimum(jump[jump], t + pad - 1)
    if n_fields > 1 and offsets[-1] >= t:
        raise EOFError(
            f"bitstream exhausted resolving field offsets: field "
            f"{n_fields - 1} starts at bit {int(offsets[-1])} of {t}")
    return offsets


def escape_field_offsets_batch(bits: np.ndarray, starts: np.ndarray,
                               counts: np.ndarray, low_width: int,
                               full_width: int,
                               ends: np.ndarray | None = None) -> np.ndarray:
    """Field-start offsets for MANY escape streams laid back-to-back in
    ``bits`` (stream ``i`` starts at ``starts[i]`` and holds ``counts[i]``
    fields).  All stream cursors advance in lockstep — one vectorized
    gather per field *rank*, so the work is ``O(total_fields)`` regardless
    of how long the bit array is (vs the ``O(|bits| · log n)`` pointer
    doubling of :func:`escape_field_offsets`, which remains the
    single-stream fallback).

    ``ends`` — per-stream end offsets.  When given, each stream's final
    cursor must land EXACTLY on its end (field widths tile a valid payload
    with no slack), so a truncated or corrupt stream raises
    :class:`EOFError` instead of silently bleeding into its neighbour's
    bits — the same guarantee the scalar :class:`BitReader` gives.

    Returns the flat per-field offsets in stream-major order.
    """
    starts = np.asarray(starts, dtype=np.int64)
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    out = np.empty(total, dtype=np.int64)
    if total == 0:
        return out
    dest_base = np.cumsum(counts) - counts
    order = np.argsort(-counts, kind="stable")   # longest streams first →
    counts_s = counts[order]                     # active set is a prefix
    cur = starts[order].copy()
    dest = dest_base[order]
    step = full_width - low_width
    try:
        for s in range(int(counts_s[0])):
            k = np.searchsorted(-counts_s, -s, side="left")
            c = cur[:k]
            out[dest[:k] + s] = c
            cur[:k] = c + low_width + step * bits[c]
    except IndexError:
        raise EOFError(
            f"bitstream exhausted resolving batch field offsets at rank "
            f"{s} of {int(counts_s[0])}") from None
    if ends is not None:
        bad = np.nonzero(cur != np.asarray(ends, dtype=np.int64)[order])[0]
        if len(bad):
            i = int(order[bad[0]])
            raise EOFError(
                f"corrupt stream {i}: {int(counts[i])} fields end at bit "
                f"{int(cur[bad[0]] - starts[i])} of its "
                f"{int(np.asarray(ends)[i] - starts[i])}-bit payload")
    return out


def gather_bitfields(bits: np.ndarray, offsets: np.ndarray,
                     widths: np.ndarray | int) -> np.ndarray:
    """Extract ``values[i]`` = the LSB-first ``widths[i]``-bit field starting
    at ``offsets[i]`` — one vectorized shift/mask pass, no cursor walk."""
    offsets = np.asarray(offsets, dtype=np.int64)
    widths = np.broadcast_to(np.asarray(widths, dtype=np.int64), offsets.shape)
    if len(offsets) == 0:
        return np.zeros(0, dtype=np.int64)
    w_max = int(widths.max())
    if w_max == 0:
        return np.zeros(len(offsets), dtype=np.int64)
    if len(bits) == 0 or int((offsets + widths).max()) > len(bits):
        raise EOFError(
            f"bitstream exhausted: field ends at bit "
            f"{int((offsets + widths).max())} of {len(bits)}")
    lanes = np.arange(w_max, dtype=np.int64)
    idx = np.minimum(offsets[:, None] + lanes, len(bits) - 1)
    lane_bits = bits[idx].astype(np.uint64) * (lanes < widths[:, None])
    return (lane_bits << lanes.astype(np.uint64)).sum(axis=1).astype(np.int64)


class BitReader:
    """Sequential cursor over a packed bitstream (LSB-first fields)."""

    def __init__(self, packed: np.ndarray, total_bits: int):
        self._bits = unpack_bits(packed, total_bits)
        self.pos = 0

    @property
    def remaining(self) -> int:
        return len(self._bits) - self.pos

    def read(self, width: int) -> int:
        if width == 0:
            return 0
        if self.pos + width > len(self._bits):
            raise EOFError(
                f"bitstream exhausted: read of {width} bits at position "
                f"{self.pos} overruns the {len(self._bits)}-bit payload")
        chunk = self._bits[self.pos : self.pos + width]
        self.pos += width
        # LSB-first
        return int((chunk.astype(np.uint64) << np.arange(width, dtype=np.uint64)).sum())

    def read_many(self, widths) -> np.ndarray:
        """Bulk read: ``out[i]`` is the next ``widths[i]``-bit field, in
        order.  One vectorized gather instead of ``len(widths)`` cursor
        steps; raises :class:`EOFError` (cursor unmoved) on overrun."""
        widths = np.asarray(widths, dtype=np.int64)
        if widths.ndim != 1:
            raise ValueError("widths must be a 1-D sequence")
        if len(widths) and widths.min() < 0:
            raise ValueError("widths must be non-negative")
        total = int(widths.sum())
        if self.pos + total > len(self._bits):
            raise EOFError(
                f"bitstream exhausted: bulk read of {total} bits at position "
                f"{self.pos} overruns the {len(self._bits)}-bit payload")
        offsets = self.pos + np.cumsum(widths) - widths
        out = gather_bitfields(self._bits, offsets, widths)
        self.pos += total
        return out
